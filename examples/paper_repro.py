"""Reproduce the paper's three experimental artifacts end-to-end.

  1. Table 1  — MOA census of AlexNet under Direct Hardware Mapping.
  2. Figure 4 — serialized MOA vs adder tree (ALM model) + the TPU
                inversion (serial accumulation is free — Pallas kernel).
  3. Figure 5 — LOA approximate adder: MRED curves + flat-ALM negative
                result + the measured TPU analogue (6 VPU ops vs 1).

Plus the end-to-end piece the paper motivates but doesn't run: an actual
quantized conv layer computed with LOA accumulation, showing the accuracy
impact on real dot products (LeNet-5 conv1).

  PYTHONPATH=src python examples/paper_repro.py
"""

import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, _ROOT)                      # benchmarks package
sys.path.insert(0, os.path.join(_ROOT, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import fig4_serialization, fig5_loa, table1_moa_counts
from repro.core import metrics
from repro.core.scm import quantize_symmetric
from repro.models import cnn


def loa_conv_end_to_end():
    """§3.2 taken to its logical end: LOA accumulation inside a real conv."""
    print("\n=== LOA inside a real conv layer (beyond-paper) " + "=" * 22)
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (1, 16, 16, 3))
    w = jax.random.normal(kw, (8, 3, 5, 5))
    b = jnp.zeros((8,))
    # quantize to the paper's 8-bit regime
    xq = jnp.asarray(quantize_symmetric(np.asarray(x), 8) + 128,
                     jnp.int32)  # unsigned 8-bit operands
    wq = jnp.asarray(np.abs(quantize_symmetric(np.asarray(w), 4)),
                     jnp.int32)
    exact = cnn.im2col_conv(xq, wq, jnp.zeros((8,), jnp.int32), stride=1,
                            strategy="tree")
    print(f"{'l':>3s} {'MRED':>9s}")
    for l in (0, 2, 4, 6):
        approx = cnn.im2col_conv(
            xq, wq, jnp.zeros((8,), jnp.int32), stride=1,
            strategy=f"loa?approx_bits={l}&width=8")
        m = float(metrics.mred(approx, exact))
        print(f"{l:3d} {m:9.5f}")
    print("→ graceful error growth, exactly as Fig. 5 predicts — but on "
          "TPU this path costs 6× the exact adds (see fig5 bench). "
          "How not to solve it.")


def main():
    print("=== Table 1 " + "=" * 60)
    table1_moa_counts.run()
    print("\n=== Figure 4 " + "=" * 59)
    fig4_serialization.run()
    print("\n=== Figure 5 " + "=" * 59)
    fig5_loa.run()
    loa_conv_end_to_end()


if __name__ == "__main__":
    main()
