"""Fault-tolerant training demo: two injected node failures, automatic
checkpoint-restore, bit-exact resume, plus int8 gradient compression.

  PYTHONPATH=src python examples/fault_tolerant_train.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.registry import get_config, smoke_config
from repro.launch.steps import TrainHyper
from repro.launch.train import TrainLoop
from repro.runtime import FailureInjector


def main():
    cfg = smoke_config(get_config("mamba2-370m"))
    steps = 40
    with tempfile.TemporaryDirectory() as d:
        # clean reference run
        ref = TrainLoop(cfg, steps=steps, global_batch=8, seq_len=48,
                        ckpt_dir=os.path.join(d, "ref"), save_every=10,
                        hyper=TrainHyper(peak_lr=3e-3, warmup_steps=4,
                                         total_steps=steps,
                                         compress_grads=True),
                        log_every=10, async_save=False)
        ref.run_segment(0, None)
        ref_final = ref.metrics_history[-1]["loss"]

        # faulty run: nodes die at steps 17 and 31
        print("\n--- now with two injected node losses (steps 17, 31) ---")
        faulty = TrainLoop(cfg, steps=steps, global_batch=8, seq_len=48,
                           ckpt_dir=os.path.join(d, "faulty"), save_every=10,
                           hyper=TrainHyper(peak_lr=3e-3, warmup_steps=4,
                                            total_steps=steps,
                                            compress_grads=True),
                           injector=FailureInjector([17, 31]),
                           log_every=10, async_save=False)
        _, result = faulty.run(max_restarts=3)
        faulty_final = faulty.metrics_history[-1]["loss"]
        print(f"\nrestarts: {result.restarts}  "
              f"completed: {result.completed}")
        print(f"final loss clean={ref_final:.6f} faulty={faulty_final:.6f} "
              f"({'BIT-EXACT resume' if ref_final == faulty_final else 'drift!'})")
        print(f"straggler reports: {len(faulty.monitor.reports)}")


if __name__ == "__main__":
    main()
