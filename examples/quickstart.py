"""Quickstart: train a small LM end-to-end on CPU in ~2 minutes.

Demonstrates the public API surface:
  config registry → build_model → TrainLoop (data pipeline, AdamW,
  checkpointing) → loss goes down → serve a few greedy tokens.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, smoke_config
from repro.launch.serve import serve_batch
from repro.launch.steps import TrainHyper
from repro.launch.train import TrainLoop
from repro.models.api import build_model


def main():
    cfg = smoke_config(get_config("llama3-8b"))
    print(f"arch: {cfg.name} ({cfg.n_layers}L d={cfg.d_model}, "
          f"{cfg.param_count()/1e3:.0f}k params)")
    print(f"MOA strategy: {cfg.moa_strategy.spec} — the paper's §3.1 knob, "
          "resolved from the repro.moa registry framework-wide")

    steps = 60
    with tempfile.TemporaryDirectory() as ckpt_dir:
        loop = TrainLoop(
            cfg, steps=steps, global_batch=8, seq_len=64,
            ckpt_dir=ckpt_dir, save_every=20,
            hyper=TrainHyper(peak_lr=5e-3, warmup_steps=5,
                             total_steps=steps),
            log_every=10)
        state, result = loop.run()
        losses = [m["loss"] for m in loop.metrics_history]
        print(f"\nloss: {losses[0]:.3f} → {losses[-1]:.3f} "
              f"({'LEARNED' if losses[-1] < losses[0] - 0.2 else 'check'})")

        # serve from the trained weights
        model = build_model(cfg)
        prompts = model.make_batch(jax.random.PRNGKey(1),
                                   ShapeSpec("s", 32, 2, "prefill"))
        tokens, stats = serve_batch(model, state["params"], prompts,
                                    gen_len=8, max_len=48)
        print(f"served {tokens.shape[1]} tokens/seq at "
              f"{stats['per_token_ms']:.0f} ms/token (CPU)")


if __name__ == "__main__":
    main()
