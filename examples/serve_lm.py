"""Batched serving demo across families: dense (KV cache), SSM (constant
state), hybrid (mixed) — prefill + greedy decode with latency stats.

  PYTHONPATH=src python examples/serve_lm.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, smoke_config
from repro.launch.serve import serve_batch
from repro.models.api import build_model


def main():
    rng = jax.random.PRNGKey(0)
    for arch in ("llama3-8b", "mamba2-370m", "zamba2-1.2b"):
        cfg = smoke_config(get_config(arch))
        model = build_model(cfg)
        params = model.init(rng)
        B, P, G = 4, 32, 16
        prompts = model.make_batch(rng, ShapeSpec("s", P, B, "prefill"))
        tokens, stats = serve_batch(model, params, prompts, gen_len=G,
                                    max_len=P + G + 1)
        state_kind = {"dense": "KV cache (grows with context)",
                      "ssm": "SSM state (O(1) in context)",
                      "hybrid": "SSM states + periodic shared-attn KV"} \
            .get(cfg.family, cfg.family)
        print(f"{arch:14s} [{cfg.family:6s}] prefill "
              f"{stats['prefill_s']*1e3:6.0f}ms  decode "
              f"{stats['per_token_ms']:6.1f}ms/tok  "
              f"{stats['decode_tok_per_s']:7.1f} tok/s  | {state_kind}")


if __name__ == "__main__":
    main()
