"""Batched serving demo across families: dense (KV cache), SSM (constant
state), hybrid (mixed) — prefill + greedy decode with latency stats, plus
a continuous-batching run (Poisson arrivals into a slot scheduler; see
docs/serving.md) and an optional speculative-decoding run
(docs/spec-decode.md).

  PYTHONPATH=src python examples/serve_lm.py
  PYTHONPATH=src python examples/serve_lm.py --spec-decode
  PYTHONPATH=src python examples/serve_lm.py --spec-decode \
      --drafter "oracle?accept=1.0" --spec-k 4

The same flags exist on the full serving CLI
(``python -m repro.launch.serve --spec-decode --drafter ngram?n=3``).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, smoke_config
from repro.launch.serve import serve_batch
from repro.models.api import build_model
from repro.serve import ServeEngine, poisson_workload, resolve_drafter


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--spec-decode", action="store_true",
                    help="add a speculative-decoding engine run")
    ap.add_argument("--drafter", default="ngram?n=3",
                    help="drafter spec: ngram[?n=N] or oracle[?accept=P]")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="draft tokens per verify window")
    args = ap.parse_args()
    rng = jax.random.PRNGKey(0)
    for arch in ("llama3-8b", "mamba2-370m", "zamba2-1.2b"):
        cfg = smoke_config(get_config(arch))
        model = build_model(cfg)
        params = model.init(rng)
        B, P, G = 4, 32, 16
        prompts = model.make_batch(rng, ShapeSpec("s", P, B, "prefill"))
        tokens, stats = serve_batch(model, params, prompts, gen_len=G,
                                    max_len=P + G + 1)
        state_kind = {"dense": "KV cache (grows with context)",
                      "ssm": "SSM state (O(1) in context)",
                      "hybrid": "SSM states + periodic shared-attn KV"} \
            .get(cfg.family, cfg.family)
        print(f"{arch:14s} [{cfg.family:6s}] prefill "
              f"{stats['prefill_s']*1e3:6.0f}ms  decode "
              f"{stats['per_token_ms']:6.1f}ms/tok  "
              f"{stats['decode_tok_per_s']:7.1f} tok/s  | {state_kind}")

    # continuous batching: open-loop arrivals into a 3-slot engine
    cfg = smoke_config(get_config("llama3-8b"))
    model = build_model(cfg)
    params = model.init(rng)
    engine = ServeEngine(model, params, n_slots=3, max_len=64)
    results, report = engine.run(poisson_workload(
        n_requests=8, rate_rps=100.0, vocab=cfg.vocab,
        prompt_len_range=(4, 24), gen_len_range=(2, 10)))
    print(f"\ncontinuous batching: {report['n_requests']} requests over "
          f"{report['n_slots']} slots — {report['tok_per_s']:.1f} tok/s, "
          f"occupancy {report['slot_occupancy']:.2f}, "
          f"{report['slot_reuse']} slot reuses")

    if args.spec_decode:
        # speculative decoding: draft k tokens per tick, verify in one
        # pass; greedy outputs stay bit-identical to plain decode, the
        # accept rate decides whether the gamble paid
        engine = ServeEngine(model, params, n_slots=3, max_len=64,
                             drafter=resolve_drafter(args.drafter,
                                                     args.spec_k))
        _, report = engine.run(poisson_workload(
            n_requests=8, rate_rps=100.0, vocab=cfg.vocab,
            prompt_len_range=(4, 24), gen_len_range=(2, 10)))
        sp = report["spec"]
        print(f"speculative ({args.drafter}, k={args.spec_k}): "
              f"{sp['tokens_per_step']:.2f} tokens/step "
              f"(plain decode = 1.00), accept rate "
              f"{sp['accept_rate']:.2f}, "
              f"{sp['draft_steps']} draft model steps")


if __name__ == "__main__":
    main()
