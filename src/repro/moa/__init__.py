"""Pluggable Multi-Operand-Adder engine — the paper's design space as an API.

Public surface::

    from repro.moa import (
        MOAStrategy,                      # abstract base (sum / dot / cost)
        TreeStrategy, SerialStrategy, LOAStrategy,
        register_strategy,                # add your own in ~50 lines
        resolve,                          # "serial?chunk=512" -> strategy
        available_strategies, get_strategy_class,
        moa_scope, active_strategy,       # scoped experiment overrides
        registry_stats,
    )

Every dense contraction in the model stack routes through a strategy
resolved from :class:`repro.configs.base.ModelConfig` (``cfg.moa`` spec
string plus per-site ``cfg.moa_overrides``), with the Pallas kernels
selected automatically on TPU (``backend="auto"``). The legacy string-kind
API survives as a deprecation shim in :mod:`repro.core.moa`.
"""

from repro.moa.base import BACKENDS, MOAStrategy, resolved_backend
from repro.moa.backends import chunked_matmul
from repro.moa.registry import (active_strategy, available_strategies,
                                get_strategy_class, moa_scope,
                                register_strategy, registry_stats, resolve)
from repro.moa.strategies import LOAStrategy, SerialStrategy, TreeStrategy

__all__ = [
    "MOAStrategy", "TreeStrategy", "SerialStrategy", "LOAStrategy",
    "BACKENDS", "resolved_backend", "chunked_matmul",
    "register_strategy", "resolve", "available_strategies",
    "get_strategy_class", "moa_scope", "active_strategy", "registry_stats",
]
