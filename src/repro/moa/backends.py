"""Backend execution paths for MOA strategies: jnp reference and Pallas.

Two substrates realize every strategy:

  * **jnp** — pure-jnp reference schedules (explicit binary tree,
    ``lax.scan`` serialization, K-blocked matmul). Differentiable, run
    anywhere, and are the numerical oracles for the kernels.
  * **pallas** — the TPU kernels in :mod:`repro.kernels` (grid-serialized
    accumulators, BlockSpec VMEM tiling). On CPU they execute in interpret
    mode through the auto-detecting wrappers in :mod:`repro.kernels.ops`.
    The float kernels carry a ``jax.custom_vjp`` here whose backward pass
    is the plain matmul/broadcast rule, so strategies stay trainable when
    the forward runs on-device.

Strategies pick a path via ``MOAStrategy.resolve_backend()``; nothing in
this module is strategy-specific.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.layers.numerics import accum_upcast

__all__ = ["tree_sum", "serial_sum", "chunked_matmul",
           "pallas_sum", "pallas_dot"]


# ---------------------------------------------------------------------------
# jnp reference schedules
# ---------------------------------------------------------------------------


def tree_sum(x: jax.Array, accum_dtype) -> jax.Array:
    """Explicit balanced binary adder tree over axis 0.

    Structurally mirrors Fig. 1's adder tree: ``ceil(log2 n)`` levels of
    pairwise adds, odd leftovers passing through. For floats this fixes the
    reassociation order to the hardware tree's order.
    """
    x = x.astype(accum_dtype)
    while x.shape[0] > 1:
        m = x.shape[0]
        half = m // 2
        paired = x[: 2 * half : 2] + x[1 : 2 * half : 2]
        if m % 2:
            paired = jnp.concatenate([paired, x[2 * half :]], axis=0)
        x = paired
    return x[0]


def serial_sum(x: jax.Array, chunk: int, accum_dtype) -> jax.Array:
    """§3.1 serialized MOA: scan over clusters of ``chunk`` operands.

    The carried accumulator lives in ``accum_dtype`` — the TPU analogue of
    the single accumulator in the fast clock domain. Ragged tails are
    zero-padded (padding is exact for addition).
    """
    n = x.shape[0]
    chunk = min(chunk, n)
    n_chunks = -(-n // chunk)
    pad = n_chunks * chunk - n
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    x = accum_upcast(x.reshape((n_chunks, chunk) + x.shape[1:]), accum_dtype)

    def body(acc, block):
        # In-cluster reduction is a tree (the paper's serializer feeds the
        # accumulator one *cluster* at a time); across clusters we serialize.
        return acc + jnp.sum(block, axis=0), None

    init = jnp.zeros(x.shape[2:], accum_dtype)
    acc, _ = lax.scan(body, init, x)
    return acc


def chunked_matmul(a: jax.Array, b: jax.Array, *, chunk: int,
                   accum_dtype=jnp.float32,
                   out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """K-blocked matmul: ``a @ b`` with a serialized-MOA contraction.

    ``a: (..., M, K)``, ``b: (K, N)``. The contraction dimension is processed
    ``chunk`` operands at a time by a ``lax.scan`` carrying an f32
    accumulator — §3.1 realized on hardware whose "serializer" (DMA) and
    "accumulator" (MXU) are hard-wired. Differentiable (scan has a transpose
    rule), so it is usable in training.
    """
    k = a.shape[-1]
    if b.shape[0] != k:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    out_dtype = out_dtype or a.dtype
    chunk = min(chunk, k)
    n_chunks = -(-k // chunk)
    pad = n_chunks * chunk - k
    if pad:
        a = jnp.concatenate([a, jnp.zeros(a.shape[:-1] + (pad,), a.dtype)], axis=-1)
        b = jnp.concatenate([b, jnp.zeros((pad,) + b.shape[1:], b.dtype)], axis=0)
    a_blocks = jnp.moveaxis(
        a.reshape(a.shape[:-1] + (n_chunks, chunk)), -2, 0
    )  # (n_chunks, ..., M, chunk)
    b_blocks = b.reshape((n_chunks, chunk) + b.shape[1:])

    def body(acc, blocks):
        a_blk, b_blk = blocks
        acc = acc + jnp.matmul(
            a_blk, b_blk, preferred_element_type=accum_dtype
        ).astype(accum_dtype)
        return acc, None

    init = jnp.zeros(a_blocks.shape[1:-1] + (b.shape[-1],), accum_dtype)
    acc, _ = lax.scan(body, init, (a_blocks, b_blocks))
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Pallas paths (differentiable wrappers over repro.kernels.ops)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _pallas_dot_fn(block_k: int, approx_bits: int, out_dtype_name: str):
    out_dtype = jnp.dtype(out_dtype_name)

    @jax.custom_vjp
    def f(a, b):
        return ops.dot_moa(a, b, block_k=block_k, approx_bits=approx_bits,
                           out_dtype=out_dtype)

    def fwd(a, b):
        return f(a, b), (a, b)

    def bwd(res, g):
        # The kernel's contraction is exact up to reassociation, so the
        # backward pass is the ordinary matmul transpose rule in f32.
        a, b = res
        gf = g.astype(jnp.float32)
        da = jnp.matmul(gf, b.astype(jnp.float32).T).astype(a.dtype)
        db = jnp.matmul(a.astype(jnp.float32).T, gf).astype(b.dtype)
        return da, db

    f.defvjp(fwd, bwd)
    return f


def pallas_dot(a: jax.Array, b: jax.Array, *, block_k: int,
               out_dtype, approx_bits: int = 0) -> jax.Array:
    """``(m, k) @ (k, n)`` through the ``dot_moa`` Pallas kernel.

    ``block_k`` is the serialization cluster size ``n_c`` (the trailing —
    sequential — grid dimension); strategies choose it and default the
    ``out_dtype`` (via ``MOAStrategy._default_out_dtype``) before calling.
    Float paths are differentiable via a custom VJP; integer paths are
    forward-only.
    """
    out_dtype = jnp.dtype(out_dtype)
    return _pallas_dot_fn(int(block_k), int(approx_bits), out_dtype.name)(a, b)


@functools.lru_cache(maxsize=None)
def _pallas_sum_fn(block_n: int):
    @jax.custom_vjp
    def f(x):
        return ops.moa_reduce(x, block_n=block_n)

    def fwd(x):
        return f(x), (x.shape, jnp.dtype(x.dtype).name)

    def bwd(res, g):
        shape, dtype_name = res
        return (jnp.broadcast_to(g, shape).astype(dtype_name),)

    f.defvjp(fwd, bwd)
    return f


def pallas_sum(x: jax.Array, *, block_n: int) -> jax.Array:
    """``(n, f) -> (f,)`` through the ``moa_reduce`` Pallas kernel.

    The operand axis is grid-serialized in blocks of ``block_n`` (the §3.1
    cluster size); accumulation is f32 for floats, int32 for ints.
    """
    return _pallas_sum_fn(int(block_n))(x)
