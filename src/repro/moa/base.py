"""Abstract MOA strategy — the pluggable core of the paper's design space.

The paper's object of study is the *multi-operand adder*: a reduction node
with hundreds to thousands of operands, and the question of how to schedule
it (spatial tree, §3.1 serialization, §3.2 approximate adders). This module
makes that scheduling axis a first-class API:

  * :class:`MOAStrategy` — abstract base. A strategy knows how to ``sum``
    operands over an axis, how to ``dot`` two matrices (scheduling the
    contraction dimension), and how to ``cost`` itself analytically.
  * Every strategy is a frozen dataclass, so it is hashable, comparable and
    safe to embed in a :class:`repro.configs.base.ModelConfig` or close over
    inside a jitted train step.
  * ``backend`` selects the executing substrate per call site:
    ``"jnp"`` (pure-jnp reference paths), ``"pallas"`` (the TPU kernels in
    :mod:`repro.kernels`, interpret-mode on CPU) or ``"auto"`` (pallas iff
    the default JAX backend is TPU).
  * Each strategy serializes to a canonical *spec string* —
    ``"serial?chunk=512"`` — parsed back by :func:`repro.moa.resolve`; the
    round trip ``resolve(spec).spec == spec`` holds for canonical specs.

Concrete strategies register themselves in :mod:`repro.moa.registry`;
adding a new scheduling strategy (e.g. a two-level tree-of-serial or a
stochastic-rounding accumulator) is one subclass + one
``@register_strategy`` decoration — no cross-cutting edits.
"""

from __future__ import annotations

import abc
import dataclasses
from typing import Any, ClassVar, Dict, Optional

import jax
import jax.numpy as jnp

__all__ = ["MOAStrategy", "BACKENDS", "resolved_backend"]

BACKENDS = ("auto", "jnp", "pallas")


def resolved_backend(backend: str) -> str:
    """Map ``"auto"`` to the substrate the process is actually running on."""
    if backend != "auto":
        return backend
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _format_value(v: Any) -> str:
    return str(v)


@dataclasses.dataclass(frozen=True)
class MOAStrategy(abc.ABC):
    """How a large-fan-in reduction is scheduled, and on what substrate.

    Attributes:
      backend: ``"auto"`` | ``"jnp"`` | ``"pallas"``. ``auto`` resolves to
        the Pallas kernels on TPU and the jnp reference paths elsewhere.
    """

    backend: str = "auto"

    #: registry key; set by each concrete subclass
    name: ClassVar[str] = ""
    #: True for strategies whose arithmetic is defined on integers only
    integer_only: ClassVar[bool] = False

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of {BACKENDS}")

    # ---- spec-string round trip -------------------------------------------
    @property
    def spec(self) -> str:
        """Canonical spec string: ``name`` + sorted non-default params.

        ``resolve(s.spec) == s`` for every strategy ``s``; conversely
        ``resolve(spec).spec == spec`` whenever ``spec`` is canonical
        (params alphabetical, defaults omitted).
        """
        params = sorted(
            f"{f.name}={_format_value(getattr(self, f.name))}"
            for f in dataclasses.fields(self)
            if getattr(self, f.name) != f.default
        )
        return self.name + ("?" + "&".join(params) if params else "")

    def __str__(self) -> str:
        return self.spec

    # ---- backend / dtype plumbing -----------------------------------------
    def resolve_backend(self) -> str:
        return resolved_backend(self.backend)

    def accum_dtype_for(self, operand_dtype) -> jnp.dtype:
        """Accumulator dtype: int32 for integer operands, else ``accum``.

        Mirrors the hardware: the MXU hard-wires f32 accumulation for float
        operands and int32 for int8 — a strategy's ``accum`` field only
        chooses among float precisions.
        """
        if jnp.issubdtype(jnp.dtype(operand_dtype), jnp.integer):
            return jnp.dtype(jnp.int32)
        return jnp.dtype(getattr(self, "accum", "float32"))

    def replace(self, **updates) -> "MOAStrategy":
        return dataclasses.replace(self, **updates)

    def _check_operands(self, dtype) -> None:
        if self.integer_only and not jnp.issubdtype(jnp.dtype(dtype),
                                                    jnp.integer):
            raise TypeError(
                f"{self.name!r} strategy requires integer operands, got "
                f"{jnp.dtype(dtype).name}")

    @classmethod
    def bench_specs(cls) -> tuple:
        """Representative spec strings for registry-driven benchmark sweeps.

        Benchmarks enumerate ``available_strategies()`` and call this per
        class, so a newly registered strategy appears in the sweeps without
        editing any benchmark. Default: the bare name.
        """
        return (cls.name,)

    # ---- the strategy interface -------------------------------------------
    @abc.abstractmethod
    def sum(self, x, *, axis: int = -1) -> jax.Array:
        """Reduce ``x`` over ``axis``; returns the accumulator dtype."""

    @abc.abstractmethod
    def dot(self, a, b, *, out_dtype: Optional[Any] = None) -> jax.Array:
        """``a @ b`` with the K contraction scheduled per this strategy.

        ``a: (..., M, K)`` (leading batch dims allowed), ``b: (K, N)``.
        ``out_dtype`` defaults to ``a.dtype`` for floats and int32 for
        integer operands (an int8 output would silently wrap).
        """

    @abc.abstractmethod
    def cost(self, n_operands: int, dtype: Any = "bfloat16") -> Dict[str, Any]:
        """Analytic cost of one ``n_operands``-wide reduction.

        Returns a :class:`repro.launch.costing.CellCost`-compatible dict:

          flops                 per output element (mults + scheduled adds)
          hbm_bytes             operand bytes streamed per output element
          adds                  two-operand additions per output
          ops_per_add           hardware ops each add costs (LOA: ~6 on VPU)
          sequential_steps      scan/grid trip count (tree: 1)
          working_set_operands  live operands per sequential step
          exact                 True when the reduction is exact up to
                                reassociation
        """

    # ---- shared jnp/pallas shape plumbing ---------------------------------
    @staticmethod
    def _flatten_dot(a: jax.Array):
        """``(..., M, K) -> (rows, K)`` + a restorer for the output."""
        lead = a.shape[:-1]
        a2 = a.reshape((-1, a.shape[-1]))
        return a2, (lambda y: y.reshape(lead + (y.shape[-1],)))

    @staticmethod
    def _flatten_sum(x: jax.Array, axis: int):
        """``x`` with ``axis`` moved to front and trailing dims flattened to
        ``(n, f)``; returns the 2-D view + a restorer for the reduced output."""
        x = jnp.moveaxis(jnp.asarray(x), axis, 0)
        rest = x.shape[1:]
        x2 = x.reshape((x.shape[0], -1))
        return x2, (lambda y: y.reshape(rest))

    @staticmethod
    def _default_out_dtype(a_dtype, out_dtype):
        if out_dtype is not None:
            return jnp.dtype(out_dtype)
        if jnp.issubdtype(jnp.dtype(a_dtype), jnp.integer):
            return jnp.dtype(jnp.int32)
        return jnp.dtype(a_dtype)
