"""Strategy registry, spec-string parsing, and scoped overrides.

Spec-string grammar (URL-query style)::

    spec      := name [ "?" param ( "&" param )* ]
    param     := key "=" value
    name      := a registered strategy name    ("tree" | "serial" | "loa" | ...)
    key       := a dataclass field of that strategy
    value     := int | dtype name | backend name (coerced per field)

Examples: ``"tree"``, ``"serial?chunk=512"``,
``"loa?approx_bits=4&width=12"``, ``"serial?backend=pallas&chunk=256"``.

Canonical form sorts params alphabetically and omits defaults —
``resolve(spec).spec == spec`` holds for canonical specs and
``resolve(s.spec) == s`` for every strategy instance ``s``.

``resolve`` also accepts :class:`~repro.moa.base.MOAStrategy` instances
(returned as-is) and legacy :class:`repro.core.moa.ReductionStrategy`
objects (converted field-for-field, including the LOA operand ``width``
that the old flat-config path used to drop).

:func:`moa_scope` pushes an ambient strategy override consulted by
:func:`active_strategy` — every call site that routes through
``repro.layers.linear.project`` / ``repro.models.cnn.im2col_conv`` honours
it, so benchmarks and the Fig. 4/5 scripts can sweep the registry without
rebuilding configs. The override applies at *trace* time: wrap the trace
(or run unjitted), not a cached jitted callable.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Type, Union

from repro.moa.base import MOAStrategy

__all__ = [
    "register_strategy", "resolve", "available_strategies",
    "get_strategy_class", "moa_scope", "active_strategy", "registry_stats",
]

_REGISTRY: Dict[str, Type[MOAStrategy]] = {}
_PARSE_CACHE: Dict[str, MOAStrategy] = {}
_SCOPE: List[MOAStrategy] = []
# observability: lets tests assert the model stack actually routes through
# the registry (and benchmarks report scope usage)
_STATS = {"resolve_calls": 0, "scope_hits": 0}


def register_strategy(cls: Type[MOAStrategy]) -> Type[MOAStrategy]:
    """Class decorator: register ``cls`` under ``cls.name``.

    Re-registration under an existing name replaces the entry (latest wins),
    so experiments can shadow a built-in.
    """
    name = cls.name
    if not name:
        raise ValueError(f"{cls.__name__} must set a non-empty `name`")
    _REGISTRY[name] = cls
    _PARSE_CACHE.clear()
    return cls


def available_strategies() -> List[str]:
    return sorted(_REGISTRY)


def get_strategy_class(name: str) -> Type[MOAStrategy]:
    if name not in _REGISTRY:
        raise ValueError(f"unknown MOA strategy {name!r}; "
                         f"available: {available_strategies()}")
    return _REGISTRY[name]


def _coerce(cls: Type[MOAStrategy], key: str, value: str):
    fields = {f.name: f for f in dataclasses.fields(cls)}
    if key not in fields:
        raise ValueError(
            f"strategy {cls.name!r} has no parameter {key!r}; "
            f"expected one of {sorted(fields)}")
    default = fields[key].default
    caster = type(default) if default is not dataclasses.MISSING else str
    try:
        return caster(value)
    except (TypeError, ValueError) as e:
        raise ValueError(f"bad value {value!r} for {cls.name}.{key}") from e


def _parse(spec: str) -> MOAStrategy:
    if spec in _PARSE_CACHE:
        return _PARSE_CACHE[spec]
    name, _, query = spec.partition("?")
    cls = get_strategy_class(name.strip())
    kwargs = {}
    if query:
        for item in query.split("&"):
            key, sep, value = item.partition("=")
            if not sep:
                raise ValueError(f"malformed spec param {item!r} in {spec!r}")
            kwargs[key.strip()] = _coerce(cls, key.strip(), value.strip())
    strategy = cls(**kwargs)
    _PARSE_CACHE[spec] = strategy
    return strategy


def _from_legacy(rs) -> MOAStrategy:
    """Convert a repro.core.moa.ReductionStrategy (duck-typed on .kind)."""
    import jax.numpy as jnp

    accum = jnp.dtype(rs.accum_dtype).name
    if rs.kind == "tree":
        return _REGISTRY["tree"](accum=accum)
    if rs.kind == "serial":
        return _REGISTRY["serial"](chunk=rs.chunk, accum=accum)
    if rs.kind == "loa":
        return _REGISTRY["loa"](approx_bits=rs.approx_bits, width=rs.width)
    raise ValueError(f"unknown legacy strategy kind {rs.kind!r}")


def resolve(spec: Union[str, MOAStrategy]) -> MOAStrategy:
    """Spec string | MOAStrategy | legacy ReductionStrategy → MOAStrategy."""
    _STATS["resolve_calls"] += 1
    if isinstance(spec, MOAStrategy):
        return spec
    if isinstance(spec, str):
        return _parse(spec)
    if hasattr(spec, "kind"):  # legacy ReductionStrategy (avoids an import)
        return _from_legacy(spec)
    raise TypeError(f"cannot resolve MOA strategy from {type(spec).__name__}")


@contextlib.contextmanager
def moa_scope(strategy: Union[str, MOAStrategy]):
    """Ambient strategy override for scoped experiments.

    Inside the scope, every MOA-routed call site (``project``, attention
    projections, ``im2col_conv``, ...) uses ``strategy`` regardless of its
    configured one::

        with moa_scope("serial?chunk=256&backend=pallas"):
            loss = model.loss(params, batch)   # traced under the override

    Scopes nest; the innermost wins. Trace-time semantics: a function jitted
    *outside* the scope keeps its original strategies.
    """
    strat = resolve(strategy)
    _SCOPE.append(strat)
    try:
        yield strat
    finally:
        _SCOPE.pop()


def active_strategy(
        default: Optional[Union[str, MOAStrategy]] = None,
) -> Optional[MOAStrategy]:
    """The ambient scoped strategy, else ``resolve(default)``, else None."""
    if _SCOPE:
        _STATS["scope_hits"] += 1
        return _SCOPE[-1]
    if default is None:
        return None
    return resolve(default)


def registry_stats() -> Dict[str, int]:
    """Snapshot of resolution counters (observability for tests/benches)."""
    return dict(_STATS)
