"""Concrete MOA strategies: tree (§2), serial (§3.1), LOA (§3.2).

Each strategy is a ~50-line frozen dataclass implementing the three-method
:class:`repro.moa.base.MOAStrategy` interface and registering itself by
name. The jnp paths are the reference schedules (differentiable oracles);
the pallas paths route to :mod:`repro.kernels` (grid-serialized
accumulators on TPU, interpret mode on CPU).

Cost semantics follow the paper's TPU inversion: scheduling is *free*
(tree and serial have identical op counts — the serializer is the
hard-wired DMA path) while §3.2 approximation *costs* (~6 VPU ops per LOA
fold where the exact add is one hard-wired op). ``cost`` exposes exactly
that, so :mod:`repro.launch.costing` can price a model under any strategy
without assuming a one-shot matmul.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, ClassVar, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.core import loa as loa_lib
from repro.moa import backends
from repro.moa.base import MOAStrategy
from repro.moa.registry import register_strategy

__all__ = ["TreeStrategy", "SerialStrategy", "LOAStrategy"]

# VMEM-safe ceilings for the Pallas grid blocks. Interpret mode (CPU) has
# no memory limit, but on TPU a (block_m x block_k) + (block_k x block_n)
# f32 tile must fit VMEM (~16 MiB/core): 2048 x 256 x 4 B x 2 ≈ 4 MiB.
# "One-shot" strategies (tree) therefore still tile wide contractions —
# the in-block reduction is the spatial tree, grid accumulation stays
# exact f32.
_PALLAS_MAX_BLOCK_K = 2048
_PALLAS_MAX_BLOCK_N = 4096


def _pallas_block(requested: int, cap: int) -> int:
    return max(min(requested, cap), 1)


def _cost_dict(*, n: int, dtype, ops_per_add: float, sequential_steps: int,
               working_set_operands: int, exact: bool) -> Dict[str, Any]:
    adds = max(n - 1, 0)
    itemsize = jnp.dtype(dtype).itemsize
    return {
        "flops": n + adds * ops_per_add,       # per output: mults + adds
        "hbm_bytes": n * itemsize,             # operands streamed once
        "adds": adds,
        "ops_per_add": ops_per_add,
        "sequential_steps": sequential_steps,
        "working_set_operands": working_set_operands,
        "exact": exact,
    }


@register_strategy
@dataclasses.dataclass(frozen=True)
class TreeStrategy(MOAStrategy):
    """Spatial binary adder tree — the synthesis-tool default (§2).

    On TPU this is the one-shot reduction: XLA/the MXU emit the hard adder
    tree, materializing all partial products (maximal working set, minimal
    sequentialization). ``accum`` picks the float accumulator precision.
    """

    accum: str = "float32"

    name: ClassVar[str] = "tree"

    @classmethod
    def bench_specs(cls) -> tuple:
        return ("tree", "tree?backend=pallas")

    def sum(self, x, *, axis: int = -1) -> jax.Array:
        x2, restore = self._flatten_sum(x, axis)
        if self.resolve_backend() == "pallas":
            # widest VMEM-feasible block: the in-block tree is the spatial
            # reduction, any residual grid accumulation is exact f32
            return restore(backends.pallas_sum(
                x2, block_n=_pallas_block(x2.shape[0], _PALLAS_MAX_BLOCK_N)))
        return restore(backends.tree_sum(x2, self.accum_dtype_for(x.dtype)))

    def dot(self, a, b, *, out_dtype: Optional[Any] = None) -> jax.Array:
        out_dtype = self._default_out_dtype(a.dtype, out_dtype)
        accum = self.accum_dtype_for(a.dtype)
        if self.resolve_backend() == "pallas":
            a2, restore = self._flatten_dot(a)
            return restore(backends.pallas_dot(
                a2, b,
                block_k=_pallas_block(a2.shape[-1], _PALLAS_MAX_BLOCK_K),
                out_dtype=out_dtype))
        return jnp.matmul(a, b, preferred_element_type=accum).astype(out_dtype)

    def cost(self, n_operands: int, dtype: Any = "bfloat16") -> Dict[str, Any]:
        return dict(
            _cost_dict(n=n_operands, dtype=dtype, ops_per_add=1.0,
                       sequential_steps=1, working_set_operands=n_operands,
                       exact=True),
            depth=max(math.ceil(math.log2(max(n_operands, 1))), 1),
        )


@register_strategy
@dataclasses.dataclass(frozen=True)
class SerialStrategy(MOAStrategy):
    """§3.1 serialized MOA: clusters of ``chunk`` operands fold into one
    accumulator.

    On FPGA the serializer cost buried the savings (the paper's negative
    result); on TPU the serializer is the hard-wired DMA/address path, so
    this is the *native* idiom — ``chunk`` plays the paper's ``n_c`` and
    bounds the live working set. With ``chunk >= K`` the jnp path lowers to
    a single MXU matmul (zero overhead).
    """

    chunk: int = 512
    accum: str = "float32"

    name: ClassVar[str] = "serial"

    @classmethod
    def bench_specs(cls) -> tuple:
        return ("serial?chunk=1024", "serial?chunk=256",
                "serial?backend=pallas&chunk=512")

    def __post_init__(self):
        super().__post_init__()
        if self.chunk < 1:
            raise ValueError("chunk must be >= 1")

    def sum(self, x, *, axis: int = -1) -> jax.Array:
        x2, restore = self._flatten_sum(x, axis)
        if self.resolve_backend() == "pallas":
            return restore(backends.pallas_sum(
                x2, block_n=_pallas_block(self.chunk, _PALLAS_MAX_BLOCK_N)))
        return restore(backends.serial_sum(x2, self.chunk,
                                           self.accum_dtype_for(x.dtype)))

    def dot(self, a, b, *, out_dtype: Optional[Any] = None) -> jax.Array:
        out_dtype = self._default_out_dtype(a.dtype, out_dtype)
        accum = self.accum_dtype_for(a.dtype)
        k = a.shape[-1]
        if self.resolve_backend() == "pallas":
            a2, restore = self._flatten_dot(a)
            return restore(backends.pallas_dot(
                a2, b,
                block_k=_pallas_block(self.chunk, _PALLAS_MAX_BLOCK_K),
                out_dtype=out_dtype))
        if k <= self.chunk:
            return jnp.matmul(
                a, b, preferred_element_type=accum).astype(out_dtype)
        return backends.chunked_matmul(
            a, b, chunk=self.chunk, accum_dtype=accum, out_dtype=out_dtype)

    def cost(self, n_operands: int, dtype: Any = "bfloat16") -> Dict[str, Any]:
        steps = max(-(-n_operands // self.chunk), 1)
        return _cost_dict(
            n=n_operands, dtype=dtype, ops_per_add=1.0,
            sequential_steps=steps,
            working_set_operands=min(self.chunk, n_operands), exact=True)


@register_strategy
@dataclasses.dataclass(frozen=True)
class LOAStrategy(MOAStrategy):
    """§3.2 approximate MOA: Lower-part-OR adders, integer operands only.

    ``approx_bits`` is the paper's ``l`` (low bits OR-approximated),
    ``width`` the operand bit-width ``b`` — both thread end-to-end through
    the spec string (``"loa?approx_bits=4&width=12"``). Backends differ in
    *where* the approximation sits, mirroring the two hardware structures:

      * jnp — a balanced binary tree in which **every** adder is an LOA
        (:func:`repro.core.loa.loa_sum`; Fig. 1 with Fig. 3 cells);
      * pallas — the serialized composition: operand clusters of ``chunk``
        are tree-reduced *exactly*, and each cluster partial folds into the
        running accumulator through one LOA (§3.1 + §3.2 combined).

    Both are exact (and agree bitwise) at ``approx_bits=0``.
    """

    approx_bits: int = 4
    width: int = 8
    chunk: int = 256

    name: ClassVar[str] = "loa"
    integer_only: ClassVar[bool] = True

    @classmethod
    def bench_specs(cls) -> tuple:
        return ("loa?approx_bits=0", "loa?approx_bits=4")

    def __post_init__(self):
        super().__post_init__()
        if not 0 <= self.approx_bits <= self.width:
            raise ValueError(
                f"approx_bits={self.approx_bits} outside [0, width={self.width}]")
        if self.chunk < 1:
            raise ValueError("chunk must be >= 1")

    def _fold_block(self, n: int) -> int:
        """Cluster size for the pallas kernels: LOA accumulator chains are
        not exact under zero padding, so fall back to one cluster when the
        operand count is ragged."""
        return self.chunk if n % self.chunk == 0 else n

    def sum(self, x, *, axis: int = -1) -> jax.Array:
        self._check_operands(jnp.asarray(x).dtype)
        if self.resolve_backend() == "pallas":
            x2, restore = self._flatten_sum(x, axis)
            from repro.kernels import ops
            return restore(ops.loa_reduce(
                x2, approx_bits=self.approx_bits, width=self.width,
                block_n=self._fold_block(x2.shape[0])))
        return loa_lib.loa_sum(jnp.asarray(x), approx_bits=self.approx_bits,
                               width=self.width, axis=axis)

    def dot(self, a, b, *, out_dtype: Optional[Any] = None) -> jax.Array:
        self._check_operands(a.dtype)
        self._check_operands(b.dtype)
        out_dtype = self._default_out_dtype(a.dtype, out_dtype)
        if self.resolve_backend() == "pallas":
            a2, restore = self._flatten_dot(a)
            return restore(backends.pallas_dot(
                a2, b, block_k=self._fold_block(a2.shape[-1]),
                approx_bits=self.approx_bits, out_dtype=out_dtype))
        # Partial products (…, M, K, N) reduced over K through the LOA tree.
        partials = a[..., None].astype(jnp.int32) * b.astype(jnp.int32)
        return loa_lib.loa_sum(
            partials, approx_bits=self.approx_bits, width=self.width,
            axis=-2).astype(out_dtype)

    def cost(self, n_operands: int, dtype: Any = "int8") -> Dict[str, Any]:
        ops_per_add = (float(cost_model.vpu_ops_loa_add())
                       if self.approx_bits else 1.0)
        steps = max(-(-n_operands // self.chunk), 1)
        return dict(
            _cost_dict(n=n_operands, dtype=dtype, ops_per_add=ops_per_add,
                       sequential_steps=steps,
                       working_set_operands=min(self.chunk, n_operands),
                       exact=self.approx_bits == 0),
            # FPGA foil: ALM count is *flat* in approx_bits (Fig. 5 bottom)
            alms=cost_model.alm_loa_adder(self.width, self.approx_bits),
            error_bound_per_add=loa_lib.loa_error_bound(self.approx_bits),
        )
