from repro.data.pipeline import SyntheticLMData, host_shard

__all__ = ["SyntheticLMData", "host_shard"]
