"""Deterministic, restart-safe synthetic data pipeline.

Design constraints (the ones a real multi-pod pipeline must satisfy):

  * **Stateless indexing** — ``batch_for_step(step)`` is a pure function of
    ``(seed, step)``, so a restarted job resumes mid-epoch with zero drift
    and no iterator state in the checkpoint.
  * **Host sharding** — each host materializes only its slice of the global
    batch (``host_shard``); the global batch is the concatenation across
    hosts in host-id order.
  * **Learnability** — tokens follow a noisy affine bigram process
    (``next = (a·prev + c) mod V`` with probability ``1-noise``), so a ~1M
    parameter model demonstrably reduces loss within tens of steps — used
    by the integration tests and the quickstart example.

Everything is jittable ``jax.random`` (threefry counter-mode): no files, no
state, reproducible across process boundaries.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

__all__ = ["SyntheticLMData", "host_shard"]


@dataclasses.dataclass(frozen=True)
class SyntheticLMData:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    family: str = "dense"      # encoder family gets frames/mask/targets
    d_model: int = 0           # encoder/vlm stub embedding dim
    n_patches: int = 0         # vlm prefix

    def _bigram_next(self, prev):
        a = 2 * (self.seed % 1000) + 1  # odd multiplier → full-period affine map
        c = (self.seed * 7919 + 13) % self.vocab
        return (prev * a + c) % self.vocab

    def batch_for_step(self, step: int) -> Dict[str, jax.Array]:
        """Global batch for ``step`` (pure function)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        if self.family == "encoder":
            kf, km, kt = jax.random.split(key, 3)
            frames = 0.02 * jax.random.normal(
                kf, (self.global_batch, self.seq_len, self.d_model))
            mask = jax.random.bernoulli(km, 0.35,
                                        (self.global_batch, self.seq_len))
            targets = jax.random.randint(
                kt, (self.global_batch, self.seq_len), 0, self.vocab,
                jnp.int32)
            return {"frames": frames, "mask": mask, "targets": targets}

        k0, kn, ku, kp = jax.random.split(key, 4)
        s_text = self.seq_len - self.n_patches
        first = jax.random.randint(k0, (self.global_batch, 1), 0, self.vocab,
                                   jnp.int32)

        def step_fn(prev, noise_key):
            clean = self._bigram_next(prev)
            kz, ku2 = jax.random.split(noise_key)
            rand = jax.random.randint(ku2, prev.shape, 0, self.vocab,
                                      jnp.int32)
            use_noise = jax.random.bernoulli(kz, self.noise, prev.shape)
            nxt = jnp.where(use_noise, rand, clean)
            return nxt, nxt

        # one extra token so labels are a clean shift
        noise_keys = jax.random.split(kn, s_text)
        _, rest = jax.lax.scan(step_fn, first[:, 0], noise_keys)
        tokens_ext = jnp.concatenate([first, rest.T], axis=1)  # (B, s_text+1)
        batch = {
            "tokens": tokens_ext[:, :-1],
            "labels": tokens_ext[:, 1:],
        }
        if self.n_patches:
            batch["patches"] = 0.02 * jax.random.normal(
                kp, (self.global_batch, self.n_patches, self.d_model))
        return batch

    def iterate(self, start_step: int = 0):
        step = start_step
        while True:
            yield self.batch_for_step(step)
            step += 1


def host_shard(batch: Dict[str, jax.Array], host_id: int,
               n_hosts: int) -> Dict[str, jax.Array]:
    """This host's contiguous slice of the global batch (batch-dim split)."""
    def slice_leaf(a):
        b = a.shape[0]
        assert b % n_hosts == 0, (b, n_hosts)
        per = b // n_hosts
        return a[host_id * per:(host_id + 1) * per]

    return jax.tree.map(slice_leaf, batch)
