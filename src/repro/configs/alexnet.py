"""AlexNet conv config — the paper's own Table-1 subject (not an LM arch).

Used by the DHM benchmarks and the CNN smoke test; layout lives in
``repro.models.cnn.ALEXNET_LAYOUT`` and the MOA census in
``repro.core.dhm.ALEXNET_CONV_SPECS``.
"""

from repro.core.dhm import ALEXNET_CONV_SPECS, ALEXNET_PAPER_NOPD
from repro.models.cnn import ALEXNET_LAYOUT, alexnet_forward, init_alexnet

NAME = "alexnet"
INPUT_SHAPE = (227, 227, 3)
