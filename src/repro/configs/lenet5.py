"""LeNet-5 conv config — the paper's ×8.6 SCM-optimization subject ([1])."""

from repro.core.dhm import LENET5_CONV_SPECS
from repro.models.cnn import LENET5_LAYOUT, init_lenet5, lenet5_forward

NAME = "lenet5"
INPUT_SHAPE = (32, 32, 1)
