"""mamba2-370m [ssm] — 48L d_model=1024 (attention-free) ssm_state=128,
vocab=50280, SSD (state-space duality). [arXiv:2405.21060]

The paper's technique applies to the SSD scan itself: ``ssd_chunk`` is the
serialized-MOA cluster size (intra-chunk MXU tree / inter-chunk serial
accumulator) — see docs/moa-strategies.md.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    vocab=50280,
    d_state=128,
    headdim=64,
    n_groups=1,
    expand=2,          # d_inner = 2048 → 32 ssm heads
    tie_embeddings=True,
)
