"""zamba2-1.2b [hybrid] — 38 Mamba2 layers, d_model=2048, ssm_state=64,
shared attention block (32H kv=32, head_dim 64) + shared d_ff=8192 MLP
applied every 6 mamba layers, vocab=32000. [arXiv:2411.15242; hf-verified]

Runs long_500k: SSM state is O(1) in sequence; only the shared block's
(periodic) KV caches scale with context.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=32000,
    d_state=64,
    headdim=64,
    n_groups=1,
    expand=2,          # d_inner = 4096 → 64 ssm heads
    attn_every=6,      # 6 shared-block applications + 2 tail mamba layers
    rope_theta=1e4,
)
