"""moonshot-v1-16b-a3b [moe] — 48L d_model=2048 16H (kv=16) expert
d_ff=1408, vocab=163840, MoE 64 experts top-6 (kimi/moonlight style).
[hf:moonshotai/Moonlight-16B-A3B; hf-verified]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    n_experts=64,
    top_k=6,
    rope_theta=5e5,
)
