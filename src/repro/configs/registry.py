"""Architecture registry: ``--arch <id>`` lookup + reduced smoke configs."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs import (hubert_xlarge, llama3_8b, llama3_405b,
                           llama4_maverick_400b_a17b, llava_next_34b,
                           mamba2_370m, moonshot_v1_16b_a3b, qwen1_5_32b,
                           yi_34b, zamba2_1_2b)
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, shape_applicable

__all__ = ["ARCHS", "get_config", "list_archs", "smoke_config",
           "valid_cells", "SHAPES"]

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        qwen1_5_32b.CONFIG,
        yi_34b.CONFIG,
        llama3_8b.CONFIG,
        llama3_405b.CONFIG,
        llava_next_34b.CONFIG,
        zamba2_1_2b.CONFIG,
        hubert_xlarge.CONFIG,
        mamba2_370m.CONFIG,
        llama4_maverick_400b_a17b.CONFIG,
        moonshot_v1_16b_a3b.CONFIG,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> List[str]:
    return sorted(ARCHS)


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests.

    Preserves the structural features (GQA ratio, MoE routing arity, hybrid
    grouping, biases, tying) while shrinking every dimension.
    """
    kv_ratio = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    n_heads = 4
    updates = dict(
        n_layers=3 if cfg.family == "hybrid" else 2,
        d_model=64,
        n_heads=n_heads if cfg.n_heads else 0,
        n_kv_heads=(max(n_heads // kv_ratio, 1) if cfg.n_kv_heads else 0),
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab=257,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        # dropless at smoke scale: capacity couples tokens across phases,
        # which would make prefill/decode parity checks ill-defined
        capacity_factor=8.0 if cfg.n_experts else cfg.capacity_factor,
        d_state=16 if cfg.d_state else 0,
        headdim=16 if cfg.d_state else 64,
        expand=cfg.expand,
        attn_every=1 if cfg.attn_every else 0,
        n_patches=8 if cfg.n_patches else 0,
        q_chunk=16,
        kv_chunk=16,
        ssd_chunk=8,
        moa="serial?chunk=32",
        remat="none",
        max_position=2048,
        name=cfg.name + "-smoke",
    )
    return dataclasses.replace(cfg, **updates)


def valid_cells():
    """All (arch, shape) cells after the assignment skip rules."""
    cells = []
    for arch, cfg in sorted(ARCHS.items()):
        for sname, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            cells.append((arch, sname, ok, why))
    return cells
