from repro.configs.base import (SHAPES, ModelConfig, ShapeSpec,
                                shape_applicable)

__all__ = ["SHAPES", "ModelConfig", "ShapeSpec", "shape_applicable"]
