"""llava-next-34b [vlm] — yi-34b backbone (60L d_model=7168 56H GQA kv=8
d_ff=20480 vocab=64000) with anyres patch tiling.
[hf:llava-hf/llava-v1.6 family]

Per the assignment the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings at d_model (anyres tiling happens
upstream of this framework); the backbone + mm-projector are real.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    rope_theta=5e6,
    n_patches=2304,   # anyres high-res tiling budget (stubbed frontend)
)
