"""hubert-xlarge [audio] — 48L d_model=1280 16H d_ff=5120 vocab=504
(masked-unit prediction targets), encoder-only. [arXiv:2106.07447]

Per the assignment: the conv waveform frontend is a STUB — inputs are
precomputed frame embeddings at d_model. Encoder-only ⇒ decode shapes are
skipped (no autoregressive step exists).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
)
