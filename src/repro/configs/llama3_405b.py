"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. [arXiv:2407.21783]

The d_ff=53248 down-projection is the widest dense MOA in the assignment
(53 248 operands per output element) — the natural subject for the paper's
serialized-reduction strategy (``moa_chunk``).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab=128256,
    rope_theta=5e5,
)
