"""Model/shape configuration schema shared by all architectures."""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Tuple, Union

import jax.numpy as jnp

from repro.moa import MOAStrategy, resolve

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "MOA_SITES",
           "shape_applicable"]

#: call sites that consult a per-site MOA override in ``moa_overrides``
#: (attention q/k/v/out projections; dense-MLP up/down; MoE router/experts/
#: combine). Grows as more call sites gain strategy routing — validation
#: rejects sites nothing would read.
MOA_SITES = ("attention", "mlp", "moe")

#: ``moa`` / ``moa_overrides`` values: a spec string or a strategy instance
MOASpec = Union[str, MOAStrategy]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 5e5
    attn_impl: str = "flash"    # flash | full
    q_chunk: int = 256
    kv_chunk: int = 512
    # mlp
    d_ff: int = 0
    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2 / zamba2)
    d_state: int = 0
    headdim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    expand: int = 2
    ssd_chunk: int = 256
    # hybrid: one shared attention+MLP block applied every `attn_every`
    # mamba layers (zamba2-style shared block)
    attn_every: int = 0
    # vlm
    n_patches: int = 0          # patch-embedding prefix length (stub frontend)
    # embeddings
    tie_embeddings: bool = False
    max_position: int = 1 << 20
    # MOA strategy (the paper's knob): a repro.moa spec string (e.g.
    # "serial?chunk=4096", "tree", "loa?approx_bits=4&width=8") or an
    # MOAStrategy instance, plus optional per-site overrides keyed by
    # MOA_SITES (e.g. moa_overrides={"attention": "tree", "mlp": ...}).
    # Overrides may be given as a dict; they are normalized to a sorted
    # tuple of (site, spec) pairs so the config stays hashable.
    moa: MOASpec = "serial?chunk=4096"
    moa_overrides: Tuple[Tuple[str, MOASpec], ...] = ()
    # serving
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (quantized cache)
    # paged-attention backend on the serve hot path: "jnp" streams the
    # gathered dense KV view (reference), "pallas" runs the fused
    # block-table flash kernels, "auto" resolves to pallas on TPU and jnp
    # elsewhere (layers/attention.py:resolve_attn_backend)
    attn_backend: str = "auto"
    # context-parallel attention (Ulysses-style): attention computed over
    # model-axis-sharded sequence instead of sharded heads — swaps the
    # attn-out all-reduce for a cheap layout all-to-all (§Perf lever)
    attn_cp: bool = False
    # training / lowering
    remat: str = "full"         # none | dots | full
    loss_impl: str = "vocab_parallel"   # vocab_parallel | gather
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        overrides = self.moa_overrides
        if isinstance(overrides, Mapping):
            overrides = tuple(sorted(overrides.items()))
            object.__setattr__(self, "moa_overrides", overrides)
        for site, spec in overrides:
            if site not in MOA_SITES:
                raise ValueError(f"unknown MOA site {site!r}; "
                                 f"expected one of {MOA_SITES}")
            resolve(spec)   # validate eagerly — typos fail at config time
        resolve(self.moa)
        if self.attn_backend not in ("auto", "jnp", "pallas"):
            raise ValueError(f"unknown attn_backend {self.attn_backend!r}; "
                             "expected 'auto', 'jnp' or 'pallas'")

    # ---- derived ----------------------------------------------------------
    @property
    def moa_strategy(self) -> MOAStrategy:
        """The model-wide default strategy (``moa_for`` adds per-site)."""
        return resolve(self.moa)

    def moa_for(self, site: str) -> MOAStrategy:
        """Strategy for a call site, honouring ``moa_overrides``."""
        for key, spec in self.moa_overrides:
            if key == site:
                return resolve(spec)
        return resolve(self.moa)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_causal(self) -> bool:
        return self.family != "encoder"

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6·N·D."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = 0
        mlp = 0
        ssm = 0
        moe = 0
        if self.family in ("dense", "encoder", "vlm", "moe"):
            hd = self.n_heads * self.head_dim
            kvd = self.n_kv_heads * self.head_dim
            attn = d * (hd + 2 * kvd) + hd * d
        if self.family in ("dense", "encoder", "vlm"):
            mlp = 3 * d * self.d_ff if self.family != "encoder" else 2 * d * self.d_ff
        if self.family == "moe":
            moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            proj_in = d * (2 * di + 2 * self.n_groups * self.d_state
                           + self.n_ssm_heads)
            ssm = proj_in + di * d + self.d_conv * (
                di + 2 * self.n_groups * self.d_state)
        if self.family == "hybrid":
            # shared attention + MLP block (counted once)
            hd = self.n_heads * self.head_dim
            kvd = self.n_kv_heads * self.head_dim
            shared = d * (hd + 2 * kvd) + hd * d + 3 * d * self.d_ff
            return emb + L * ssm + shared
        return emb + L * (attn + mlp + ssm + moe)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        hd = self.n_heads * self.head_dim
        kvd = self.n_kv_heads * self.head_dim
        attn = d * (hd + 2 * kvd) + hd * d
        active_moe = self.top_k * 3 * d * self.d_ff + d * self.n_experts
        return emb + L * (attn + active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    phase: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment skip rules (see docs/architecture.md skip rules)."""
    if cfg.family == "encoder" and shape.phase == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("pure full-attention arch: O(S^2) at 524k infeasible; "
                       "run only for SSM/hybrid per assignment")
    return True, ""
