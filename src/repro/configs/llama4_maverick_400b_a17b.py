"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
expert d_ff=8192, vocab=202048, MoE 128 experts top-1, early fusion.
[hf:meta-llama/Llama-4 family]

The 202k vocab makes the logits softmax the largest *distributed* MOA in
the assignment — the vocab-parallel CE path (losses.py) is load-bearing.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    rope_theta=5e5,
)
