"""Intentionally-broken audit targets: every rule's proof of life.

A static gate that never fires is indistinguishable from one that is
wired up wrong, so each auditor rule has a minimal fixture here that MUST
produce exactly that violation (enforced by ``tests/test_analysis.py``).
Keep these in sync with :data:`repro.analysis.report.RULES`.

The jaxpr fixtures live in this file on purpose: their tracebacks resolve
to ``src/repro/analysis/fixtures.py``, which is *not* on the f32-upcast
allowlist, so the upcast fixture exercises the real site-attribution
path.
"""

from __future__ import annotations

import textwrap
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.jaxpr_audit import AuditTarget

__all__ = ["JAXPR_FIXTURES", "LINT_FIXTURES", "CLEAN_LINT_FIXTURES",
           "COST_FIXTURES", "unbounded_while", "drifting_cost"]

_BF16_44 = jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)
_KV_SHAPE = (2, 32, 2, 16)
_KV_SDS = jax.ShapeDtypeStruct(_KV_SHAPE, jnp.bfloat16)
_KV_EXPECTED = ("data", None, "model", None)


def bad_host_transfer() -> AuditTarget:
    """device_put inside a jitted path → no-host-transfer."""

    def fn(x):
        return jax.device_put(x) + 1

    return AuditTarget(name="fixture/host-transfer", family="dense",
                       fn=fn, args=(_BF16_44,))


def bad_donation() -> AuditTarget:
    """Donated bf16 input, f32 output: aval mismatch drops the alias →
    donation-honored."""

    def fn(x):
        return x.astype(jnp.float32) * 2

    return AuditTarget(name="fixture/donation", family="dense",
                       fn=fn, args=(_BF16_44,), donate=(0,))


def bad_upcast() -> AuditTarget:
    """bf16 → f32 upcast originating here (not an allowlisted file) →
    f32-upcast-allowlist."""

    def fn(x):
        return jnp.sum(x.astype(jnp.float32))

    return AuditTarget(name="fixture/upcast", family="dense",
                       fn=fn, args=(_BF16_44,))


def bad_prng() -> AuditTarget:
    """In-graph PRNG on a deterministic target → determinism."""

    def fn(x):
        return x + jax.random.uniform(jax.random.PRNGKey(0), x.shape,
                                      jnp.bfloat16)

    return AuditTarget(name="fixture/prng", family="dense",
                       fn=fn, args=(_BF16_44,), deterministic=True)


def bad_missing_constraint(mesh) -> AuditTarget:
    """KV-shaped value flows through unconstrained on a mesh →
    kv-constraint-coverage (missing)."""

    def fn(kv):
        return kv * 2

    return AuditTarget(name="fixture/missing-constraint", family="dense",
                       fn=fn, args=(_KV_SDS,), mesh=mesh,
                       kv_specs=((_KV_SHAPE, _KV_EXPECTED),))


def bad_mismatched_constraint(mesh) -> AuditTarget:
    """Constraint present but with the wrong spec →
    kv-constraint-coverage (mismatch)."""

    def fn(kv):
        kv = jax.lax.with_sharding_constraint(
            kv, NamedSharding(mesh, P(None, "model", None, None)))
        return kv * 2

    return AuditTarget(name="fixture/mismatched-constraint", family="dense",
                       fn=fn, args=(_KV_SDS,), mesh=mesh,
                       kv_specs=((_KV_SHAPE, _KV_EXPECTED),))


def bad_model_constraint(mesh) -> AuditTarget:
    """Model-axis sharding on a bitwise-reproducible (ssm) family →
    determinism."""

    def fn(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(None, "model"))) * 2

    return AuditTarget(name="fixture/model-constraint", family="ssm",
                       fn=fn, args=(_BF16_44,), mesh=mesh)


def bad_model_collective(mesh) -> AuditTarget:
    """Model-axis psum on a bitwise-reproducible (ssm) family →
    determinism."""
    from jax.experimental.shard_map import shard_map

    def fn(x):
        inner = shard_map(lambda y: jax.lax.psum(y, "model"), mesh=mesh,
                          in_specs=P(), out_specs=P())
        return inner(x)

    return AuditTarget(name="fixture/model-collective", family="ssm",
                       fn=fn, args=(_BF16_44,), mesh=mesh)


def unbounded_while() -> AuditTarget:
    """``lax.while_loop`` has no statically-provable trip count — its
    dot-bearing body is counted once and ``cost_target`` must diagnose
    the silent undercount → audit-unbounded-loop."""

    def fn(x):
        return jax.lax.while_loop(
            lambda s: jnp.sum(s).astype(jnp.float32) < 1e6,
            lambda s: s @ s + 1, x)

    return AuditTarget(name="fixture/unbounded-while", family="dense",
                       fn=fn, args=(_BF16_44,))


def drifting_cost() -> Tuple[AuditTarget, Dict[str, float]]:
    """A 4×4 matmul (128 contraction FLOPs) paired with an analytic
    prediction seeded 25 % low — ``reconcile_target`` must flag it →
    audit-cost-drift."""

    def fn(x):
        return x @ x

    target = AuditTarget(name="fixture/cost-drift", family="dense",
                         fn=fn, args=(_BF16_44,))
    true_flops = 2.0 * 4 * 4 * 4
    return target, {"flops": true_flops * 0.75}


#: cost-audit rule id → fixture builder (proven in tests/test_cost_audit.py)
COST_FIXTURES: Dict[str, Callable] = {
    "audit-unbounded-loop": unbounded_while,
    "audit-cost-drift": drifting_cost,
}


#: rule id → fixture builder; builders taking a mesh are marked True
JAXPR_FIXTURES: Dict[str, Tuple[Callable, bool]] = {
    "no-host-transfer": (bad_host_transfer, False),
    "donation-honored": (bad_donation, False),
    "f32-upcast-allowlist": (bad_upcast, False),
    "determinism": (bad_prng, False),
    "determinism/model-constraint": (bad_model_constraint, True),
    "determinism/model-collective": (bad_model_collective, True),
    "kv-constraint-coverage": (bad_missing_constraint, True),
    "kv-constraint-coverage/mismatch": (bad_mismatched_constraint, True),
}


def _src(text: str) -> str:
    return textwrap.dedent(text).lstrip()


#: lint rule id → (pretend repo-relative path, source) that must trip it
LINT_FIXTURES: Dict[str, Tuple[str, str]] = {
    "lint-jit-in-init": ("src/repro/serve/_fixture.py", _src("""
        import jax

        class Engine:
            def __init__(self, fn):
                self.step = jax.jit(fn)
    """)),
    "lint-block-in-loop": ("src/repro/serve/_fixture.py", _src("""
        def tick_loop(engine, requests):
            for r in requests:
                out = engine.step(r)
                out.block_until_ready()
            return out
    """)),
    "lint-jnp-in-loop": ("src/repro/serve/_fixture.py", _src("""
        import jax.numpy as jnp

        def detok(logits_list):
            toks = []
            for logits in logits_list:
                toks.append(int(jnp.argmax(logits)))
            return toks
    """)),
    "lint-moa-shim": ("src/repro/core/_fixture.py", _src("""
        from repro.core.moa import popcount_adder
    """)),
    "lint-stale-allow": ("src/repro/serve/_fixture.py", _src("""
        import jax

        # audit: allow(lint-jit-in-init)
        def build(fn):
            return jax.jit(fn)
    """)),
}

#: near-misses that must stay clean (scoping and suppression are part of
#: each rule's contract)
CLEAN_LINT_FIXTURES: Dict[str, Tuple[str, str]] = {
    "jit-outside-init": ("src/repro/serve/_fixture.py", _src("""
        import jax

        def build(fn):
            return jax.jit(fn)
    """)),
    "jit-in-init-allowed": ("src/repro/launch/_fixture.py", _src("""
        import jax

        class Trainer:
            def __init__(self, fn):
                # audit: allow(lint-jit-in-init)
                self.step = jax.jit(fn)
    """)),
    "block-outside-loop": ("src/repro/serve/_fixture.py", _src("""
        def warmup(engine, r):
            out = engine.step(r)
            out.block_until_ready()
            return out
    """)),
    "jnp-loop-outside-serve": ("src/repro/layers/_fixture.py", _src("""
        import jax.numpy as jnp

        def stack_all(xs):
            out = []
            for x in xs:
                out.append(jnp.asarray(x))
            return out
    """)),
    "moa-shim-in-tests": ("tests/test_fixture.py", _src("""
        from repro.core.moa import popcount_adder
    """)),
    # a LIVE allow is the stale rule's near-miss: it must not be flagged
    # (same shape as "jit-in-init-allowed", asserted separately so the
    # stale rule's contract is explicit)
    "live-allow-not-stale": ("src/repro/launch/_fixture.py", _src("""
        import jax

        class Trainer:
            def __init__(self, fn):
                # audit: allow(lint-jit-in-init)
                self.step = jax.jit(fn)
    """)),
    # allow-text inside a string literal is data, not a suppression —
    # neither suppresses nor goes stale (the tokenize rationale)
    "allow-in-string-not-stale": ("src/repro/serve/_fixture.py", _src("""
        BANNER = "# audit: allow(lint-jit-in-init)"
    """)),
}
