"""AST lint: repo-specific rules learned from PRs 1–5, plus a dead-module
census.

Rules (ids match :data:`repro.analysis.report.RULES`):

* ``lint-jit-in-init`` — a ``jax.jit`` call lexically inside an
  ``__init__`` body builds a fresh executable per instance; PR 5 shipped
  exactly this regression. Engines must route through the module compile
  cache (``serve.engine._cached_jit``). Scope: all of ``src/repro``.
* ``lint-block-in-loop`` — ``block_until_ready`` inside a Python
  ``for``/``while`` serializes the engine tick loop on device completion
  (the compile-time-in-latency bug). One straight-line warm-up sync is
  fine; a loop-carried one is not. Scope: ``src/repro/serve``.
* ``lint-jnp-in-loop`` — ``jnp.*`` calls inside a Python loop dispatch
  one kernel per token; serve code batches device work into one jitted
  call per tick. Scope: ``src/repro/serve``.
* ``lint-moa-shim`` — the deprecated ``repro.core.moa`` shim must not
  gain new importers (tests pin the legacy surface deliberately and are
  exempt). Scope: ``src``, ``scripts``, ``benchmarks``, ``examples``.
* ``lint-dead-module`` — every ``src/repro`` module must be imported
  somewhere (src, tests, scripts, benchmarks, examples); package
  ``__init__``s and ``__main__``-guarded entry points are exempt.
* ``lint-stale-allow`` — a ``# audit: allow(rule)`` comment that no
  longer sits on (or directly above) a line producing that violation
  suppresses nothing; it survives refactors as a standing invitation to
  reintroduce the bug unnoticed. Suppression comments are read from real
  COMMENT tokens (``tokenize``), never from string literals — the fixture
  corpus in ``analysis/fixtures.py`` embeds allow-comments inside test
  sources and must not trip the rule. Scope: wherever suppressions apply.
"""

from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.report import Violation

__all__ = ["lint_source", "lint_tree", "dead_module_census", "run_lint"]

_LINT_TARGET = "lint"

#: directories (relative to repo root) whose modules count as importers
_IMPORTER_DIRS = ("src", "tests", "scripts", "benchmarks", "examples")

#: the deprecated shim and the module allowed to mention it (itself)
_MOA_SHIM = "repro.core.moa"
_MOA_SHIM_FILE = "src/repro/core/moa.py"

#: inline suppression: ``# audit: allow(<rule-id>)`` on the flagged line
#: or the line directly above it (a rationale comment is expected there)
_ALLOW_RE = re.compile(r"#\s*audit:\s*allow\(([\w-]+)\)")


def _allow_comments(source: str) -> List[Tuple[int, str]]:
    """``(line, rule)`` for every suppression in a real COMMENT token.

    Tokenizing (not line-regexing) is load-bearing: fixture sources in
    this package quote allow-comments inside string literals, which must
    be invisible both to suppression and to the staleness check."""
    out: List[Tuple[int, str]] = []
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                for rule in _ALLOW_RE.findall(tok.string):
                    out.append((tok.start[0], rule))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        pass                      # ast.parse already reports unparseables
    return out


class _Linter(ast.NodeVisitor):
    """Single-pass walker tracking the enclosing function/loop stacks."""

    def __init__(self, rel_path: str, in_serve: bool):
        self.rel = rel_path
        self.in_serve = in_serve
        self.fn_stack: List[str] = []
        self.loop_depth = 0
        self.out: List[Violation] = []

    # ---- scope tracking ----------------------------------------------------
    def _visit_fn(self, node):
        self.fn_stack.append(node.name)
        outer_loops = self.loop_depth
        self.loop_depth = 0          # a nested def resets the loop context
        self.generic_visit(node)
        self.loop_depth = outer_loops
        self.fn_stack.pop()

    visit_FunctionDef = _visit_fn
    visit_AsyncFunctionDef = _visit_fn

    def _visit_loop(self, node):
        self.loop_depth += 1
        self.generic_visit(node)
        self.loop_depth -= 1

    visit_For = _visit_loop
    visit_While = _visit_loop
    visit_AsyncFor = _visit_loop

    # ---- rules -------------------------------------------------------------
    def visit_Call(self, node: ast.Call):
        func = node.func
        if isinstance(func, ast.Attribute):
            # jax.jit(...) lexically under an __init__
            if (func.attr == "jit" and isinstance(func.value, ast.Name)
                    and func.value.id == "jax"
                    and "__init__" in self.fn_stack):
                self.out.append(Violation(
                    rule="lint-jit-in-init", target=_LINT_TARGET,
                    file=self.rel, line=node.lineno,
                    message=("jax.jit inside __init__ builds a per-instance "
                             "executable — route through the module compile "
                             "cache (_cached_jit)")))
            if self.in_serve and self.loop_depth > 0:
                if func.attr == "block_until_ready":
                    self.out.append(Violation(
                        rule="lint-block-in-loop", target=_LINT_TARGET,
                        file=self.rel, line=node.lineno,
                        message=("block_until_ready inside a serve loop "
                                 "serializes ticks on device completion")))
                root = func
                while isinstance(root, ast.Attribute):
                    root = root.value
                if isinstance(root, ast.Name) and root.id == "jnp":
                    self.out.append(Violation(
                        rule="lint-jnp-in-loop", target=_LINT_TARGET,
                        file=self.rel, line=node.lineno,
                        message=("jnp call inside a per-token Python loop — "
                                 "batch device work into one jitted call "
                                 "per tick")))
        self.generic_visit(node)

    # ---- shim imports ------------------------------------------------------
    def _check_shim(self, modname: Optional[str], lineno: int):
        if modname and (modname == _MOA_SHIM
                        or modname.startswith(_MOA_SHIM + ".")):
            if self.rel != _MOA_SHIM_FILE:
                self.out.append(Violation(
                    rule="lint-moa-shim", target=_LINT_TARGET,
                    file=self.rel, line=lineno,
                    message=("import of the deprecated repro.core.moa shim "
                             "— use repro.moa")))

    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            self._check_shim(alias.name, node.lineno)
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.level == 0:
            self._check_shim(node.module, node.lineno)
            if node.module == "repro.core":
                for alias in node.names:
                    if alias.name == "moa":
                        self._check_shim(_MOA_SHIM, node.lineno)
        self.generic_visit(node)


def lint_source(rel_path: str, source: str) -> List[Violation]:
    """Lint one module given its repo-relative path and source text."""
    rel = rel_path.replace(os.sep, "/")
    in_serve = rel.startswith("src/repro/serve/")
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Violation(
            rule="lint-parse-error", target=_LINT_TARGET, file=rel,
            line=e.lineno or 0, message=f"unparseable module: {e.msg}")]
    shim_scope = rel.split("/", 1)[0] in ("src", "scripts", "benchmarks",
                                          "examples")
    linter = _Linter(rel, in_serve)
    linter.visit(tree)
    if not shim_scope:
        linter.out = [v for v in linter.out if v.rule != "lint-moa-shim"]
    allows = _allow_comments(source)

    def allowed(v: Violation) -> bool:
        # the flagged line or the line above (rationale comments sit there)
        return any(rule == v.rule and ln in (v.line, v.line - 1)
                   for ln, rule in allows)

    kept = [v for v in linter.out if not allowed(v)]
    for ln, rule in allows:
        if not any(v.rule == rule and v.line in (ln, ln + 1)
                   for v in linter.out):
            kept.append(Violation(
                rule="lint-stale-allow", target=_LINT_TARGET, file=rel,
                line=ln,
                message=(f"# audit: allow({rule}) suppresses nothing — no "
                         f"live {rule} violation on this or the next line; "
                         "delete the comment or re-point it")))
    return sorted(kept, key=lambda v: (v.line, v.rule))


def _py_files(root: str, sub: str) -> Iterable[str]:
    base = os.path.join(root, sub)
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = [d for d in dirnames
                       if d not in ("__pycache__", ".git")]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.relpath(os.path.join(dirpath, fn), root)


def lint_tree(repo_root: str) -> Tuple[List[Violation], int]:
    """Lint every Python module in the importer directories; returns
    (violations, files linted)."""
    out: List[Violation] = []
    n = 0
    for sub in _IMPORTER_DIRS:
        if not os.path.isdir(os.path.join(repo_root, sub)):
            continue
        for rel in _py_files(repo_root, sub):
            with open(os.path.join(repo_root, rel), encoding="utf-8") as f:
                src = f.read()
            out.extend(lint_source(rel, src))
            n += 1
    return out, n


# ---------------------------------------------------------------------------
# dead-module census
# ---------------------------------------------------------------------------


def _module_name(rel: str) -> Optional[str]:
    """src/repro/a/b.py → repro.a.b (None for non-src files)."""
    rel = rel.replace(os.sep, "/")
    if not rel.startswith("src/") or not rel.endswith(".py"):
        return None
    mod = rel[len("src/"):-len(".py")]
    if mod.endswith("/__init__"):
        mod = mod[: -len("/__init__")]
    return mod.replace("/", ".")


def _imported_modules(tree: ast.AST, known: Set[str]) -> Set[str]:
    """Module names this AST imports, resolved against the known set
    (``from repro.a import b`` marks ``repro.a.b`` when it is a module)."""
    out: Set[str] = set()

    def mark(name: str):
        if name in known:
            out.add(name)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                mark(alias.name)
        elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module:
            mark(node.module)
            for alias in node.names:
                mark(f"{node.module}.{alias.name}")
    return out


def dead_module_census(repo_root: str) -> List[Violation]:
    """Flag every ``src/repro`` module imported by nothing.

    Exemptions: package ``__init__`` modules (plumbing) and modules with a
    ``__main__`` guard (CLI entry points run via ``python -m``).
    """
    sources: Dict[str, Tuple[str, ast.AST]] = {}
    for sub in _IMPORTER_DIRS:
        if not os.path.isdir(os.path.join(repo_root, sub)):
            continue
        for rel in _py_files(repo_root, sub):
            with open(os.path.join(repo_root, rel), encoding="utf-8") as f:
                try:
                    tree = ast.parse(f.read())
                except SyntaxError:
                    continue
            sources[rel] = (_module_name(rel), tree)

    known = {mod for mod, _ in sources.values() if mod}
    imported: Set[str] = set()
    for rel, (mod, tree) in sources.items():
        for name in _imported_modules(tree, known):
            if name != mod:          # self-imports don't keep a module alive
                imported.add(name)

    out: List[Violation] = []
    for rel in sorted(sources):
        mod, tree = sources[rel]
        if not mod or not mod.startswith("repro"):
            continue
        if rel.endswith("__init__.py"):
            continue
        if mod in imported:
            continue
        if any(isinstance(n, ast.If) and isinstance(n.test, ast.Compare)
               and isinstance(n.test.left, ast.Name)
               and n.test.left.id == "__name__"
               for n in ast.walk(tree)):
            continue                 # __main__-guarded entry point
        out.append(Violation(
            rule="lint-dead-module", target=_LINT_TARGET, file=rel, line=1,
            message=(f"module {mod} is imported by nothing under "
                     f"{'/'.join(_IMPORTER_DIRS)} — wire it up or remove "
                     "it")))
    return out


def run_lint(repo_root: str) -> Tuple[List[Violation], int]:
    """Both lint passes; returns (violations, files linted)."""
    violations, n_files = lint_tree(repo_root)
    violations.extend(dead_module_census(repo_root))
    return violations, n_files
