"""Enumerate serve-path audit targets: families × dense/paged × mesh modes.

Each family module registers its serve surface in a ``SERVE_AUDIT`` dict
(phases, KV stack key, paged/suffix capability); this module turns that
table into :class:`~repro.analysis.jaxpr_audit.AuditTarget` records with
abstract (``ShapeDtypeStruct``) arguments — exactly the callables the
:class:`~repro.serve.engine.ServeEngine` jits, with the same donation and
in/out sharding wiring, so the auditor inspects what the engine actually
compiles.

Mesh targets trace on a (data=1, model=1) mesh: ``sharding_constraint``
equations carry their full logical specs regardless of axis sizes (and
nothing is dropped for indivisibility on size-1 axes), so the audit runs
on a single CPU device.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.jaxpr_audit import AuditTarget, _norm_spec
from repro.configs.registry import get_config, smoke_config
from repro.models.api import build_model
from repro.parallel.sharding import (constraint_spec,
                                     replicate_uneven_kv_heads,
                                     serve_cache_shardings, serve_rules_for)
from repro.serve.engine import (_clear_slot, _cow_copy, _gather_prefix,
                                _paged_write, _read_paged_slot, _read_slot,
                                _restore_paged_slot, _write_slot)
from repro.serve.sampling import sample_batch
from repro.serve.spec import verify_accept

__all__ = ["SMOKE_BY_FAMILY", "SERVE_FAMILIES", "AUDIT_SHAPE",
           "make_audit_mesh", "build_family_targets", "enumerate_targets"]

#: family → smallest real config of that family (smoke-shrunk for tracing)
SMOKE_BY_FAMILY = {
    "dense": "llama3-8b",
    "moe": "moonshot-v1-16b-a3b",
    "ssm": "mamba2-370m",
    "hybrid": "zamba2-1.2b",
}
SERVE_FAMILIES = tuple(SMOKE_BY_FAMILY)

#: the one shape every audit target traces at — shared with the cost
#: auditor so :func:`repro.launch.costing.serve_target_cost` predictions
#: are keyed exactly the way the targets are built
AUDIT_SHAPE = dict(slots=2, max_len=32, window=4, block_size=8,
                   prefill_len=16)

_CACHE_AXES = ("batch", "kv_seq", "kv_heads_cache", "head_dim")
_POOL_AXES = (None, None, "kv_heads_cache", "head_dim")

_i32, _bf16, _f32 = jnp.int32, jnp.bfloat16, jnp.float32


def make_audit_mesh() -> Mesh:
    """A (data=1, model=1) logical mesh on the first local device."""
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _expected_specs(cache: Dict, kv_key: Optional[str], rules, mesh, *,
                    paged: bool, max_len: int, slots: int):
    """KV operand shape → expected normalized constraint spec.

    Covers the per-stack cache/pool slices the model constrains in-flight
    (``_constrain_cache`` / ``_constrain_pool``) and, for the paged layout,
    the gathered logical view (``gather_paged_kv``) which must carry the
    dense-slot layout.
    """
    if kv_key is None or mesh is None:
        return ()
    out: Dict[Tuple[int, ...], Tuple[Any, ...]] = {}
    axes = _POOL_AXES if paged else _CACHE_AXES
    for name in ("k", "v"):
        leaf = cache[kv_key][name]            # (stack, ...) per-stack slice
        shape = tuple(leaf.shape[1:])
        out[shape] = _norm_spec(constraint_spec(axes, rules, mesh),
                                len(shape))
        if paged:
            # gathered logical view: (slots, max_len, Hk, D), dense layout
            gathered = (slots, max_len) + shape[2:]
            out[gathered] = _norm_spec(
                constraint_spec(_CACHE_AXES, rules, mesh), len(gathered))
    return tuple(out.items())


def build_family_targets(family: str, *, mesh: Optional[Mesh] = None,
                         slots: int = 2, max_len: int = 32, window: int = 4,
                         block_size: int = 8,
                         prefill_len: int = 16) -> List[AuditTarget]:
    """All serve-path targets for one family on one mesh mode."""
    cfg = smoke_config(get_config(SMOKE_BY_FAMILY[family]))
    model = build_model(cfg)
    hooks = model._mod.SERVE_AUDIT
    kv_key = hooks["kv_key"]
    params = model.abstract_params()
    tag = "@mesh" if mesh is not None else ""

    rules = param_sh = cache_sh = rep = None
    if mesh is not None:
        from repro.launch.steps import build_shardings, infer_param_axes
        rules = replicate_uneven_kv_heads(
            serve_rules_for(family), cfg.n_kv_heads, mesh)
        param_sh = build_shardings(params, infer_param_axes(params), mesh,
                                   rules)
        rep = NamedSharding(mesh, P())

    # engine-shaped dense cache: batched slots, per-slot position vector
    cache = dict(jax.eval_shape(lambda: model.init_cache(slots, max_len)))
    cache["pos"] = _sds((slots,), _i32)
    if mesh is not None:
        cache_sh = serve_cache_shardings(cache, mesh, rules, paged=False)
    kv_dense = _expected_specs(cache, kv_key, rules, mesh, paged=False,
                               max_len=max_len, slots=slots)

    def mk(phase, fn, args, *, donate=(), det=True, ins=None, outs=None,
           kv=()):
        return AuditTarget(
            name=f"{family}/{phase}{tag}", family=family, fn=fn,
            args=tuple(args), donate=tuple(donate), deterministic=det,
            mesh=mesh, rules=rules,
            in_shardings=ins if mesh is not None else None,
            out_shardings=outs if mesh is not None else None,
            kv_specs=kv)

    targets: List[AuditTarget] = []
    phases = hooks["phases"]

    if "prefill" in phases:
        if model.supports_padded_prefill:
            fn = lambda p, t, pl: model.prefill(  # noqa: E731
                p, {"tokens": t}, max_len=max_len, prompt_len=pl)
            targets.append(mk(
                "prefill", fn,
                (params, _sds((slots, prefill_len), _i32), _sds((), _i32)),
                ins=(param_sh, rep, rep), outs=rep, kv=kv_dense))
        else:
            fn = lambda p, t: model.prefill(  # noqa: E731
                p, {"tokens": t}, max_len=max_len)
            targets.append(mk(
                "prefill", fn, (params, _sds((slots, prefill_len), _i32)),
                ins=(param_sh, rep), outs=rep, kv=kv_dense))

    tokens1 = _sds((slots, 1), _i32)
    if "decode" in phases:
        targets.append(mk(
            "decode", model.decode_step, (params, cache, tokens1),
            donate=(1,), ins=(param_sh, cache_sh, rep),
            outs=(rep, cache_sh), kv=kv_dense))

    aux = None
    if "verify" in phases and model.supports_spec_decode:
        tokens_v = _sds((slots, window), _i32)
        targets.append(mk(
            "verify", model.verify_step, (params, cache, tokens_v),
            donate=(1,), ins=(param_sh, cache_sh, rep),
            outs=(rep, cache_sh, rep), kv=kv_dense))
        aux = jax.eval_shape(model.verify_step, params, cache, tokens_v)[2]

    if "commit" in phases and model.supports_spec_decode:
        fn = lambda c, k, a: model.commit_verified(c, k, a)  # noqa: E731
        targets.append(mk(
            "commit", fn, (cache, _sds((slots,), _i32), aux),
            donate=(0,), ins=(cache_sh, rep, rep), outs=cache_sh))

    # engine slot-install: batch=1 prefill scattered into the batched cache
    pre_tokens = _sds((1, prefill_len), _i32)
    pre_cache = jax.eval_shape(
        lambda p, t: model.prefill(p, {"tokens": t}, max_len=max_len),
        params, pre_tokens)[1]
    targets.append(mk(
        "write_slot", _write_slot, (cache, pre_cache, _sds((), _i32)),
        donate=(0,), ins=(cache_sh, rep, rep), outs=cache_sh))
    # preemption spill: the exact inverse gather (no donation — pure read)
    targets.append(mk(
        "read_slot", _read_slot, (cache, _sds((), _i32)),
        ins=(cache_sh, rep), outs=rep))

    if hooks.get("prefill_chunk"):
        # recurrent chunked prefill: carried state in, advanced state out
        cache1 = jax.eval_shape(lambda: model.init_cache(1, max_len))
        state_key = "layers" if family == "ssm" else "ssm"
        state = {state_key: cache1[state_key], "pos": _sds((), _i32)}
        if family == "ssm":
            fn = lambda p, t, st: model.prefill_chunk(  # noqa: E731
                p, {"tokens": t}, state=st)
            targets.append(mk(
                "prefill_chunk", fn, (params, pre_tokens, state),
                ins=(param_sh, rep, rep), outs=rep, kv=kv_dense))
        else:
            kv = cache[kv_key]
            chunk_prefix = {
                name: _sds((kv[name].shape[0], 1, prefill_len)
                           + kv[name].shape[3:], cfg.cdtype)
                for name in ("k", "v")}
            fn = lambda p, t, st, pre: model.prefill_chunk(  # noqa: E731
                p, {"tokens": t}, state=st, prefix_kv=pre)
            # batch=1 chunk: no cache-shaped value in flight (the engine
            # scatters the returned suffix KV separately), so no kv specs —
            # same regime as the suffix_prefill target
            targets.append(mk(
                "prefill_chunk", fn,
                (params, pre_tokens, state, chunk_prefix),
                ins=(param_sh, rep, rep, rep), outs=rep))

    if family == "dense":
        # engine-level samplers are family-independent; audit them once
        rng = _sds((2,), jnp.uint32)
        temps, greedy = _sds((slots,), _f32), _sds((slots,), jnp.bool_)
        targets.append(mk(
            "sample", sample_batch,
            (_sds((slots, cfg.vocab), _bf16), temps, greedy, rng),
            det=False))
        targets.append(mk(
            "accept", verify_accept,
            (_sds((slots, window, cfg.vocab), _bf16),
             _sds((slots, window - 1), _i32), temps, greedy, rng),
            det=False))

    if not hooks["paged"]:
        return targets

    # ---- paged layout ------------------------------------------------------
    max_blocks = max_len // block_size
    n_blocks = slots * max_blocks
    cache_p = jax.eval_shape(lambda: model.init_paged_cache(
        slots, n_blocks + 1, block_size, max_blocks))
    cache_p_sh = None
    if mesh is not None:
        cache_p_sh = serve_cache_shardings(cache_p, mesh, rules, paged=True)
    kv_paged = _expected_specs(cache_p, kv_key, rules, mesh, paged=True,
                               max_len=max_len, slots=slots)

    def mkp(phase, fn, args, *, donate=(), ins=None, outs=None, kv=()):
        return mk(f"paged_{phase}", fn, args, donate=donate, ins=ins,
                  outs=outs, kv=kv)

    targets.append(mkp(
        "decode", model.paged_decode_step, (params, cache_p, tokens1),
        donate=(1,), ins=(param_sh, cache_p_sh, rep),
        outs=(rep, cache_p_sh), kv=kv_paged))

    # the engine's per-live-block-bucket decode closure (high-water gather:
    # only the first `hw` block-table columns are streamed)
    hw = max(max_blocks // 2, 1)
    fn_hw = lambda p, c, t: model.paged_decode_step(  # noqa: E731
        p, c, t, live_blocks=hw)
    targets.append(mkp(
        "decode_hw", fn_hw, (params, cache_p, tokens1),
        donate=(1,), ins=(param_sh, cache_p_sh, rep),
        outs=(rep, cache_p_sh), kv=kv_paged))

    # fused pallas backend (kernels/paged_attention.py); on CPU the kernel
    # traces in interpret mode, which is exactly what the engine compiles
    import dataclasses as _dc
    model_pl = build_model(_dc.replace(cfg, attn_backend="pallas"))
    targets.append(mkp(
        "decode_fused", model_pl.paged_decode_step,
        (params, cache_p, tokens1),
        donate=(1,), ins=(param_sh, cache_p_sh, rep),
        outs=(rep, cache_p_sh), kv=kv_paged))

    if model.supports_spec_decode:
        targets.append(mkp(
            "verify", model.paged_verify_step,
            (params, cache_p, _sds((slots, window), _i32)),
            donate=(1,), ins=(param_sh, cache_p_sh, rep),
            outs=(rep, cache_p_sh, rep), kv=kv_paged))
        targets.append(mkp(
            "verify_fused", model_pl.paged_verify_step,
            (params, cache_p, _sds((slots, window), _i32)),
            donate=(1,), ins=(param_sh, cache_p_sh, rep),
            outs=(rep, cache_p_sh, rep), kv=kv_paged))

    pool_sh = cache_p_sh[kv_key] if cache_p_sh is not None else None
    targets.append(mkp(
        "gather_prefix",
        functools.partial(_gather_prefix, cdtype=cfg.cdtype),
        (cache_p[kv_key], _sds((2,), _i32)),
        ins=(pool_sh, rep), outs=rep))

    # prefill scatter: nb written blocks of the batch=1 prefill
    nb = 2
    pre_kv, pre_state_full = model.split_prefill_cache(pre_cache)
    pre_kv = jax.tree.map(
        lambda l: _sds((l.shape[0], 1, nb * block_size) + l.shape[3:],
                       l.dtype), pre_kv)
    pre_state = None
    if pre_state_full is not None:
        pre_state = jax.tree.map(
            lambda l: _sds((l.shape[0], 1) + l.shape[2:], l.dtype),
            pre_state_full)
    targets.append(mkp(
        "write",
        functools.partial(_paged_write, kv_key=kv_key),
        (cache_p, pre_kv, pre_state, _sds((nb,), _i32),
         _sds((max_blocks,), _i32), _sds((), _i32), _sds((), _i32)),
        donate=(0,), ins=(cache_p_sh,) + (rep,) * 6, outs=cache_p_sh))

    scalar = _sds((), _i32)
    targets.append(mkp(
        "cow_copy", functools.partial(_cow_copy, kv_key=kv_key),
        (cache_p, scalar, scalar, scalar, scalar),
        donate=(0,), ins=(cache_p_sh,) + (rep,) * 4, outs=cache_p_sh))
    targets.append(mkp(
        "clear_slot", _clear_slot, (cache_p, scalar),
        donate=(0,), ins=(cache_p_sh, rep), outs=cache_p_sh))

    # preemption spill/revive on the paged layout: snapshot only the
    # slot-indexed leaves (pool pages stay pinned), then reinstall the
    # table row + cursor (+ recurrent state) on revival
    has_ssm = family == "hybrid"
    read_paged = functools.partial(_read_paged_slot, has_ssm=has_ssm)
    targets.append(mkp(
        "read_slot", read_paged, (cache_p, scalar),
        ins=(cache_p_sh, rep), outs=rep))
    snap = jax.eval_shape(read_paged, cache_p, scalar)
    targets.append(mkp(
        "restore_slot",
        functools.partial(_restore_paged_slot, has_ssm=has_ssm),
        (cache_p, snap, _sds((max_blocks,), _i32), scalar),
        donate=(0,), ins=(cache_p_sh,) + (rep,) * 3, outs=cache_p_sh))

    if hooks["suffix_prefill"]:
        prefix = jax.eval_shape(
            functools.partial(_gather_prefix, cdtype=cfg.cdtype),
            cache_p[kv_key], _sds((nb,), _i32))
        fn = lambda p, t, pre, pl: model.prefill_suffix(  # noqa: E731
            p, {"tokens": t}, prefix=pre, prompt_len=pl)
        targets.append(mkp(
            "suffix_prefill", fn,
            (params, pre_tokens, prefix, scalar),
            ins=(param_sh, rep, rep, rep), outs=rep))

    return targets


def enumerate_targets(families: Sequence[str] = SERVE_FAMILIES,
                      mesh_modes: Sequence[str] = ("none", "mesh"),
                      **kwargs) -> List[AuditTarget]:
    """The full audit matrix: families × dense/paged × mesh/no-mesh."""
    out: List[AuditTarget] = []
    for mode in mesh_modes:
        mesh = make_audit_mesh() if mode == "mesh" else None
        for family in families:
            out.extend(build_family_targets(family, mesh=mesh, **kwargs))
    return out
