"""Static analysis for the serve path: jaxpr auditing + repo lint.

Three passes, all run by ``scripts/audit_serve_path.py`` and gated in CI:

* :mod:`repro.analysis.jaxpr_audit` traces every serve-path callable
  (families × dense/paged × mesh/no-mesh, enumerated by
  :mod:`repro.analysis.targets`) without executing it and checks the
  lowered jaxpr against the repo invariants (host transfers, donation,
  f32-upcast allowlist, KV sharding-constraint coverage, determinism);
* :mod:`repro.analysis.lint` checks the source tree itself for the
  regression patterns learned in PRs 1–5 (per-instance ``jax.jit``,
  blocking tick loops, per-token ``jnp`` calls, the deprecated
  ``repro.core.moa`` shim, stale suppressions) plus a dead-module census;
* :mod:`repro.analysis.cost_audit` walks the same jaxprs with
  trip-count-aware FLOP/byte accounting and reconciles every target
  against the analytic model in :mod:`repro.launch.costing` (the
  ``analysis-v2`` record, ``--cost`` gate).

See docs/static-analysis.md for the rule catalog and how to allowlist a
site or add a rule.
"""

from repro.analysis.cost_audit import (DRIFT_PHASES, FLOPS_RTOL,
                                       KV_BYTES_RTOL, LoopRecord, StaticCost,
                                       cost_audit_targets, cost_target,
                                       count_jaxpr, reconcile_target)
from repro.analysis.jaxpr_audit import (AuditTarget, audit_target,
                                        audit_targets)
from repro.analysis.lint import run_lint
from repro.analysis.report import (ANALYSIS_SCHEMA, ANALYSIS_V2_SCHEMA,
                                   RULES, Violation, build_cost_report,
                                   build_report, summarize)
from repro.analysis.targets import (AUDIT_SHAPE, SERVE_FAMILIES,
                                    SMOKE_BY_FAMILY, build_family_targets,
                                    enumerate_targets, make_audit_mesh)

__all__ = [
    "ANALYSIS_SCHEMA", "ANALYSIS_V2_SCHEMA", "RULES", "Violation",
    "build_report", "build_cost_report", "summarize",
    "AuditTarget", "audit_target", "audit_targets", "run_lint",
    "StaticCost", "LoopRecord", "count_jaxpr", "cost_target",
    "cost_audit_targets", "reconcile_target",
    "DRIFT_PHASES", "FLOPS_RTOL", "KV_BYTES_RTOL",
    "AUDIT_SHAPE", "SERVE_FAMILIES", "SMOKE_BY_FAMILY",
    "build_family_targets", "enumerate_targets", "make_audit_mesh",
]
