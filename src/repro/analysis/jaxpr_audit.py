"""Jaxpr-level serve-path auditor.

Traces a serve callable with :func:`jax.make_jaxpr` over abstract
``ShapeDtypeStruct`` inputs — no FLOPs, no allocation — and walks the
resulting ClosedJaxpr (recursing into pjit / scan / cond / remat bodies)
checking the repo invariants the end-to-end tests can only probe one
shape at a time:

* **no-host-transfer** — no ``device_put`` / host-callback primitives
  inside a jitted hot path (each is a device sync per tick);
* **donation-honored** — every leaf of a donated argument appears in the
  lowering's input-output aliasing table (``tf.aliasing_output``), i.e.
  donation survived the in/out sharding specs;
* **f32-upcast-allowlist** — bf16/f16 → f32 ``convert_element_type`` only
  at the named accumulation sites (``layers/numerics.py`` helpers and
  ``layers/attention.py``); an upcast anywhere else is an unbudgeted 2×
  memory-stream regression (the paper's accumulate-wide-store-narrow
  discipline made checkable);
* **kv-constraint-coverage** — on a mesh, KV-cache-shaped intermediates
  carry ``sharding_constraint`` ops whose spec matches the
  ``serve_rules_for(family)`` table (a dropped ``_constrain_cache`` means
  GSPMD remats the donated cache every step);
* **determinism** — deterministic targets contain no PRNG primitives, and
  the bitwise-reproducible families (ssm / hybrid) never touch the
  ``model`` mesh axis (no model-axis collectives, no model-axis specs).

This is the analogue of inspecting the synthesized netlist instead of
trusting the HDL (PAPER.md): the jaxpr is what actually runs.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.report import Violation
from repro.parallel.sharding import activate

__all__ = ["AuditTarget", "audit_target", "audit_targets", "iter_eqns"]

#: primitives that force a host round-trip / transfer inside a hot path
BANNED_PRIMITIVES = {
    "device_put", "pure_callback", "io_callback", "callback",
    "debug_callback", "infeed", "outfeed",
}

#: unkeyed-or-not, any PRNG primitive on a deterministic path breaks
#: bitwise reproducibility (keys must enter through explicit rng args on
#: the sampling targets only)
PRNG_PRIMITIVES = {
    "random_seed", "random_bits", "random_wrap", "random_unwrap",
    "random_fold_in", "random_gamma", "threefry2x32",
}

#: cross-device collectives — checked for the ``model`` axis on ssm/hybrid
COLLECTIVE_PRIMITIVES = {
    "psum", "psum2", "pmax", "pmin", "all_gather", "all_to_all",
    "ppermute", "reduce_scatter", "pgather",
}

#: the only files allowed to originate a bf16/f16 → f32 upcast on a serve
#: path (relative to the repo root)
UPCAST_ALLOWLIST = (
    "src/repro/layers/numerics.py",
    "src/repro/layers/attention.py",
    # fused paged-attention kernels accumulate (m, l, acc) in f32 and
    # dequantize int8 KV in-register — both are budgeted upcasts
    "src/repro/kernels/paged_attention.py",
)

_SMALL_FLOATS = (jnp.bfloat16, jnp.float16)


@dataclasses.dataclass(frozen=True)
class AuditTarget:
    """One serve-path callable plus everything needed to trace and lower
    it exactly the way the engine does (donation, in/out shardings)."""

    name: str
    family: str
    fn: Any
    args: Tuple[Any, ...]
    donate: Tuple[int, ...] = ()
    deterministic: bool = True
    mesh: Any = None
    rules: Any = None
    in_shardings: Any = None
    out_shardings: Any = None
    #: operand shape → expected normalized constraint spec (mesh targets
    #: that touch KV state; empty disables the coverage rule)
    kv_specs: Tuple[Tuple[Tuple[int, ...], Tuple[Any, ...]], ...] = ()


def _subjaxprs(eqn):
    for v in eqn.params.values():
        items = v if isinstance(v, (list, tuple)) else (v,)
        for x in items:
            if hasattr(x, "eqns"):
                yield x
            elif hasattr(x, "jaxpr") and hasattr(x.jaxpr, "eqns"):
                yield x.jaxpr


def iter_eqns(jaxpr, path: Tuple[str, ...] = ()) -> Iterator[Tuple[Any, Tuple[str, ...]]]:
    """Yield ``(eqn, nesting_path)`` over a jaxpr and all inner jaxprs
    (pjit bodies, scan/while/cond branches, remat, custom_jvp, ...)."""
    for eqn in jaxpr.eqns:
        yield eqn, path
        for sub in _subjaxprs(eqn):
            yield from iter_eqns(sub, path + (eqn.primitive.name,))


def _site(eqn) -> Tuple[str, int]:
    """Innermost repo frame of the primitive's traceback → (file, line)."""
    tb = getattr(eqn.source_info, "traceback", None)
    if tb is None:
        return "", 0
    for fr in tb.frames:
        fname = fr.file_name.replace("\\", "/")
        idx = fname.find("/src/repro/")
        if idx >= 0:
            return fname[idx + 1:], fr.line_num
        if "/repro/" in fname:  # installed/editable layouts
            return "src/repro/" + fname.split("/repro/", 1)[1], fr.line_num
    return "", 0


def _norm_spec(spec, ndim: int) -> Tuple[Any, ...]:
    """PartitionSpec → comparable tuple padded to ``ndim`` entries."""
    entries = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
    out = []
    for e in entries:
        if isinstance(e, tuple):
            e = e[0] if len(e) == 1 else tuple(e)
        out.append(e)
    return tuple(out)


def _mentions_model(spec_entries) -> bool:
    for e in spec_entries:
        axes = e if isinstance(e, tuple) else (e,)
        if "model" in axes:
            return True
    return False


def _trace(target: AuditTarget):
    ctx = activate(target.mesh, target.rules) if target.mesh is not None \
        else contextlib.nullcontext()
    with ctx:
        return jax.make_jaxpr(target.fn)(*target.args)


def _live_donated_leaves(target: AuditTarget, closed) -> int:
    """Donated leaves that survive dead-code elimination.

    ``jax.jit`` (``keep_unused=False``) drops arguments the output never
    depends on — e.g. recurrent leaves a spec-decode commit replaces
    wholesale from the verify snapshot. A dead donated leaf cannot (and
    need not) alias, so only live leaves count toward the expectation.
    """
    n_out = len(closed.jaxpr.outvars)
    try:
        from jax.interpreters import partial_eval as pe
        _, used_inputs = pe.dce_jaxpr(closed.jaxpr, [True] * n_out)
    except Exception:
        used_inputs = [True] * len(closed.jaxpr.invars)
    sizes = [len(jax.tree.leaves(a)) for a in target.args]
    offsets = [sum(sizes[:i]) for i in range(len(sizes))]
    live = 0
    for i in target.donate:
        live += sum(bool(u)
                    for u in used_inputs[offsets[i]:offsets[i] + sizes[i]])
    return live


def _check_donation(target: AuditTarget, closed) -> List[Violation]:
    """Lower exactly like the engine's ``_build`` and count aliased
    outputs: every *live* leaf of a donated argument must alias."""
    kwargs: Dict[str, Any] = {"donate_argnums": target.donate}
    if target.mesh is not None:
        if target.in_shardings is not None:
            kwargs["in_shardings"] = target.in_shardings
        if target.out_shardings is not None:
            kwargs["out_shardings"] = target.out_shardings
    ctx = activate(target.mesh, target.rules) if target.mesh is not None \
        else contextlib.nullcontext()
    import warnings
    with ctx, warnings.catch_warnings():
        # an unhonored donation warns at lowering time; the violation
        # record below is the actionable signal
        warnings.simplefilter("ignore")
        lowered = jax.jit(target.fn, **kwargs).lower(*target.args)
    text = lowered.as_text()
    n_aliased = text.count("tf.aliasing_output")
    n_donated = _live_donated_leaves(target, closed)
    if n_aliased < n_donated:
        return [Violation(
            rule="donation-honored", target=target.name, file="", line=0,
            message=(f"only {n_aliased}/{n_donated} donated leaves appear "
                     "in the lowering's input-output aliasing — donation "
                     "dropped (dtype/shape/sharding mismatch between the "
                     "donated input and its output)"),
            provenance=f"donate_argnums={target.donate}")]
    return []


def audit_target(target: AuditTarget) -> List[Violation]:
    """Run every jaxpr rule against one serve callable."""
    out: List[Violation] = []
    closed = _trace(target)
    reproducible = target.family in ("ssm", "hybrid")
    kv_specs = dict(target.kv_specs)
    seen_kv_constraint = False

    for eqn, path in iter_eqns(closed.jaxpr):
        name = eqn.primitive.name
        prov = "/".join(path + (name,))

        if name in BANNED_PRIMITIVES:
            file, line = _site(eqn)
            out.append(Violation(
                rule="no-host-transfer", target=target.name, file=file,
                line=line, provenance=prov,
                message=f"{name} primitive inside a jitted serve path"))

        elif name == "convert_element_type":
            src = eqn.invars[0].aval.dtype
            dst = eqn.params.get("new_dtype")
            if src in _SMALL_FLOATS and dst == jnp.float32:
                file, line = _site(eqn)
                if file not in UPCAST_ALLOWLIST:
                    out.append(Violation(
                        rule="f32-upcast-allowlist", target=target.name,
                        file=file, line=line, provenance=prov,
                        message=(f"{src} -> float32 upcast outside the "
                                 "allowlisted accumulation sites (route it "
                                 "through a layers/numerics.py helper)")))

        elif name in PRNG_PRIMITIVES and target.deterministic:
            file, line = _site(eqn)
            out.append(Violation(
                rule="determinism", target=target.name, file=file,
                line=line, provenance=prov,
                message=(f"PRNG primitive {name} on a deterministic serve "
                         "path")))

        elif name in COLLECTIVE_PRIMITIVES and reproducible:
            axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
            if not isinstance(axes, tuple):
                axes = (axes,)
            if "model" in axes:
                file, line = _site(eqn)
                out.append(Violation(
                    rule="determinism", target=target.name, file=file,
                    line=line, provenance=prov,
                    message=(f"model-axis collective {name} on a "
                             "bitwise-reproducible family (serve_rules_for "
                             "must keep ssm/hybrid off the model axis)")))

        elif name == "sharding_constraint":
            aval = eqn.invars[0].aval
            sharding = eqn.params.get("sharding")
            spec = getattr(sharding, "spec", None)
            if spec is None:
                continue
            entries = _norm_spec(spec, aval.ndim)
            if reproducible and _mentions_model(entries):
                file, line = _site(eqn)
                out.append(Violation(
                    rule="determinism", target=target.name, file=file,
                    line=line, provenance=prov,
                    message=("model-axis sharding constraint "
                             f"{entries} on a bitwise-reproducible family")))
            expected = kv_specs.get(tuple(aval.shape))
            if expected is not None:
                seen_kv_constraint = True
                if entries != expected:
                    file, line = _site(eqn)
                    out.append(Violation(
                        rule="kv-constraint-coverage", target=target.name,
                        file=file, line=line, provenance=prov,
                        message=(f"KV constraint {entries} on shape "
                                 f"{tuple(aval.shape)} does not match the "
                                 f"serve_rules_for table ({expected})")))

    if kv_specs and target.mesh is not None and not seen_kv_constraint:
        out.append(Violation(
            rule="kv-constraint-coverage", target=target.name, file="",
            line=0, provenance="<no sharding_constraint found>",
            message=("no sharding_constraint on any KV-cache-shaped value — "
                     "the cache layout is unpinned and GSPMD may reshard "
                     "the donated buffer every step")))

    if target.donate:
        out.extend(_check_donation(target, closed))
    return out


def audit_targets(targets) -> List[Violation]:
    out: List[Violation] = []
    for t in targets:
        out.extend(audit_target(t))
    return out
