"""Trip-count-aware static FLOP/byte accounting over serve-path jaxprs.

XLA's ``compiled.cost_analysis()`` counts every ``while``/``scan`` body
ONCE, not × trip-count (the costing.py docstring documents the exact
1/8-undercount on a length-8 scan), which is why the analytic model in
:mod:`repro.launch.costing` could only ever be validated on loop-free
single-layer configs. This module closes that gap from the other side:
it walks the traced jaxpr of every serve-path callable (recursing into
pjit / remat / custom-vjp bodies like :func:`~repro.analysis.jaxpr_audit
.iter_eqns`) and **multiplies loop-body costs by statically-extracted
trip counts** — ``scan`` carries its ``length`` in ``eqn.params``,
``pallas_call`` carries its grid, ``cond`` branches count at their
maximum. A ``while`` has no static trip count; rather than silently
undercounting (the exact failure mode the paper warns about: an
optimistic paper model diverging from the mapped design) it emits an
explicit ``audit-unbounded-loop`` diagnostic attributed to the innermost
``/src/repro/`` frame.

Counted quantities per target:

* ``flops`` — contraction FLOPs only (``dot_general`` at
  ``2 · |out| · K``, ``conv_general_dilated`` at
  ``2 · |out| · C_in/groups · Πk``), matching the analytic model's
  every-einsum convention (elementwise/norm FLOPs are deliberately
  excluded on both sides);
* ``gather_bytes`` / ``scatter_bytes`` — byte traffic of explicit
  gather/scatter ops (output resp. update size × itemsize), with the
  slice attributed to ``layers/attention.py`` split out as
  ``kv_gather_bytes`` — the paged-KV stream the engine's
  ``_kv_bytes_tick`` and ``benchmarks/roofline.py`` also price;
* ``pallas_stream_bytes`` — grid × block-shape input traffic of fused
  kernels (the *upper bound* the fused path touches; liveness-elided
  pages cannot be seen statically, so this is recorded, not reconciled);
* ``peak_bytes`` — peak live buffer bytes from a first-order linear-scan
  liveness over the jaxpr (loop bodies contribute one iteration's
  residency, call bodies their own peak);
* ``loops`` / ``unbounded`` — every loop-like eqn with its resolved trip
  count, or its diagnostic when unprovable.

Reconciliation (:func:`reconcile_target`): targets whose name maps to a
model-forward phase are compared against
:func:`repro.launch.costing.serve_target_cost` and drift beyond the
per-quantity tolerance raises an ``audit-cost-drift`` violation through
the same :class:`~repro.analysis.report.Violation` machinery as every
other rule. Helper targets (slot copies, samplers, pool maintenance)
have no analytic counterpart; they are recorded with ``analytic: null``
and never drift-checked — coverage is reported, not faked.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.jaxpr_audit import AuditTarget, _site, _subjaxprs, _trace
from repro.analysis.report import Violation

__all__ = ["StaticCost", "LoopRecord", "count_jaxpr", "cost_target",
           "reconcile_target", "cost_audit_targets", "FLOPS_RTOL",
           "KV_BYTES_RTOL", "DRIFT_PHASES"]

#: per-quantity drift tolerances (documented in docs/static-analysis.md):
#: FLOPs at the same ±2 % the loop-free validation in tests/test_costing.py
#: uses; KV gather bytes are exact by construction (both sides derive from
#: the same CacheSpec leaves) so anything past float noise is a bug.
FLOPS_RTOL = 0.02
KV_BYTES_RTOL = 1e-6

#: target phases with a model-forward analytic counterpart; everything
#: else (slot copies, samplers, pool maintenance) is recorded un-checked
DRIFT_PHASES = (
    "prefill", "decode", "verify", "prefill_chunk",
    "paged_decode", "paged_decode_hw", "paged_decode_fused",
    "paged_verify", "paged_verify_fused", "paged_suffix_prefill",
)

#: the file whose gathers stream the KV cache (gather_paged_kv and the
#: quantized-pool scale gathers live here)
_KV_GATHER_FILE = "src/repro/layers/attention.py"

#: call-like primitives whose single body executes exactly once per
#: enclosing execution (handled generically via _subjaxprs)
_SCATTER_PRIMS = ("scatter", "scatter-add", "scatter_add", "scatter-mul",
                  "scatter_mul", "scatter-min", "scatter-max",
                  "scatter_min", "scatter_max", "scatter_apply")


@dataclasses.dataclass
class LoopRecord:
    """One loop-like eqn: its kind, resolved trip count and source site."""

    kind: str                 # "scan" | "while" | "pallas_grid"
    length: Optional[int]     # None = statically unprovable
    path: str                 # nesting path, e.g. "pjit/scan"
    file: str
    line: int


@dataclasses.dataclass
class StaticCost:
    """Trip-count-corrected static counts for one traced callable."""

    flops: float = 0.0
    gather_bytes: float = 0.0
    scatter_bytes: float = 0.0
    kv_gather_bytes: float = 0.0
    pallas_stream_bytes: float = 0.0
    peak_bytes: float = 0.0
    arg_bytes: float = 0.0
    out_bytes: float = 0.0
    n_eqns: int = 0
    loops: List[LoopRecord] = dataclasses.field(default_factory=list)
    unbounded: List[LoopRecord] = dataclasses.field(default_factory=list)

    def merge_max(self, other: "StaticCost") -> None:
        """Elementwise max of the count fields (cond-branch policy: a
        branchy target is priced at its most expensive branch)."""
        for f in ("flops", "gather_bytes", "scatter_bytes",
                  "kv_gather_bytes", "pallas_stream_bytes"):
            setattr(self, f, max(getattr(self, f), getattr(other, f)))
        self.n_eqns += other.n_eqns
        self.loops.extend(other.loops)
        self.unbounded.extend(other.unbounded)


def _itemsize(dtype) -> int:
    try:
        return np.dtype(dtype).itemsize
    except TypeError:
        return 0                  # extended dtypes (PRNG keys): not counted


def _aval_bytes(aval) -> float:
    size = getattr(aval, "size", None)
    if size is None:
        return 0.0
    return float(size) * _itemsize(getattr(aval, "dtype", None))


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _dot_flops(eqn) -> float:
    """2 · |out| · K for a dot_general (K = Π contracting dims; an
    outer-product einsum has K = 1 and still costs 2/element — the same
    MAC convention the analytic model uses)."""
    (lhs_c, _), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval
    contract = _prod(lhs.shape[i] for i in lhs_c)
    return 2.0 * float(eqn.outvars[0].aval.size) * contract


def _conv_flops(eqn) -> float:
    """2 · |out| · (C_in / groups) · Π kernel-spatial."""
    dn = eqn.params["dimension_numbers"]
    rhs = eqn.invars[1].aval
    k_spatial = _prod(rhs.shape[i] for i in dn.rhs_spec[2:])
    in_ch = rhs.shape[dn.rhs_spec[1]]       # already / feature_group_count
    return 2.0 * float(eqn.outvars[0].aval.size) * in_ch * k_spatial


def _pallas_grid(eqn) -> Optional[int]:
    gm = eqn.params.get("grid_mapping")
    grid = getattr(gm, "grid", None)
    if grid is None:
        return None
    try:
        return _prod(int(g) for g in grid)
    except (TypeError, ValueError):
        return None                          # dynamic grid dims


def _pallas_stream_bytes(eqn, grid: int) -> float:
    """Grid × block-shape bytes of every input block — what the kernel's
    BlockSpecs cause to be streamed per full sweep (upper bound; index
    maps may revisit or elide pages, which is invisible statically)."""
    gm = eqn.params.get("grid_mapping")
    mappings = getattr(gm, "block_mappings", ()) or ()
    n_in = getattr(gm, "num_inputs", len(mappings))
    total = 0.0
    for bm in list(mappings)[:n_in]:
        aval = getattr(bm, "array_shape_dtype", None)
        shape = getattr(bm, "block_shape", None)
        if aval is None or shape is None:
            continue
        blk = _prod(int(s) for s in shape if s is not None)
        total += float(blk) * _itemsize(aval.dtype) * grid
    return total


def _loop_site(eqn, path: Tuple[str, ...], kind: str,
               length: Optional[int]) -> LoopRecord:
    file, line = _site(eqn)
    return LoopRecord(kind=kind, length=length,
                      path="/".join(path + (eqn.primitive.name,)),
                      file=file, line=line)


def count_jaxpr(jaxpr, *, mult: float = 1.0, path: Tuple[str, ...] = (),
                acc: Optional[StaticCost] = None) -> StaticCost:
    """Walk one (open) jaxpr, accumulating trip-count-weighted costs."""
    if acc is None:
        acc = StaticCost()
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        acc.n_eqns += 1

        if name == "dot_general":
            acc.flops += mult * _dot_flops(eqn)
        elif name == "conv_general_dilated":
            acc.flops += mult * _conv_flops(eqn)

        elif name == "gather":
            b = mult * sum(_aval_bytes(o.aval) for o in eqn.outvars)
            acc.gather_bytes += b
            # KV stream = gathers of the (pool, block, ...) cache tensors;
            # rank-<3 gathers at the same site are block-table/index
            # lookups, not KV traffic
            if (_site(eqn)[0] == _KV_GATHER_FILE
                    and getattr(eqn.invars[0].aval, "ndim", 0) >= 3):
                acc.kv_gather_bytes += b
        elif name in _SCATTER_PRIMS:
            # operand layout: (operand, indices, updates)
            upd = eqn.invars[2].aval if len(eqn.invars) >= 3 else None
            if upd is not None:
                acc.scatter_bytes += mult * _aval_bytes(upd)

        elif name == "scan":
            length = eqn.params.get("length")
            inner = eqn.params["jaxpr"].jaxpr
            if length is None:
                acc.unbounded.append(_loop_site(eqn, path, "scan", None))
                count_jaxpr(inner, mult=mult, path=path + (name,), acc=acc)
            else:
                acc.loops.append(_loop_site(eqn, path, "scan", int(length)))
                count_jaxpr(inner, mult=mult * int(length),
                            path=path + (name,), acc=acc)

        elif name == "while":
            # no static trip count — count the body ONCE and diagnose
            # loudly instead of silently undercounting
            acc.unbounded.append(_loop_site(eqn, path, "while", None))
            count_jaxpr(eqn.params["cond_jaxpr"].jaxpr, mult=mult,
                        path=path + (name,), acc=acc)
            count_jaxpr(eqn.params["body_jaxpr"].jaxpr, mult=mult,
                        path=path + (name,), acc=acc)

        elif name == "pallas_call":
            grid = _pallas_grid(eqn)
            inner = eqn.params.get("jaxpr")
            if grid is None:
                acc.unbounded.append(
                    _loop_site(eqn, path, "pallas_grid", None))
                grid = 1
            else:
                acc.loops.append(
                    _loop_site(eqn, path, "pallas_grid", grid))
                acc.pallas_stream_bytes += mult * _pallas_stream_bytes(
                    eqn, grid)
            if inner is not None and hasattr(inner, "eqns"):
                count_jaxpr(inner, mult=mult * grid, path=path + (name,),
                            acc=acc)

        elif name == "cond":
            branches = eqn.params.get("branches", ())
            branch_costs = []
            for br in branches:
                sub = br.jaxpr if hasattr(br, "jaxpr") else br
                branch_costs.append(count_jaxpr(
                    sub, mult=mult, path=path + (name,)))
            if branch_costs:
                worst = branch_costs[0]
                for bc in branch_costs[1:]:
                    worst.merge_max(bc)
                acc.flops += worst.flops
                acc.gather_bytes += worst.gather_bytes
                acc.scatter_bytes += worst.scatter_bytes
                acc.kv_gather_bytes += worst.kv_gather_bytes
                acc.pallas_stream_bytes += worst.pallas_stream_bytes
                acc.n_eqns += worst.n_eqns
                acc.loops.extend(worst.loops)
                acc.unbounded.extend(worst.unbounded)

        else:
            # pjit / remat / custom_jvp / custom_vjp / closed_call bodies
            # execute exactly once per enclosing execution
            for sub in _subjaxprs(eqn):
                count_jaxpr(sub, mult=mult, path=path + (name,), acc=acc)
    return acc


# ---------------------------------------------------------------------------
# peak live buffer bytes: first-order linear-scan liveness
# ---------------------------------------------------------------------------


def _peak_live_bytes(jaxpr) -> float:
    """Peak of Σ live-value bytes over a single in-order execution.

    First-order: inputs/constants are live until their last top-level
    use; an eqn's outputs go live before it executes; a call/loop body
    contributes its own (recursive) peak minus its argument bytes while
    its eqn executes — loop bodies count one iteration's residency
    (buffers are reused across iterations, which is the point of a loop).
    """
    last_use: Dict[Any, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if hasattr(v, "aval") and not hasattr(v, "val"):  # skip Literals
                last_use[v] = i
    for v in jaxpr.outvars:
        if hasattr(v, "aval") and not hasattr(v, "val"):
            last_use[v] = len(jaxpr.eqns)

    live: Dict[Any, float] = {}
    for v in tuple(jaxpr.invars) + tuple(jaxpr.constvars):
        if v in last_use:
            live[v] = _aval_bytes(v.aval)
    peak = sum(live.values())

    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.outvars:
            if v in last_use:
                live[v] = _aval_bytes(v.aval)
        inner_extra = 0.0
        for sub in _subjaxprs(eqn):
            sub = getattr(sub, "jaxpr", sub)      # unwrap ClosedJaxpr
            arg_bytes = sum(_aval_bytes(iv.aval)
                            for iv in tuple(sub.invars)
                            + tuple(sub.constvars))
            inner_extra = max(inner_extra,
                              _peak_live_bytes(sub) - arg_bytes)
        peak = max(peak, sum(live.values()) + max(inner_extra, 0.0))
        for v in list(live):
            if last_use.get(v, -1) <= i:
                del live[v]
    return peak


# ---------------------------------------------------------------------------
# per-target costing + reconciliation
# ---------------------------------------------------------------------------


def cost_target(target: AuditTarget) -> Tuple[StaticCost, List[Violation]]:
    """Trace one target and count its static costs; unprovable trip
    counts surface as ``audit-unbounded-loop`` violations (error on
    drift-checked phases — the reconciliation would silently undercount —
    warning on helper targets, whose counts are recorded, not checked)."""
    closed = _trace(target)
    cost = count_jaxpr(closed.jaxpr)
    cost.peak_bytes = _peak_live_bytes(closed.jaxpr)
    cost.arg_bytes = sum(_aval_bytes(v.aval) for v in closed.jaxpr.invars)
    cost.out_bytes = sum(_aval_bytes(v.aval) for v in closed.jaxpr.outvars)

    checked = target_phase(target.name) in DRIFT_PHASES
    violations = [
        Violation(
            rule="audit-unbounded-loop", target=target.name,
            file=lr.file, line=lr.line, provenance=lr.path,
            severity="error" if checked else "warning",
            message=(f"{lr.kind} with no statically-provable trip count — "
                     "its body is counted once, so every derived cost is "
                     "a lower bound" + (
                         " and the drift check against the analytic model "
                         "is unsound for this target" if checked else "")))
        for lr in cost.unbounded
    ]
    return cost, violations


def target_phase(name: str) -> str:
    """``"moe/paged_decode_hw@mesh"`` → ``"paged_decode_hw"``."""
    return name.split("/", 1)[1].split("@", 1)[0]


def _drift(static: float, analytic: float) -> float:
    if analytic == 0.0:
        return 0.0 if static == 0.0 else math.inf
    return static / analytic - 1.0


def reconcile_target(target: AuditTarget, static: StaticCost,
                     analytic: Optional[Dict[str, float]], *,
                     flops_rtol: float = FLOPS_RTOL,
                     kv_bytes_rtol: float = KV_BYTES_RTOL,
                     ) -> Tuple[Optional[Dict[str, float]], List[Violation]]:
    """Compare static counts against the analytic prediction.

    Returns ``(drift, violations)`` where ``drift`` maps quantity →
    signed relative drift (``static/analytic − 1``), or ``None`` when
    the target has no analytic counterpart.
    """
    if analytic is None:
        return None, []
    out: List[Violation] = []
    drift: Dict[str, float] = {}

    d = _drift(static.flops, analytic["flops"])
    drift["flops"] = d
    if abs(d) > flops_rtol:
        out.append(Violation(
            rule="audit-cost-drift", target=target.name, file="", line=0,
            provenance=f"phase={target_phase(target.name)}",
            message=(f"static contraction FLOPs {static.flops:.6g} vs "
                     f"analytic {analytic['flops']:.6g} "
                     f"(drift {d:+.2%}, tolerance ±{flops_rtol:.0%}) — "
                     "launch/costing.py and the traced computation "
                     "disagree")))

    kv_pred = analytic.get("kv_gather_bytes")
    if kv_pred is not None:
        d = _drift(static.kv_gather_bytes, kv_pred)
        drift["kv_gather_bytes"] = d
        if abs(d) > kv_bytes_rtol:
            out.append(Violation(
                rule="audit-cost-drift", target=target.name, file="",
                line=0, provenance=f"phase={target_phase(target.name)}",
                message=(f"static KV gather bytes "
                         f"{static.kv_gather_bytes:.6g} vs analytic "
                         f"{kv_pred:.6g} (drift {d:+.2%}) — "
                         "kv_bytes_per_token / _kv_bytes_tick / roofline "
                         "accounting has diverged from the traced gather")))
    return drift, out


def _loop_meta(cost: StaticCost) -> Dict[str, Any]:
    return {
        "scans": sum(1 for l in cost.loops if l.kind == "scan"),
        "pallas_grids": sum(1 for l in cost.loops
                            if l.kind == "pallas_grid"),
        "max_trip_count": max((l.length for l in cost.loops
                               if l.length is not None), default=0),
        "unbounded": len(cost.unbounded),
    }


def cost_audit_targets(targets: Sequence[AuditTarget], *,
                       flops_rtol: float = FLOPS_RTOL,
                       kv_bytes_rtol: float = KV_BYTES_RTOL,
                       ) -> Tuple[List[Dict[str, Any]], List[Violation]]:
    """Cost-audit a target list → (analysis-v2 target records, violations).

    Predictions come from :func:`repro.launch.costing.serve_target_cost`,
    keyed exactly the way ``targets.py`` keys its audit targets.
    """
    from repro.configs.registry import get_config, smoke_config
    from repro.launch.costing import serve_target_cost
    from repro.analysis.targets import (SMOKE_BY_FAMILY, AUDIT_SHAPE)

    cfgs = {fam: smoke_config(get_config(arch))
            for fam, arch in SMOKE_BY_FAMILY.items()}
    records: List[Dict[str, Any]] = []
    violations: List[Violation] = []
    for t in targets:
        cost, v = cost_target(t)
        violations.extend(v)
        phase = target_phase(t.name)
        analytic = None
        if phase in DRIFT_PHASES:
            analytic = serve_target_cost(cfgs[t.family], phase,
                                         **AUDIT_SHAPE)
            analytic = {k: v for k, v in analytic.items()
                        if k != "components"}
        drift, dv = reconcile_target(t, cost, analytic,
                                     flops_rtol=flops_rtol,
                                     kv_bytes_rtol=kv_bytes_rtol)
        violations.extend(dv)
        records.append({
            "target": t.name,
            "family": t.family,
            "phase": phase,
            "mesh": t.mesh is not None,
            "drift_checked": analytic is not None,
            "static": {
                "flops": cost.flops,
                "gather_bytes": cost.gather_bytes,
                "scatter_bytes": cost.scatter_bytes,
                "kv_gather_bytes": cost.kv_gather_bytes,
                "pallas_stream_bytes": cost.pallas_stream_bytes,
                "peak_bytes": cost.peak_bytes,
                "arg_bytes": cost.arg_bytes,
                "out_bytes": cost.out_bytes,
            },
            "analytic": analytic,
            "drift": drift,
            "loops": _loop_meta(cost),
        })
    return records, violations
