"""Violation records and the ``analysis-v1`` report schema.

The static auditor's output mirrors the serving benchmark records
(``serving-v1..v4``): a JSON document with a ``schema`` tag, validated by
the registry in ``scripts/check_bench_schema.py`` and uploaded as a CI
artifact. Keeping the report schema-checked means the CI gate can never
silently pass on a malformed (e.g. empty-by-accident) report.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

__all__ = ["ANALYSIS_SCHEMA", "ANALYSIS_V2_SCHEMA", "RULES", "Violation",
           "build_report", "build_cost_report"]

ANALYSIS_SCHEMA = "analysis-v1"
ANALYSIS_V2_SCHEMA = "analysis-v2"

#: rule id → one-line description (the catalog in docs/static-analysis.md)
RULES: Dict[str, str] = {
    "no-host-transfer": (
        "no device_put / host-callback primitives inside jitted serve-path "
        "callables"),
    "donation-honored": (
        "every donated argument's leaves appear in the lowering's "
        "input-output aliasing table"),
    "f32-upcast-allowlist": (
        "bf16/f16 -> f32 upcasts only at the named accumulation sites in "
        "layers/numerics.py and layers/attention.py"),
    "kv-constraint-coverage": (
        "KV-cache writes and gathers carry a sharding_constraint matching "
        "the serve_rules_for(family) table"),
    "determinism": (
        "bitwise-reproducible families: no PRNG primitives on deterministic "
        "paths, no model-axis collectives or constraints on ssm/hybrid"),
    "lint-jit-in-init": (
        "no per-instance jax.jit in __init__ — route through the module "
        "compile cache (_cached_jit)"),
    "lint-block-in-loop": (
        "no block_until_ready inside serve/ Python loops (engine ticks must "
        "stay async)"),
    "lint-jnp-in-loop": (
        "no jnp.* calls inside per-token Python loops in serve/ (one fused "
        "call per tick)"),
    "lint-moa-shim": (
        "no new imports of the deprecated repro.core.moa shim"),
    "lint-dead-module": (
        "every src/repro module is imported by something (dead-code census)"),
    "audit-cost-drift": (
        "trip-count-corrected static FLOP/byte counts of every serve-path "
        "jaxpr reconcile with launch/costing.py within tolerance"),
    "audit-unbounded-loop": (
        "every serve-path loop has a statically-provable trip count — a "
        "while with none makes every derived cost a silent lower bound"),
    "lint-stale-allow": (
        "every '# audit: allow(rule)' comment suppresses a live violation "
        "(a stale suppression hides nothing today and a regression "
        "tomorrow)"),
}


@dataclasses.dataclass(frozen=True)
class Violation:
    """One broken invariant, with source provenance.

    ``file``/``line`` point at the offending source site (for jaxpr rules,
    the innermost repro frame of the primitive's traceback); ``provenance``
    carries the jaxpr-side context (primitive name and nesting path) or the
    lint rule's AST context.
    """

    rule: str
    target: str
    file: str
    line: int
    message: str
    provenance: str = ""
    severity: str = "error"

    def format(self) -> str:
        loc = f"{self.file}:{self.line}" if self.file else "<unknown>"
        tail = f" [{self.provenance}]" if self.provenance else ""
        return f"{loc}: {self.rule} ({self.target}): {self.message}{tail}"


def build_report(violations: Sequence[Violation], *, targets_audited: int,
                 files_linted: int, config: Dict) -> Dict:
    """Assemble the ``analysis-v1`` record (see scripts/check_bench_schema)."""
    return {
        "schema": ANALYSIS_SCHEMA,
        "config": dict(config),
        "summary": {
            "targets_audited": int(targets_audited),
            "files_linted": int(files_linted),
            "violations": len(violations),
            "rules_checked": sorted(RULES),
        },
        "violations": [
            {
                "rule": v.rule,
                "severity": v.severity,
                "target": v.target,
                "file": v.file,
                "line": int(v.line),
                "message": v.message,
                "provenance": v.provenance,
            }
            for v in violations
        ],
    }


def build_cost_report(records: Sequence[Dict], violations: Sequence[Violation],
                      *, config: Dict) -> Dict:
    """Assemble the ``analysis-v2`` cost-audit record: per-target static
    vs. analytic FLOPs/bytes, drift ratios, and loop-accounting metadata
    (see scripts/check_bench_schema.py for the cross-field invariants)."""
    checked = [r for r in records if r.get("drift_checked")]
    max_abs_drift = 0.0
    for r in checked:
        for d in (r.get("drift") or {}).values():
            if d == d and abs(d) > abs(max_abs_drift):     # NaN-safe
                max_abs_drift = d
    return {
        "schema": ANALYSIS_V2_SCHEMA,
        "config": dict(config),
        "summary": {
            "targets_costed": len(records),
            "targets_drift_checked": len(checked),
            "violations": len(violations),
            "unbounded_loops": sum(r["loops"]["unbounded"] for r in records),
            "max_abs_drift": float(max_abs_drift),
        },
        "targets": [dict(r) for r in records],
        "violations": [
            {
                "rule": v.rule,
                "severity": v.severity,
                "target": v.target,
                "file": v.file,
                "line": int(v.line),
                "message": v.message,
                "provenance": v.provenance,
            }
            for v in violations
        ],
    }


def summarize(violations: List[Violation]) -> str:
    if not violations:
        return "analysis: clean (0 violations)"
    by_rule: Dict[str, int] = {}
    for v in violations:
        by_rule[v.rule] = by_rule.get(v.rule, 0) + 1
    parts = ", ".join(f"{r}={n}" for r, n in sorted(by_rule.items()))
    return f"analysis: {len(violations)} violation(s) ({parts})"
