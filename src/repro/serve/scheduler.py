"""Slot scheduler: admission bookkeeping for the continuous-batching engine.

Pure Python — no device work happens here. The engine owns the batched
cache; the scheduler decides *which request enters which slot when*.

Invariants (tested in ``tests/test_serving.py`` and property-tested in
``tests/test_scheduler_properties.py``; ``check()`` audits the structural
ones after any operation):

1. A slot is either free or bound to exactly one in-flight request.
2. Admission follows the *policy* order over **arrived** requests (a
   request is arrived once the engine clock reaches its ``arrival_s``):
   ``"fifo"`` orders by ``(arrival_s, uid)`` — exactly the historical
   behaviour — while ``"slo"`` orders by ``(priority desc, deadline asc,
   arrival_s, uid)`` (EDF within a priority class; no deadline sorts
   last). Ties beyond that break by submission order.
3. An admitted request fits its slot for its whole lifetime:
   ``prompt_len + max_new_tokens + spec_margin <= max_len`` (checked at
   submit; ``spec_margin`` is 0 unless the engine runs speculative decode,
   where it reserves room for the verify window's tentative writes).
4. ``prompt_len`` never exceeds the largest prefill bucket.
5. A freed slot's device state is garbage until the next admission
   overwrites it (the engine masks freed slots out of all metrics).
6. When an admission ``gate`` is installed (the paged engine's
   memory-aware rule: "free slot **and** enough free KV blocks"), a
   rejected head-of-queue request blocks everything behind it — the
   policy order is never reordered by backpressure. Admitted requests
   hold their worst-case block reservation, so under ``"fifo"`` they are
   never evicted; under ``"slo"`` the engine may *preempt* them (below),
   which keeps the reservation but frees the slot.
7. ``preempt(slot)`` unbinds an active request and returns it to the
   ready queue under the policy key; the slot is immediately free and
   the request is re-admissible exactly like a fresh arrival. A request
   is never simultaneously active and queued, and every preemption is
   recorded in ``preemption_log``.
"""

from __future__ import annotations

import heapq
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serve.request import Request

__all__ = ["SlotScheduler", "default_buckets"]


def default_buckets(max_len: int) -> Tuple[int, ...]:
    """Power-of-two prompt buckets, capped by a final ``max_len`` bucket:
    8, 16, 32, ..., max_len.

    Bucketing bounds the number of prefill shapes ``jax.jit`` ever sees to
    ``len(buckets)`` — prompts are right-padded up to the nearest bucket.
    The trailing ``max_len`` bucket ensures any prompt that fits the cache
    also fits a bucket (invariant 3 alone decides admissibility).
    """
    out, b = [], 8
    while b < max_len:
        out.append(b)
        b *= 2
    if not out or out[-1] != max_len:
        out.append(max_len)
    return tuple(out)


class SlotScheduler:
    """Policy-ordered admission of arrived requests into free decode slots.

    Two queues: ``_pending`` is a heap keyed by arrival time (requests the
    clock has not reached yet); once arrived, a request is *promoted* into
    ``_ready``, a heap keyed by the admission policy. Splitting the two
    keeps the policy key free to ignore arrival order (SLO mode) without
    ever admitting a request before its ``arrival_s``.
    """

    #: admission policies: FIFO (arrival order) or SLO (priority, then
    #: earliest deadline first)
    POLICIES = ("fifo", "slo")

    def __init__(self, n_slots: int, max_len: int,
                 buckets: Sequence[int] = (), spec_margin: int = 0,
                 policy: str = "fifo", clock=None):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if spec_margin < 0:
            raise ValueError("spec_margin must be >= 0")
        if policy not in self.POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"expected one of {self.POLICIES}")
        self.n_slots = n_slots
        self.max_len = max_len
        self.policy = policy
        #: virtual clock for methods called without an explicit ``now_s``
        #: (tests inject a deterministic one; the engine always passes
        #: ``now_s`` explicitly)
        self._clock = clock if clock is not None else time.monotonic
        #: extra cache rows reserved past every request's worst-case length
        #: (speculative decoding: a verify window of k draft tokens may
        #: tentatively write up to k rows past the final committed token,
        #: and those writes must stay inside the slot — invariant 3 becomes
        #: ``prompt + max_new_tokens + spec_margin <= max_len``)
        self.spec_margin = spec_margin
        self.buckets: Tuple[int, ...] = tuple(sorted(buckets)) \
            or default_buckets(max_len)
        self._free: List[int] = list(range(n_slots))   # min-heap: lowest id
        heapq.heapify(self._free)
        # arrival heap: (arrival_s, uid, submit_seq, request); the sequence
        # number breaks (arrival, uid) ties so Request never gets compared
        self._pending: List[Tuple[float, int, int, Request]] = []
        # ready heap: (*policy_key, request) — arrived, waiting for a slot
        self._ready: List[tuple] = []
        self._seq = itertools.count()
        self.active: Dict[int, Request] = {}           # slot -> request
        #: admission history [(uid, slot, engine_time_s)] — slot-reuse is
        #: observable here (a slot id appearing more than once)
        self.admission_log: List[Tuple[int, int, float]] = []
        #: preemption history [(uid, slot, engine_time_s)]
        self.preemption_log: List[Tuple[int, int, float]] = []

    # ---- policy ------------------------------------------------------------
    def _key(self, req: Request, seq: int) -> tuple:
        """Heap key ordering the ready queue (ends in ``(uid, seq)`` so
        entries are always totally ordered without comparing Requests)."""
        if self.policy == "slo":
            deadline = (req.deadline_s if req.deadline_s is not None
                        else float("inf"))
            return (-req.priority, deadline, req.arrival_s, req.uid, seq)
        return (req.arrival_s, req.uid, seq)

    def _promote(self, now_s: float) -> None:
        """Move every arrived request from the arrival heap to the ready
        heap (policy order takes over from arrival order)."""
        while self._pending and self._pending[0][0] <= now_s:
            _, _, seq, req = heapq.heappop(self._pending)
            heapq.heappush(self._ready, self._key(req, seq) + (req,))

    # ---- submission --------------------------------------------------------
    def submit(self, request: Request) -> None:
        """Queue a request for admission at its ``arrival_s`` (invariant 3
        and 4 checked here, so a bad request fails before taking a slot)."""
        p = request.prompt_len
        if p + request.max_new_tokens + self.spec_margin > self.max_len:
            margin = (f" + spec_margin {self.spec_margin}"
                      if self.spec_margin else "")
            raise ValueError(
                f"request {request.uid}: prompt {p} + max_new_tokens "
                f"{request.max_new_tokens}{margin} exceeds max_len "
                f"{self.max_len}")
        if p > self.buckets[-1]:
            raise ValueError(
                f"request {request.uid}: prompt {p} tokens exceeds the "
                f"largest prefill bucket {self.buckets[-1]}")
        heapq.heappush(self._pending, (request.arrival_s, request.uid,
                                       next(self._seq), request))

    def bucket_for(self, prompt_len: int) -> int:
        """Smallest bucket that fits ``prompt_len`` tokens."""
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(f"prompt_len {prompt_len} exceeds buckets "
                         f"{self.buckets}")

    # ---- admission ---------------------------------------------------------
    @property
    def has_pending(self) -> bool:
        """Anything still waiting (future arrivals or arrived-but-queued)."""
        return bool(self._pending or self._ready)

    @property
    def next_arrival_s(self) -> float:
        """Arrival time of the earliest *future* queued request (inf if
        none). Requests already promoted to the ready queue have arrived
        and do not appear here — they are waiting on a slot, not time."""
        return self._pending[0][0] if self._pending else float("inf")

    @property
    def has_free(self) -> bool:
        """True when at least one slot is unbound."""
        return bool(self._free)

    @property
    def has_ready(self) -> bool:
        """True when an arrived request is waiting on a slot (only
        meaningful after a ``_promote``-ing call like ``admit_ready`` or
        ``ready_head`` at the current engine time)."""
        return bool(self._ready)

    def ready_head(self, now_s: float) -> Optional[Request]:
        """Best admissible request under the policy at ``now_s`` (None if
        nothing has arrived). Promotes arrivals first, so the engine's
        preemption check sees exactly what ``admit_ready`` would admit."""
        self._promote(now_s)
        return self._ready[0][-1] if self._ready else None

    def admit_ready(self, now_s: Optional[float] = None, gate=None,
                    limit: int = 0) -> List[Tuple[int, Request]]:
        """Pop arrived requests into free slots in policy order; returns
        the new ``(slot, request)`` bindings (engine then prefills each).

        ``gate(request) -> bool`` vetoes admissions that a slot alone
        cannot satisfy (the paged engine's block-availability check); a
        vetoed head request stops the loop — invariant 6. ``limit`` caps
        admissions per call (0 = unlimited); the paged engine admits one
        at a time so each admission's allocation is visible to the next
        gate evaluation. ``now_s`` defaults to the scheduler's clock.
        """
        if now_s is None:
            now_s = self._clock()
        self._promote(now_s)
        admitted = []
        while self._free and self._ready:
            if limit and len(admitted) >= limit:
                break
            if gate is not None and not gate(self._ready[0][-1]):
                break
            req = heapq.heappop(self._ready)[-1]
            slot = heapq.heappop(self._free)
            self.active[slot] = req
            self.admission_log.append((req.uid, slot, now_s))
            admitted.append((slot, req))
        return admitted

    def admit_revivable(self, now_s: float,
                        revivable) -> Optional[Tuple[int, Request]]:
        """Admit the best ready request whose uid is in ``revivable``,
        skipping (but preserving) everything ahead of it.

        This is the engine's memory-stall escape hatch: a spilled
        (preempted, paged) request keeps its worst-case block reservation,
        so reviving it needs no new blocks and always makes progress even
        when the gate vetoes every fresh request at the head of the queue.
        Returns the ``(slot, request)`` binding, or None if no revivable
        request is ready or no slot is free.
        """
        if not self._free:
            return None
        self._promote(now_s)
        skipped: List[tuple] = []
        found = None
        while self._ready:
            entry = heapq.heappop(self._ready)
            if entry[-1].uid in revivable:
                found = entry[-1]
                break
            skipped.append(entry)
        for entry in skipped:
            heapq.heappush(self._ready, entry)
        if found is None:
            return None
        slot = heapq.heappop(self._free)
        self.active[slot] = found
        self.admission_log.append((found.uid, slot, now_s))
        return (slot, found)

    def release(self, slot: int) -> None:
        """Free a slot whose request finished (invariant 1: must be active)."""
        if slot not in self.active:
            raise KeyError(f"slot {slot} is not active")
        del self.active[slot]
        heapq.heappush(self._free, slot)

    def preempt(self, slot: int, now_s: Optional[float] = None) -> Request:
        """Unbind the request in ``slot`` and return it to the ready queue
        (invariant 7). The engine is responsible for spilling/snapshotting
        the slot's device state before calling this; the returned request
        is re-admissible immediately (its ``arrival_s`` has long passed).
        """
        if slot not in self.active:
            raise KeyError(f"slot {slot} is not active")
        if now_s is None:
            now_s = self._clock()
        req = self.active.pop(slot)
        heapq.heappush(self._free, slot)
        heapq.heappush(self._ready, self._key(req, next(self._seq)) + (req,))
        self.preemption_log.append((req.uid, slot, now_s))
        return req

    @property
    def done(self) -> bool:
        return not self._pending and not self._ready and not self.active

    def slot_reuse_count(self, start: int = 0) -> int:
        """Number of admissions (from ``admission_log[start:]``) that reused
        a slot occupied earlier *in that slice* — pass the log length at
        run start to get a per-run count on a reused engine."""
        seen, reused = set(), 0
        for _, slot, _ in self.admission_log[start:]:
            if slot in seen:
                reused += 1
            seen.add(slot)
        return reused

    # ---- auditing ----------------------------------------------------------
    def check(self) -> None:
        """Structural audit of invariants 1–4 and 7 (raises AssertionError).

        Cheap enough to run after every operation in property tests:
        free/active slots partition ``range(n_slots)``; no request is in
        two places at once; every tracked request satisfies the fit and
        bucket bounds; all three heaps are well-formed.
        """
        free = list(self._free)
        assert len(set(free)) == len(free), "duplicate free slot"
        assert not (set(free) & set(self.active)), \
            "slot both free and active"
        assert set(free) | set(self.active) == set(range(self.n_slots)), \
            "slots lost: free/active do not partition range(n_slots)"
        queued = [e[-1] for e in self._pending] + [e[-1] for e in self._ready]
        uids = [r.uid for r in queued] + [r.uid for r in self.active.values()]
        assert len(set(uids)) == len(uids), \
            "request queued/active in more than one place"
        for req in queued + list(self.active.values()):
            p = req.prompt_len
            assert p + req.max_new_tokens + self.spec_margin <= self.max_len
            assert p <= self.buckets[-1]
        # heap property (heapq is a plain list; corruption would silently
        # reorder admissions)
        for heap in (self._free, self._pending, self._ready):
            for i in range(1, len(heap)):
                assert heap[(i - 1) // 2] <= heap[i], "heap order violated"
