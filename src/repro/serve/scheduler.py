"""Slot scheduler: admission bookkeeping for the continuous-batching engine.

Pure Python — no device work happens here. The engine owns the batched
cache; the scheduler decides *which request enters which slot when*.

Invariants (tested in ``tests/test_serving.py`` and property-tested in
``tests/test_scheduler_properties.py``):

1. A slot is either free or bound to exactly one in-flight request.
2. Admission is FIFO over *arrived* requests (ties broken by uid): a
   request is arrived once the engine clock reaches its ``arrival_s``.
3. An admitted request fits its slot for its whole lifetime:
   ``prompt_len + max_new_tokens + spec_margin <= max_len`` (checked at
   submit; ``spec_margin`` is 0 unless the engine runs speculative decode,
   where it reserves room for the verify window's tentative writes).
4. ``prompt_len`` never exceeds the largest prefill bucket.
5. A freed slot's device state is garbage until the next admission
   overwrites it (the engine masks freed slots out of all metrics).
6. When an admission ``gate`` is installed (the paged engine's
   memory-aware rule: "free slot **and** enough free KV blocks"), a
   rejected head-of-queue request blocks everything behind it — FIFO is
   never reordered, so backpressure is preempt-free: admitted requests
   hold their worst-case block reservation and are never evicted.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Sequence, Tuple

from repro.serve.request import Request

__all__ = ["SlotScheduler", "default_buckets"]


def default_buckets(max_len: int) -> Tuple[int, ...]:
    """Power-of-two prompt buckets, capped by a final ``max_len`` bucket:
    8, 16, 32, ..., max_len.

    Bucketing bounds the number of prefill shapes ``jax.jit`` ever sees to
    ``len(buckets)`` — prompts are right-padded up to the nearest bucket.
    The trailing ``max_len`` bucket ensures any prompt that fits the cache
    also fits a bucket (invariant 3 alone decides admissibility).
    """
    out, b = [], 8
    while b < max_len:
        out.append(b)
        b *= 2
    if not out or out[-1] != max_len:
        out.append(max_len)
    return tuple(out)


class SlotScheduler:
    """FIFO admission of arrived requests into free decode slots."""

    def __init__(self, n_slots: int, max_len: int,
                 buckets: Sequence[int] = (), spec_margin: int = 0):
        if n_slots < 1:
            raise ValueError("need at least one slot")
        if spec_margin < 0:
            raise ValueError("spec_margin must be >= 0")
        self.n_slots = n_slots
        self.max_len = max_len
        #: extra cache rows reserved past every request's worst-case length
        #: (speculative decoding: a verify window of k draft tokens may
        #: tentatively write up to k rows past the final committed token,
        #: and those writes must stay inside the slot — invariant 3 becomes
        #: ``prompt + max_new_tokens + spec_margin <= max_len``)
        self.spec_margin = spec_margin
        self.buckets: Tuple[int, ...] = tuple(sorted(buckets)) \
            or default_buckets(max_len)
        self._free: List[int] = list(range(n_slots))   # min-heap: lowest id
        heapq.heapify(self._free)
        # arrival heap: (arrival_s, uid, submit_seq, request); the sequence
        # number breaks (arrival, uid) ties so Request never gets compared
        self._pending: List[Tuple[float, int, int, Request]] = []
        self._seq = itertools.count()
        self.active: Dict[int, Request] = {}           # slot -> request
        #: admission history [(uid, slot, engine_time_s)] — slot-reuse is
        #: observable here (a slot id appearing more than once)
        self.admission_log: List[Tuple[int, int, float]] = []

    # ---- submission --------------------------------------------------------
    def submit(self, request: Request) -> None:
        """Queue a request for admission at its ``arrival_s`` (invariant 3
        and 4 checked here, so a bad request fails before taking a slot)."""
        p = request.prompt_len
        if p + request.max_new_tokens + self.spec_margin > self.max_len:
            margin = (f" + spec_margin {self.spec_margin}"
                      if self.spec_margin else "")
            raise ValueError(
                f"request {request.uid}: prompt {p} + max_new_tokens "
                f"{request.max_new_tokens}{margin} exceeds max_len "
                f"{self.max_len}")
        if p > self.buckets[-1]:
            raise ValueError(
                f"request {request.uid}: prompt {p} tokens exceeds the "
                f"largest prefill bucket {self.buckets[-1]}")
        heapq.heappush(self._pending, (request.arrival_s, request.uid,
                                       next(self._seq), request))

    def bucket_for(self, prompt_len: int) -> int:
        """Smallest bucket that fits ``prompt_len`` tokens."""
        for b in self.buckets:
            if prompt_len <= b:
                return b
        raise ValueError(f"prompt_len {prompt_len} exceeds buckets "
                         f"{self.buckets}")

    # ---- admission ---------------------------------------------------------
    @property
    def has_pending(self) -> bool:
        return bool(self._pending)

    @property
    def next_arrival_s(self) -> float:
        """Arrival time of the earliest queued request (inf if none)."""
        return self._pending[0][0] if self._pending else float("inf")

    def admit_ready(self, now_s: float, gate=None,
                    limit: int = 0) -> List[Tuple[int, Request]]:
        """Pop arrived requests into free slots, FIFO; returns the new
        ``(slot, request)`` bindings (engine then prefills each).

        ``gate(request) -> bool`` vetoes admissions that a slot alone
        cannot satisfy (the paged engine's block-availability check); a
        vetoed head request stops the loop — invariant 6. ``limit`` caps
        admissions per call (0 = unlimited); the paged engine admits one
        at a time so each admission's allocation is visible to the next
        gate evaluation.
        """
        admitted = []
        while self._free and self._pending \
                and self._pending[0][0] <= now_s:
            if limit and len(admitted) >= limit:
                break
            if gate is not None and not gate(self._pending[0][3]):
                break
            _, _, _, req = heapq.heappop(self._pending)
            slot = heapq.heappop(self._free)
            self.active[slot] = req
            self.admission_log.append((req.uid, slot, now_s))
            admitted.append((slot, req))
        return admitted

    def release(self, slot: int) -> None:
        """Free a slot whose request finished (invariant 1: must be active)."""
        if slot not in self.active:
            raise KeyError(f"slot {slot} is not active")
        del self.active[slot]
        heapq.heappush(self._free, slot)

    @property
    def done(self) -> bool:
        return not self._pending and not self.active

    def slot_reuse_count(self, start: int = 0) -> int:
        """Number of admissions (from ``admission_log[start:]``) that reused
        a slot occupied earlier *in that slice* — pass the log length at
        run start to get a per-run count on a reused engine."""
        seen, reused = set(), 0
        for _, slot, _ in self.admission_log[start:]:
            if slot in seen:
                reused += 1
            seen.add(slot)
        return reused
