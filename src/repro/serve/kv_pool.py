"""Paged KV-cache block pool: allocator, ref-counted prefix cache, CoW plan.

Host-side bookkeeping only — no device arrays live here. The engine owns
the pooled device cache (``(stack, n_phys_blocks, block_size, ...)``
leaves); this module decides *which physical block holds which logical
block of which request*, exactly like the paper's lesson applied to cache
memory: one shared physical pool time-multiplexed across requests instead
of a dense ``n_slots x max_len`` region statically over-provisioned per
slot (Shen et al., arXiv:1607.00064, resource partitioning).

Physical block ids are ``1..n_blocks``; **id 0 is the trash block** — the
engine redirects writes for logical blocks it must not touch (shared
pages, padding beyond a request's table) to id 0, so every device write
keeps a static shape and shared content is never clobbered.

Three block states partition ``1..n_blocks``:

* **free** — on the free list, content garbage.
* **allocated** — ``refcount >= 1`` requests map a logical block here.
* **evictable** — ``refcount == 0`` but the block still holds prompt KV
  registered in the prefix trie; it is reclaimable (LRU) when the free
  list runs dry, and revivable by a later prefix match.

The prefix trie is keyed by the **exact token chain** from position 0 to
the block's end (a content hash with no collisions), so two requests
sharing a prompt prefix map their leading full blocks to the same physical
pages. A *partial* tail block (prompt length not block-aligned, or an
identical full prompt) may also be shared; the first divergent write —
the first generated token's KV — triggers copy-on-write into a spare
block that admission reserved, so backpressure stays preempt-free: a
request that is admitted never needs another block mid-flight.

Invariants (property-tested in ``tests/test_scheduler_properties.py``):

P1. free / allocated / evictable partition ``1..n_blocks``.
P2. refcounts are >= 1 for allocated blocks and never go negative:
    freeing a non-allocated block raises (no double-free).
P3. every trie entry points at an allocated or evictable block, each
    block has at most one trie entry, and the trie is **prefix-closed**:
    every block-aligned proper prefix of a registered chain is itself
    registered. Closure is what makes registered content *reachable* —
    ``plan`` matches full blocks front-to-back and a partial tail only
    behind a fully matched prefix — so LRU eviction must cascade: when a
    block is reclaimed, the chain suffix rooted below it is unregistered
    too (evictable descendants return to the free list; they could never
    be matched again and would otherwise squat in LRU as dead cache).
P4. ``alloc`` never returns a block that is still referenced.
P5. an admission plan's ``new_needed`` never exceeds ``available`` at the
    time ``can_admit`` approved it (the memory-aware admission rule).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

__all__ = ["AdmissionPlan", "BlockPool", "TRASH_BLOCK", "blocks_needed"]

#: physical id of the write-trash page (never allocated, never read).
TRASH_BLOCK = 0


def blocks_needed(prompt_len: int, max_new_tokens: int,
                  block_size: int) -> int:
    """Worst-case logical blocks a request needs over its whole lifetime.

    Token positions ``0 .. prompt_len + max_new_tokens - 1`` must be
    mappable (the final sampled token is never written back, so this
    over-reserves by at most one block — the price of a simple rule).
    """
    return -(-(prompt_len + max_new_tokens) // block_size)


@dataclasses.dataclass
class AdmissionPlan:
    """What admitting one request would do to the pool (no mutation yet).

    ``new_needed`` counts fresh allocations: every logical block not
    matched as a shared full block, **plus** a copy-on-write spare when
    the partial tail matched (the spare is what keeps admission
    preempt-free), which is why ``new_needed == n_logical - n_full``.
    """

    n_logical: int                    # table length in blocks
    full_matched: List[int]           # physical ids of matched full blocks
    tail_matched: Optional[int]       # physical id of a matched partial tail
    new_needed: int                   # fresh blocks to allocate

    @property
    def n_shared(self) -> int:
        return len(self.full_matched) + (1 if self.tail_matched else 0)


class BlockPool:
    """Fixed pool of ``n_blocks`` KV pages with a token-hash prefix trie."""

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 1:
            raise ValueError("need at least one block")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.n_blocks = n_blocks
        self.block_size = block_size
        # LIFO free list over ids n_blocks..1 so pop() hands out low ids
        # first (deterministic tests)
        self._free: List[int] = list(range(n_blocks, 0, -1))
        self._ref: Dict[int, int] = {}                  # id -> refcount >= 1
        # token-chain -> block id; chains are exact token tuples from
        # position 0 through the block's last stored token
        self._trie: Dict[Tuple[int, ...], int] = {}
        self._block_key: Dict[int, Tuple[int, ...]] = {}   # reverse of _trie
        self._evictable: "OrderedDict[int, None]" = OrderedDict()  # LRU
        # counters (engine metrics)
        self.hits = 0          # blocks served from the trie
        self.evictions = 0     # cached blocks reclaimed for new allocations

    # ---- capacity ----------------------------------------------------------
    @property
    def available(self) -> int:
        """Blocks allocatable right now (free + evictable cached)."""
        return len(self._free) + len(self._evictable)

    @property
    def in_use(self) -> int:
        """Blocks holding live (referenced) request state."""
        return len(self._ref)

    @property
    def resident(self) -> int:
        """Blocks holding data (referenced + cached-evictable)."""
        return len(self._ref) + len(self._evictable)

    # ---- allocation --------------------------------------------------------
    def _take(self) -> int:
        if self._free:
            bid = self._free.pop()
        elif self._evictable:
            bid, _ = self._evictable.popitem(last=False)   # LRU eviction
            self._evict_registration(bid)
            self.evictions += 1
        else:
            raise RuntimeError("block pool exhausted — admission gate "
                               "should have prevented this allocation")
        self._ref[bid] = 1
        return bid

    def _evict_registration(self, bid: int) -> None:
        """Unregister an evicted block *and* the chain suffix rooted below
        it (invariant P3's prefix closure).

        Dropping only the evicted block's own entry would strand every
        descendant chain: ``plan`` matches front-to-back, so a chain whose
        parent is gone can never be served again, yet its block would keep
        its trie entry and sit in the LRU queue as unreclaimable-by-match
        dead cache. Cascading keeps the trie prefix-closed; evictable
        descendants go straight back to the free list (their content is
        unreachable garbage now), while still-referenced descendants merely
        lose their registration and free normally when released.
        """
        root = self._block_key.get(bid)
        self._drop_registration(bid)
        if root is None:
            return
        bs = self.block_size
        if len(root) % bs:
            return      # partial-tail chains never have descendants
        dropped = {root}
        # length order visits parents before children, so one pass over a
        # snapshot unregisters the whole subtree under ``root``
        for chain in sorted(self._trie, key=len):
            aligned = (len(chain) - 1) // bs * bs
            if aligned and chain[:aligned] in dropped:
                dropped.add(chain)
                child = self._trie[chain]
                self._drop_registration(child)
                if child in self._evictable:
                    del self._evictable[child]
                    self._free.append(child)

    def alloc(self, n: int) -> List[int]:
        """Allocate ``n`` fresh blocks (refcount 1 each)."""
        if n > self.available:
            raise RuntimeError(
                f"asked for {n} blocks with only {self.available} available")
        return [self._take() for _ in range(n)]

    def share(self, block_id: int) -> None:
        """Add a reference to a matched block (reviving it if evictable)."""
        if block_id in self._ref:
            self._ref[block_id] += 1
        elif block_id in self._evictable:
            del self._evictable[block_id]
            self._ref[block_id] = 1
        else:
            raise KeyError(f"block {block_id} is not live (free or unknown)")
        self.hits += 1

    def free(self, block_id: int) -> None:
        """Drop one reference. At refcount 0 a trie-registered block turns
        evictable (content stays matchable); an unregistered one returns to
        the free list. Freeing a non-allocated block raises (no
        double-free)."""
        if block_id not in self._ref:
            raise KeyError(f"double free of block {block_id}")
        self._ref[block_id] -= 1
        if self._ref[block_id] == 0:
            del self._ref[block_id]
            if block_id in self._block_key:
                self._evictable[block_id] = None       # newest at LRU tail
            else:
                self._free.append(block_id)

    def refcount(self, block_id: int) -> int:
        return self._ref.get(block_id, 0)

    # ---- prefix trie -------------------------------------------------------
    def register(self, block_id: int, chain: Tuple[int, ...]) -> None:
        """Publish a prompt block's content under its token chain. A chain
        already registered (by a concurrent identical admission) keeps its
        first block; re-registering the same pair is a no-op."""
        if block_id not in self._ref:
            raise KeyError(f"cannot register non-allocated block {block_id}")
        chain = tuple(chain)
        if chain in self._trie or block_id in self._block_key:
            return
        self._trie[chain] = block_id
        self._block_key[block_id] = chain

    def match(self, chain: Tuple[int, ...]) -> Optional[int]:
        """Look up a token chain; returns the block id without referencing
        it (callers follow up with :meth:`share`)."""
        return self._trie.get(tuple(chain))

    def _drop_registration(self, block_id: int) -> None:
        key = self._block_key.pop(block_id, None)
        if key is not None:
            del self._trie[key]

    # ---- admission planning ------------------------------------------------
    def plan(self, prompt: Tuple[int, ...], max_new_tokens: int, *,
             match_tail: bool = True) -> AdmissionPlan:
        """Pure lookup: how the pool would serve this request.

        Walks the prompt in ``block_size`` chunks matching full blocks
        front-to-back (stopping at the first miss — a prefix property),
        then optionally the partial tail under the full-prompt chain.
        ``match_tail=False`` is the dense-family mode, where the tail is
        recomputed by the suffix prefill anyway.
        """
        bs = self.block_size
        p = len(prompt)
        n_logical = blocks_needed(p, max_new_tokens, bs)
        full_matched: List[int] = []
        for i in range(p // bs):
            bid = self.match(prompt[: (i + 1) * bs])
            if bid is None:
                break
            full_matched.append(bid)
        tail = None
        if match_tail and p % bs and len(full_matched) == p // bs:
            tail = self.match(prompt)
        return AdmissionPlan(
            n_logical=n_logical, full_matched=full_matched,
            tail_matched=tail,
            new_needed=n_logical - len(full_matched))

    def can_admit(self, prompt: Tuple[int, ...], max_new_tokens: int, *,
                  match_tail: bool = True) -> bool:
        """The memory-aware admission rule: enough blocks for the whole
        worst-case lifetime, counting prefix-cache hits as free.

        Matched blocks that are currently *evictable* still sit in
        ``available``, but admission will revive them (share), taking them
        off the allocatable set — so they must not double-count as both a
        hit and allocatable capacity.
        """
        plan = self.plan(prompt, max_new_tokens, match_tail=match_tail)
        matched = list(plan.full_matched)
        if plan.tail_matched is not None:
            matched.append(plan.tail_matched)
        revived = sum(1 for b in matched if b in self._evictable)
        return plan.new_needed <= self.available - revived

    # ---- invariants (test hook) -------------------------------------------
    def check(self) -> None:
        """Assert invariants P1-P3 (cheap; called from property tests)."""
        free, alloc = set(self._free), set(self._ref)
        evict = set(self._evictable)
        assert not (free & alloc) and not (free & evict) \
            and not (alloc & evict), "block states overlap"
        assert free | alloc | evict == set(range(1, self.n_blocks + 1)), \
            "block states do not partition the pool"
        assert all(c >= 1 for c in self._ref.values()), "refcount < 1"
        assert set(self._block_key) <= alloc | evict, \
            "trie entry points at a free block"
        assert {self._trie[k] for k in self._trie} == set(self._block_key), \
            "trie and reverse map disagree"
        for bid, key in self._block_key.items():
            assert self._trie.get(key) == bid, "trie reverse-map mismatch"
        bs = self.block_size
        for chain in self._trie:
            aligned = (len(chain) - 1) // bs * bs
            assert aligned == 0 or chain[:aligned] in self._trie, \
                "trie lost prefix closure (orphaned chain suffix)"
