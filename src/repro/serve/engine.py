"""Continuous-batching engine: slot-scheduled prefill + batched decode.

One engine tick = (admit arrived requests into free slots via bucketed
prefill) + (one batched ``decode_step`` over all slots). The batched cache
holds every slot's KV/SSM state with a **per-slot position vector**
(``cache["pos"]: (n_slots,) int32``), so slots sit at heterogeneous
context lengths inside a single jitted decode step — the paper's serial
accumulator with one accumulator per slot.

Shape discipline (everything ``jax.jit`` sees is from a fixed set):
  * decode: always ``(n_slots, 1)`` tokens against the same cache shapes;
  * prefill: one shape per prompt bucket (attention families right-pad and
    pass ``prompt_len``; SSM/hybrid compile one prefill per exact length
    because pad tokens would pollute the recurrent state — see
    ``docs/serving.md``);
  * sampling: one ``(n_slots, vocab)`` mixed-policy call.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.costing import request_decode_cost
from repro.serve.metrics import RequestMetrics, aggregate
from repro.serve.request import FinishReason, Request, RequestResult
from repro.serve.sampling import sample_batch
from repro.serve.scheduler import SlotScheduler

__all__ = ["ServeEngine"]


@dataclasses.dataclass
class _Inflight:
    """Host-side state of one admitted request (device state lives in the
    engine's batched cache at ``slot``)."""

    request: Request
    slot: int
    generated: List[int]
    next_token: int
    metrics: RequestMetrics


def _write_slot(cache: dict, pre: dict, slot):
    """Copy a batch=1 prefill cache into row ``slot`` of the batched cache.

    Every non-``pos`` leaf is laid out ``(stack, batch, ...)`` (layer or
    app-point stack first, batch axis second) in all model families;
    ``pos`` is the per-slot position vector and takes the prefill's scalar
    cursor. Jitted with the batched cache donated.
    """
    out = {}
    for key, big in cache.items():
        if key == "pos":
            out[key] = big.at[slot].set(pre["pos"].astype(big.dtype))
        else:
            out[key] = jax.tree.map(
                lambda b, s: b.at[:, slot].set(s[:, 0].astype(b.dtype)),
                big, pre[key])
    return out


class ServeEngine:
    """Continuous-batching server over a :class:`repro.models.api.Model`.

    Parameters
    ----------
    model, params:
        A built model and its parameters. Any decode-capable *text*
        family (dense / MoE / SSM / hybrid); VLM is rejected — the engine
        feeds token-only prompts.
    n_slots:
        Decode batch width — the number of requests in flight at once.
    max_len:
        Per-slot context capacity in tokens (prompt + generation).
    prompt_buckets:
        Prefill shape set (tokens); defaults to powers of two up to
        ``max_len``. Attention families right-pad prompts up to a bucket.
    rng:
        Key for sampled (non-greedy) requests. Defaults to ``PRNGKey(0)``.
    clock:
        Monotonic time source in seconds (injectable for deterministic
        tests). Idle gaps before the next arrival are fast-forwarded, so a
        frozen clock still makes progress.
    """

    def __init__(self, model, params, *, n_slots: int, max_len: int,
                 prompt_buckets: Sequence[int] = (), rng=None,
                 clock: Callable[[], float] = time.monotonic):
        if model.cfg.family == "encoder":
            raise ValueError("encoder-only arch has no decode step")
        if model.cfg.family == "vlm":
            raise ValueError("vlm serving is not supported: the engine "
                             "feeds token-only prompts, but vlm prefill "
                             "needs a patch batch")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.scheduler = SlotScheduler(n_slots, max_len,
                                       [b for b in prompt_buckets
                                        if b <= max_len])
        self._clock = clock
        self._rng = jax.random.PRNGKey(0) if rng is None else rng
        self._padded = model.supports_padded_prefill

        cache = model.init_cache(n_slots, max_len)
        cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.cache = cache

        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        if self._padded:
            self._prefill = jax.jit(
                lambda p, b, pl: model.prefill(p, b, max_len=max_len,
                                               prompt_len=pl))
        else:
            self._prefill = jax.jit(
                lambda p, b: model.prefill(p, b, max_len=max_len))
        self._write = jax.jit(_write_slot, donate_argnums=(0,))
        self._sample = jax.jit(sample_batch)

        self._inflight: Dict[int, _Inflight] = {}
        self._steps = 0
        self._occupancy_sum = 0.0
        self._fast_forward_s = 0.0

    # ---- time --------------------------------------------------------------
    def _now(self, t_start: float) -> float:
        """Engine clock in seconds: wall time plus fast-forwarded idle."""
        return (self._clock() - t_start) + self._fast_forward_s

    # ---- lifecycle ---------------------------------------------------------
    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def _admit(self, slot: int, req: Request, now_s: float,
               results: List[RequestResult]) -> None:
        """Prefill ``req`` into ``slot`` and seed its first token."""
        p = req.prompt_len
        prompt = req.prompt_array()
        if self._padded:
            bucket = self.scheduler.bucket_for(p)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :p] = prompt[0]
            logits, pre = self._prefill(self.params, {"tokens": toks},
                                        jnp.asarray(p, jnp.int32))
        else:
            logits, pre = self._prefill(self.params, {"tokens": prompt})
        first = int(np.asarray(req.sampler(
            logits[:, -1], None if req.sampler.greedy else self._next_key()))[0])
        self.cache = self._write(self.cache, pre, slot)
        t_first = self._now(self._t_start)
        metrics = RequestMetrics(arrival_s=req.arrival_s, admitted_s=now_s,
                                 first_token_s=t_first, prompt_tokens=p)
        inf = _Inflight(request=req, slot=slot, generated=[first],
                        next_token=first, metrics=metrics)
        if first == req.eos_id or req.max_new_tokens == 1:
            self._finish(inf, t_first, results)
        else:
            self._inflight[slot] = inf

    def _finish(self, inf: _Inflight, now_s: float,
                results: List[RequestResult]) -> None:
        """Close out a request: metrics and slot release (MOA pricing is
        deferred to the end of ``run`` — it is an O(new_tokens) host loop
        and must not stall the decode ticks of the remaining slots)."""
        m = inf.metrics
        m.finished_s = now_s
        m.new_tokens = len(inf.generated)
        reason = (FinishReason.EOS
                  if inf.generated[-1] == inf.request.eos_id
                  else FinishReason.LENGTH)
        results.append(RequestResult(
            uid=inf.request.uid,
            tokens=np.asarray(inf.generated, np.int32),
            prompt_len=m.prompt_tokens, slot=inf.slot,
            finish_reason=reason, metrics=m))
        self.scheduler.release(inf.slot)
        self._inflight.pop(inf.slot, None)

    def _decode_tick(self, results: List[RequestResult]) -> None:
        """One batched decode step over all slots; advance active requests."""
        toks = np.zeros((self.n_slots, 1), np.int32)
        temps = np.zeros((self.n_slots,), np.float32)
        greedy = np.ones((self.n_slots,), bool)
        for slot, inf in self._inflight.items():
            toks[slot, 0] = inf.next_token
            temps[slot] = max(inf.request.sampler.temperature, 0.0)
            greedy[slot] = inf.request.sampler.greedy
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks))
        next_toks = np.asarray(self._sample(
            logits[:, -1], jnp.asarray(temps), jnp.asarray(greedy),
            self._next_key()))
        self._steps += 1
        self._occupancy_sum += len(self._inflight) / self.n_slots
        now = self._now(self._t_start)
        for slot in sorted(self._inflight):
            inf = self._inflight[slot]
            tok = int(next_toks[slot])
            inf.generated.append(tok)
            inf.next_token = tok
            if tok == inf.request.eos_id \
                    or len(inf.generated) >= inf.request.max_new_tokens:
                self._finish(inf, now, results)

    # ---- public API --------------------------------------------------------
    def submit(self, request: Request) -> None:
        """Queue a request (admitted when arrived and a slot frees up)."""
        self.scheduler.submit(request)

    def run(self, requests: Sequence[Request] = (),
            max_steps: Optional[int] = None
            ) -> Tuple[List[RequestResult], dict]:
        """Serve until every submitted request completes.

        Returns ``(results sorted by uid, report)`` where ``report`` is the
        JSON-able aggregate from :func:`repro.serve.metrics.aggregate` plus
        ``slot_reuse`` (admissions into a previously-used slot this run).
        ``max_steps`` is a runaway backstop, not a budget: exceeding it
        raises RuntimeError (default 1e6 decode ticks).
        """
        for r in requests:
            self.submit(r)
        results: List[RequestResult] = []
        # per-run counters: a reused engine (submit + repeated run) must not
        # carry stale fast-forward offsets, occupancy sums, or prior-run
        # admissions into its report
        self._steps = 0
        self._occupancy_sum = 0.0
        self._fast_forward_s = 0.0
        log_start = len(self.scheduler.admission_log)
        self._t_start = self._clock()
        limit = max_steps if max_steps is not None else 1_000_000
        while not self.scheduler.done:
            now = self._now(self._t_start)
            if not self.scheduler.active \
                    and self.scheduler.next_arrival_s > now:
                # idle: fast-forward the engine clock to the next arrival
                self._fast_forward_s += self.scheduler.next_arrival_s - now
                now = self._now(self._t_start)
            for slot, req in self.scheduler.admit_ready(now):
                self._admit(slot, req, now, results)
            if self._inflight:
                self._decode_tick(results)
            if self._steps >= limit:
                raise RuntimeError(
                    f"serve engine exceeded {limit} decode steps with "
                    f"{len(self._inflight)} requests still in flight")
        wall = self._now(self._t_start)
        for r in results:
            r.metrics.moa_flops = request_decode_cost(
                self.model.cfg, prompt_tokens=r.metrics.prompt_tokens,
                new_tokens=r.metrics.new_tokens)
        report = aggregate(results, n_slots=self.n_slots,
                           decode_steps=self._steps,
                           occupancy_sum=self._occupancy_sum, wall_s=wall)
        report["slot_reuse"] = self.scheduler.slot_reuse_count(log_start)
        report["arch"] = self.model.cfg.name
        report["moa"] = self.model.cfg.moa_strategy.spec
        results.sort(key=lambda r: r.uid)
        return results, report
