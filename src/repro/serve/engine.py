"""Continuous-batching engine: slot-scheduled prefill + batched decode.

One engine tick = (admit arrived requests into free slots via bucketed
prefill) + (one batched ``decode_step`` over all slots). The batched cache
holds every slot's KV/SSM state with a **per-slot position vector**
(``cache["pos"]: (n_slots,) int32``), so slots sit at heterogeneous
context lengths inside a single jitted decode step — the paper's serial
accumulator with one accumulator per slot.

Two cache layouts (``docs/paged-kv.md``):

* **dense slots** (default): every slot statically reserves a
  ``max_len``-token KV region — simple, but over-provisioned exactly the
  way the paper warns against for any shared resource;
* **paged** (``paged=True``): KV lives in a shared pool of fixed-size
  physical pages mapped through per-slot block tables
  (:mod:`repro.serve.kv_pool`). Requests sharing a prompt prefix share
  physical pages (ref-counted, copy-on-write at the first divergent
  write), admission requires "free slot **and** enough free blocks"
  (preempt-free backpressure), and on the dense family a prefix-cache hit
  skips recomputing the shared prefill blocks entirely.

A :class:`~repro.serve.spec.Drafter` switches the decode tick to
**speculative** mode (``docs/spec-decode.md``): draft ``k`` tokens per
slot, score them in one ``(n_slots, k+1)`` ``verify_step``, commit each
slot's accepted prefix — up to ``k + 1`` tokens per tick, rejection being
a per-slot cursor rewind (plus a state-snapshot restore for recurrent
families).

**SLO-aware serving** (``docs/slo-scheduling.md``): with
``prefill_chunk_tokens`` set, long prompts prefill in fixed-budget
chunks interleaved with decode ticks, so an in-flight request's
inter-token latency is bounded by one chunk instead of one whole prompt.
With ``scheduling="slo"`` the scheduler admits by (priority, earliest
deadline) and the engine may *preempt* a running request whose deadline
is later than a waiting one's: its device state is spilled (dense slots:
a slot-row snapshot; paged: the block table is pinned and only the
per-slot state is snapshotted), the slot is handed over, and the victim
is revived later with bit-identical continuation. Both features preserve
greedy-token parity with the one-shot FIFO engine.

Shape discipline (everything ``jax.jit`` sees is from a fixed set):
  * decode: always ``(n_slots, 1)`` tokens against the same cache shapes;
  * speculative verify: always ``(n_slots, k + 1)`` tokens, one shape;
  * prefill: one shape per prompt bucket (attention families right-pad and
    pass ``prompt_len``; SSM/hybrid compile one prefill per exact length
    because pad tokens would pollute the recurrent state — see
    ``docs/serving.md``); suffix prefill adds one shape per
    (prefix blocks, suffix bucket) pair;
  * sampling: one ``(n_slots, vocab)`` mixed-policy call.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.launch.costing import request_decode_cost, spec_request_decode_cost
from repro.layers.attention import resolve_attn_backend
from repro.parallel import (activate, replicate_uneven_kv_heads,
                            serve_cache_shardings, serve_rules_for)
from repro.serve.kv_pool import TRASH_BLOCK, BlockPool, blocks_needed
from repro.serve.metrics import (RequestMetrics, aggregate, paged_report,
                                 slo_report, spec_report)
from repro.serve.request import FinishReason, Request, RequestResult
from repro.serve.sampling import sample_batch
from repro.serve.scheduler import SlotScheduler
from repro.serve.spec import Drafter, verify_accept

__all__ = ["ServeEngine"]


# ---------------------------------------------------------------------------
# Compilation cache: engine callables are jitted once per
# (model config, cache layout, mesh) — constructing a second engine on the
# same model (dense + paged + spec benchmark sweeps) reuses the jitted
# functions and their XLA executables instead of recompiling everything.
# ---------------------------------------------------------------------------

_COMPILE_CACHE: Dict[tuple, Callable] = {}


def _cache_size() -> int:
    """Number of cached jitted callables (test probe: constructing a second
    engine with an identical layout must not grow this)."""
    return len(_COMPILE_CACHE)


def _clear_compile_cache() -> None:
    _COMPILE_CACHE.clear()


def _cached_jit(key: tuple, build: Callable[[], Callable]) -> Callable:
    fn = _COMPILE_CACHE.get(key)
    if fn is None:
        fn = _COMPILE_CACHE[key] = build()
    return fn


@dataclasses.dataclass
class _Inflight:
    """Host-side state of one admitted request (device state lives in the
    engine's batched cache at ``slot``)."""

    request: Request
    slot: int
    generated: List[int]
    next_token: int
    metrics: RequestMetrics
    #: spec mode: committed context length at each verify tick this
    #: request was active (feeds the acceptance-aware FLOPs pricing)
    tick_contexts: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Prefilling:
    """Host-side state of one request mid-chunked-prefill.

    The slot is scheduler-active but not yet in ``_inflight`` — no token
    has been emitted. Paged: the block table is planned up front but the
    slot's installed row stays all-trash (pos 0) until the final chunk,
    so interleaved decode ticks write only to the trash page. Dense
    attention: per-chunk suffix KV accumulates in ``kv_parts`` and the
    final chunk assembles + writes the whole slot row at once.
    """

    request: Request
    slot: int
    admitted_s: float
    done: int                 # prompt tokens already consumed
    chunks: int = 0
    #: recurrent families: carried cache-shaped state between chunks
    state: Optional[dict] = None
    #: attention families, dense slots: accumulated per-chunk suffix KV
    kv_parts: List = dataclasses.field(default_factory=list)
    plan: Optional[object] = None
    table: Optional["_SlotTable"] = None
    cached_tokens: int = 0


@dataclasses.dataclass
class _SlotTable:
    """Host mirror of one slot's block table (paged mode).

    ``shared`` marks logical blocks currently mapped to ref-shared pages
    (writes must not land there — admission redirects them to the trash
    page, and the reserved ``cow_spare`` absorbs the first divergent
    write).
    """

    blocks: List[int]
    shared: Set[int]
    cow_spare: Optional[int] = None
    tail_idx: Optional[int] = None


def _write_slot(cache: dict, pre: dict, slot):
    """Copy a batch=1 prefill cache into row ``slot`` of the batched cache.

    Every non-``pos`` leaf is laid out ``(stack, batch, ...)`` (layer or
    app-point stack first, batch axis second) in all model families;
    ``pos`` is the per-slot position vector and takes the prefill's scalar
    cursor. Jitted with the batched cache donated.
    """
    out = {}
    for key, big in cache.items():
        if key == "pos":
            out[key] = big.at[slot].set(pre["pos"].astype(big.dtype))
        else:
            out[key] = jax.tree.map(
                lambda b, s: b.at[:, slot].set(s[:, 0].astype(b.dtype)),
                big, pre[key])
    return out


def _read_slot(cache: dict, slot):
    """Exact inverse of :func:`_write_slot`: snapshot row ``slot`` of the
    batched cache as a batch=1 prefill-shaped tree (preemption spill).
    ``_write_slot(_read_slot(cache, s), s)`` round-trips bit-identically —
    both sides are pure gathers/scatters in the cache dtype."""
    out = {}
    for key, big in cache.items():
        if key == "pos":
            out[key] = big[slot]
        else:
            # gather-then-expand: plain slicing needs static bounds under
            # jit, and ``b[:, slot]`` gathers fine with a traced index
            out[key] = jax.tree.map(lambda b: b[:, slot][:, None], big)
    return out


def _read_paged_slot(cache, slot, *, has_ssm):
    """Snapshot a paged slot's per-slot dense state (cursor + recurrent
    state). The KV itself is NOT copied — the spilled request keeps its
    ref-counted pool pages pinned, so only the slot-indexed leaves move."""
    out = {"pos": cache["pos"][slot]}
    if has_ssm:
        out["ssm"] = jax.tree.map(lambda b: jnp.expand_dims(b[:, slot], 1),
                                  cache["ssm"])
    return out


def _restore_paged_slot(cache, snap, table_row, slot, *, has_ssm):
    """Revive a spilled paged request into ``slot``: reinstall its block
    table row and cursor, and restore any recurrent state."""
    out = dict(cache)
    out["block_tables"] = cache["block_tables"].at[slot].set(table_row)
    out["pos"] = cache["pos"].at[slot].set(
        snap["pos"].astype(cache["pos"].dtype))
    if has_ssm:
        out["ssm"] = jax.tree.map(
            lambda b, s: b.at[:, slot].set(s[:, 0].astype(b.dtype)),
            cache["ssm"], snap["ssm"])
    return out


# ---- paged device helpers (module-level so the compile cache can share
# them across engine instances; static layout via functools.partial) -------


def _gather_prefix(pool, ids, *, cdtype):
    """Cached prefix pages → dense ``(L, 1, P, Hk, D)`` K/V (compute
    dtype; dequantized if the pool is int8)."""
    from repro.layers.attention import dequantize_kv

    def flat(name):
        x = pool[name][:, ids]                   # (L, n, bs, ...)
        return x.reshape((x.shape[0], 1, -1) + x.shape[3:])

    k, v = flat("k"), flat("v")
    if "k_scale" in pool:
        k = dequantize_kv(k, flat("k_scale"), cdtype)
        v = dequantize_kv(v, flat("v_scale"), cdtype)
    return {"k": k, "v": v}


def _paged_write(cache, pre_kv, pre_state, write_ids, table_row, slot,
                 pre_pos, *, kv_key):
    """Scatter a prefill's K/V into the pool pages named by ``write_ids``
    (one per written logical block; shared/overhang blocks arrive
    redirected to the trash page), install the slot's block-table row +
    position, and write any per-slot dense state."""
    out = dict(cache)
    nb = write_ids.shape[0]

    def w(pool_leaf, s):
        s = s[:, 0]                              # (stack, S, ...)
        s = s.reshape((s.shape[0], nb, s.shape[1] // nb) + s.shape[2:])
        return pool_leaf.at[:, write_ids].set(s.astype(pool_leaf.dtype))

    out[kv_key] = jax.tree.map(w, cache[kv_key], pre_kv)
    if pre_state is not None:
        out["ssm"] = jax.tree.map(
            lambda b, s: b.at[:, slot].set(s[:, 0].astype(b.dtype)),
            cache["ssm"], pre_state)
    out["block_tables"] = cache["block_tables"].at[slot].set(table_row)
    out["pos"] = cache["pos"].at[slot].set(
        pre_pos.astype(cache["pos"].dtype))
    return out


def _cow_copy(cache, src, dst, slot, logical_idx, *, kv_key):
    """Copy-on-write: duplicate page ``src`` into the reserved spare
    ``dst`` and repoint this slot's table entry, so the imminent divergent
    write lands on a private page."""
    out = dict(cache)
    out[kv_key] = jax.tree.map(
        lambda p: p.at[:, dst].set(p[:, src]), cache[kv_key])
    out["block_tables"] = \
        cache["block_tables"].at[slot, logical_idx].set(dst)
    return out


def _clear_slot(cache, slot):
    """Point a freed slot's table at the trash page and rewind its cursor:
    its (masked-out) decode writes can then never corrupt pages
    reallocated to live requests."""
    out = dict(cache)
    out["block_tables"] = cache["block_tables"].at[slot].set(TRASH_BLOCK)
    out["pos"] = cache["pos"].at[slot].set(0)
    return out


class ServeEngine:
    """Continuous-batching server over a :class:`repro.models.api.Model`.

    Parameters
    ----------
    model, params:
        A built model and its parameters. Any decode-capable *text*
        family (dense / MoE / SSM / hybrid); VLM is rejected — the engine
        feeds token-only prompts.
    n_slots:
        Decode batch width — the number of requests in flight at once.
    max_len:
        Per-slot context capacity in tokens (prompt + generation).
    prompt_buckets:
        Prefill shape set (tokens); defaults to powers of two up to
        ``max_len``. Attention families right-pad prompts up to a bucket.
    paged:
        Use the paged KV pool instead of dense per-slot cache regions.
        Requires a KV-bearing family (dense / MoE / hybrid — pure SSM has
        nothing to page) and ``block_size`` dividing ``max_len`` (which
        makes the gathered paged view shape-identical to the dense cache,
        the key to bit-identical decode).
    block_size:
        Tokens per physical KV page (paged mode).
    n_blocks:
        Physical pages in the pool (paged mode). Defaults to the dense
        equivalent ``n_slots * max_len / block_size``; smaller values
        trade capacity for admission backpressure.
    rng:
        Key for sampled (non-greedy) requests. Defaults to ``PRNGKey(0)``.
    drafter:
        A :class:`repro.serve.spec.Drafter` switches the decode tick to
        *speculative* mode: each tick proposes ``drafter.k`` tokens per
        slot, scores them in one ``verify_step``, and commits the accepted
        prefix — up to ``k + 1`` tokens per tick instead of 1 (see
        ``docs/spec-decode.md``). Requires ``model.supports_spec_decode``.
        The scheduler then reserves a ``k``-row margin per request
        (tentative verify writes must stay inside the slot), and paged
        admission reserves the matching extra blocks.
    mesh:
        A ``jax.sharding.Mesh`` runs the engine sharded (see
        ``docs/sharded-serving.md``): parameters land tensor-parallel (heads / ff /
        experts on the ``model`` axis per ``rules``), the KV cache shards
        slots over ``data`` and KV heads over ``model``, and every jitted
        callable carries explicit NamedSharding in/out specs (donation
        preserved) so decode steps run without resharding transfers.
        Greedy decode is bit-identical to the single-device engine.
    rules:
        :class:`repro.parallel.ShardingRules` for the mesh; defaults to
        :func:`repro.parallel.serve_rules_for` of the model family (full
        TP/EP for attention families, data-parallel for recurrent ones —
        the bitwise-reproducible table).
    clock:
        Monotonic time source in seconds (injectable for deterministic
        tests — a :class:`repro.serve.clock.StepClock` turns the engine
        into an exact discrete-event simulator). Idle gaps before the
        next arrival are fast-forwarded, so a frozen clock still makes
        progress.
    prefill_chunk_tokens:
        Split prompts longer than this into fixed-budget prefill chunks,
        one chunk per engine tick, interleaved with decode ticks (None =
        one-shot prefill). Must be a multiple of the model's
        ``prefill_chunk_alignment`` (``cfg.ssd_chunk`` for recurrent
        families) and, paged, of ``block_size``; chunked prefill is
        greedy-token bit-identical to one-shot (``docs/slo-scheduling.md``
        — chunk-size guidance in
        :func:`repro.launch.costing.prefill_chunk_guidance`).
    scheduling:
        ``"fifo"`` (default, historical behaviour) or ``"slo"``: admit by
        (priority, earliest deadline) and preempt a running request when
        a waiting one has a strictly earlier deadline and no slot is
        free. Preemption spills the victim's state (dense: slot-row
        snapshot; paged: pinned block table + per-slot state) and revives
        it later bit-identically. Incompatible with a ``drafter`` (the
        verify window's tentative state cannot be spilled mid-flight).
    attn_backend:
        Override ``cfg.attn_backend`` for the paged decode/verify hot
        path: ``"jnp"`` streams the gathered dense KV view (reference),
        ``"pallas"`` runs the fused block-table flash kernels
        (``repro.kernels.paged_attention``), ``"auto"`` picks pallas on
        TPU and jnp elsewhere. ``None`` keeps the model config's value.
        Greedy decode tokens are identical across backends
        (``docs/kernels.md``).
    """

    def __init__(self, model, params, *, n_slots: int, max_len: int,
                 prompt_buckets: Sequence[int] = (), paged: bool = False,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 rng=None, drafter: Optional[Drafter] = None,
                 mesh=None, rules=None,
                 clock: Callable[[], float] = time.monotonic,
                 prefill_chunk_tokens: Optional[int] = None,
                 scheduling: str = "fifo",
                 attn_backend: Optional[str] = None):
        if attn_backend is not None:
            # override the config's paged-attention backend ("jnp" | "pallas"
            # | "auto"); baked into cfg so it keys the compile cache and the
            # jitted decode/verify closures see it as a static attribute
            model = dataclasses.replace(
                model, cfg=dataclasses.replace(model.cfg,
                                               attn_backend=attn_backend))
        if model.cfg.family == "encoder":
            raise ValueError("encoder-only arch has no decode step")
        if model.cfg.family == "vlm":
            raise ValueError("vlm serving is not supported: the engine "
                             "feeds token-only prompts, but vlm prefill "
                             "needs a patch batch")
        if drafter is not None and not model.supports_spec_decode:
            raise ValueError(
                f"family {model.cfg.family!r} (cfg {model.cfg.name!r}) has "
                "no exact multi-token verify — speculative decoding needs "
                "Model.supports_spec_decode")
        if scheduling not in SlotScheduler.POLICIES:
            raise ValueError(f"unknown scheduling {scheduling!r}; expected "
                             f"one of {SlotScheduler.POLICIES}")
        if scheduling == "slo" and drafter is not None:
            raise ValueError(
                "scheduling='slo' is incompatible with speculative "
                "decoding: preemption would have to spill the drafter's "
                "per-slot state and the verify window's tentative writes")
        self._chunk = prefill_chunk_tokens
        if self._chunk is not None:
            if self._chunk < 1:
                raise ValueError("prefill_chunk_tokens must be >= 1")
            if not model.supports_chunked_prefill:
                raise ValueError(
                    f"family {model.cfg.family!r} (cfg {model.cfg.name!r}) "
                    "does not support chunked prefill "
                    "(Model.supports_chunked_prefill)")
            align = model.prefill_chunk_alignment
            if self._chunk % align:
                raise ValueError(
                    f"prefill_chunk_tokens {self._chunk} must be a multiple "
                    f"of the model's chunk alignment {align} (ssd_chunk for "
                    "recurrent families — misaligned chunks change the SSD "
                    "scan's block boundaries and break bit-exactness)")
            if paged and self._chunk % block_size:
                raise ValueError(
                    f"prefill_chunk_tokens {self._chunk} must be a multiple "
                    f"of block_size {block_size} so every chunk's KV lands "
                    "on whole pool pages")
            if getattr(model.cfg, "kv_cache_dtype", None) == "int8":
                raise ValueError(
                    "chunked prefill does not support int8 KV caches: "
                    "per-chunk suffix KV is quantized per chunk, which "
                    "breaks bit-exactness with the one-shot prefill scales")
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.drafter = drafter
        self.spec_k = drafter.k if drafter is not None else 0
        self.scheduling = scheduling
        self.scheduler = SlotScheduler(n_slots, max_len,
                                       [b for b in prompt_buckets
                                        if b <= max_len],
                                       spec_margin=self.spec_k,
                                       policy=scheduling, clock=clock)
        self._clock = clock
        self._rng = jax.random.PRNGKey(0) if rng is None else rng
        self._padded = model.supports_padded_prefill
        self.paged = paged

        self.mesh = mesh
        self.rules = None
        self._param_sh = self._cache_sh = self._rep = None
        if mesh is not None:
            self.rules = rules if rules is not None \
                else serve_rules_for(model.cfg.family)
            self.rules = replicate_uneven_kv_heads(
                self.rules, model.cfg.n_kv_heads, mesh)
            self._rep = NamedSharding(mesh, PartitionSpec())
            from repro.launch.steps import build_shardings, infer_param_axes
            self._param_sh = build_shardings(
                params, infer_param_axes(params), mesh, self.rules)
            params = jax.device_put(params, self._param_sh)
        self.params = params
        #: everything a cached jitted callable closes over: the config
        #: (family dispatch, dtypes, strategies), the cache layout flavor,
        #: and the mesh/rules the sharding specs are built from. Mesh
        #: engines additionally key on the layout shapes: the baked
        #: in/out sharding trees depend on them (an indivisible slot or
        #: head dim replicates), so two mesh engines may only share a jit
        #: when their cache shapes agree.
        layout_key = (n_slots, max_len, block_size, n_blocks) \
            if mesh is not None else ()
        self._jit_key = (model.cfg, paged, mesh, self.rules) + layout_key

        if paged:
            self._init_paged(block_size, n_blocks)
        else:
            cache = model.init_cache(n_slots, max_len)
            cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
            self.cache = self._place_cache(cache)
            self._decode = self._build(
                "decode", model.decode_step, donate=(1,),
                in_specs=(self._param_sh, self._cache_sh, self._rep),
                out_specs=(self._rep, self._cache_sh))
            self._write = self._build(
                "write", _write_slot, donate=(0,),
                in_specs=(self._cache_sh, self._rep, self._rep),
                out_specs=self._cache_sh)
            self._read = self._build(
                "read_slot", _read_slot,
                in_specs=(self._cache_sh, self._rep),
                out_specs=self._rep)

        if self._padded:
            self._prefill = self._build(
                "prefill",
                lambda p, b, pl: model.prefill(p, b, max_len=max_len,
                                               prompt_len=pl),
                in_specs=(self._param_sh, self._rep, self._rep),
                out_specs=self._rep, key_extra=(max_len,))
        else:
            self._prefill = self._build(
                "prefill",
                lambda p, b: model.prefill(p, b, max_len=max_len),
                in_specs=(self._param_sh, self._rep),
                out_specs=self._rep, key_extra=(max_len,))
        if self._chunk is not None:
            fam = model.cfg.family
            self._chunk_kv_key = "kv" if fam == "hybrid" else "layers"
            if fam == "ssm":
                self._prefill_chunk = self._build(
                    "prefill_chunk",
                    lambda p, b, st: model.prefill_chunk(p, b, state=st),
                    in_specs=(self._param_sh, self._rep, self._rep),
                    out_specs=self._rep)
            elif fam == "hybrid":
                self._prefill_chunk = self._build(
                    "prefill_chunk",
                    lambda p, b, st, pre: model.prefill_chunk(
                        p, b, state=st, prefix_kv=pre),
                    in_specs=(self._param_sh, self._rep, self._rep,
                              self._rep),
                    out_specs=self._rep)
            elif not hasattr(self, "_suffix_prefill"):
                # attention families chunk via suffix prefill (chunk 0 uses
                # a zero-length prefix); the paged dense engine already
                # built this callable for prefix-cache hits
                self._suffix_prefill = self._build(
                    "suffix_prefill",
                    lambda p, b, pre, pl: model.prefill_suffix(
                        p, b, prefix=pre, prompt_len=pl),
                    in_specs=(self._param_sh, self._rep, self._rep,
                              self._rep),
                    out_specs=self._rep)
        self._sample = self._build("sample", sample_batch)
        if drafter is not None:
            if not paged:
                self._verify = self._build(
                    "verify", model.verify_step, donate=(1,),
                    in_specs=(self._param_sh, self._cache_sh, self._rep),
                    out_specs=(self._rep, self._cache_sh, self._rep))
            # paged: verify is built lazily per live-block bucket
            # (_verify_for), mirroring the decode path
            self._commit = self._build(
                "commit", model.commit_verified, donate=(0,),
                in_specs=(self._cache_sh, self._rep, self._rep),
                out_specs=self._cache_sh)
            self._accept = self._build("accept", verify_accept)

        self._inflight: Dict[int, _Inflight] = {}
        #: slot -> mid-chunked-prefill request state
        self._prefilling: Dict[int, _Prefilling] = {}
        #: uid -> spilled (preempted) request record awaiting revival
        self._spilled: Dict[int, dict] = {}
        self._preemptions = 0
        self._spills = 0
        self._revivals = 0
        self._chunk_ticks = 0
        self._steps = 0
        self._occupancy_sum = 0.0
        self._fast_forward_s = 0.0
        # run() resets the clock origin; set here so preempt() works before
        # the first run (tests drive the lifecycle methods directly)
        self._t_start = self._clock()
        self._compile_s = 0.0
        self._log_start = 0
        self._spec_ticks = 0
        self._spec_emitted = 0
        self._spec_slot_steps = 0.0
        self._accept_hist = [0] * (self.spec_k + 1)
        self._draft_steps_start = 0
        self._tick_contexts: Dict[int, List[int]] = {}
        if drafter is not None:
            drafter.bind(self)

    # ---- paged setup -------------------------------------------------------
    def _init_paged(self, block_size: int, n_blocks: Optional[int]) -> None:
        model = self.model
        spec = model.cache_spec()
        if not spec.pageable:
            raise ValueError(
                f"family {model.cfg.family!r} has no KV cache to page — "
                "its decode state is constant-size per slot")
        if self.max_len % block_size:
            raise ValueError(
                f"block_size {block_size} must divide max_len "
                f"{self.max_len} so the gathered paged view matches the "
                "dense cache shape exactly")
        self.block_size = block_size
        self._max_blocks = self.max_len // block_size
        self.n_blocks = n_blocks if n_blocks is not None \
            else self.n_slots * self._max_blocks
        self._pool = BlockPool(self.n_blocks, block_size)
        self._tables: Dict[int, _SlotTable] = {}
        # dense family: prefix hits skip prefill compute via suffix prefill;
        # partial-tail sharing is pointless there (the tail is recomputed),
        # so tail matching — and with it CoW — is the full-prefill
        # families' (MoE / hybrid) regime
        self._suffix_capable = model.cfg.family == "dense"
        self._match_tail = not self._suffix_capable
        # prefix-content reuse is exact only when a prompt position's KV is
        # independent of the rest of the prefill batch: dense and hybrid
        # (causal) always, MoE only dropless — below that, expert capacity
        # couples a token's output to the total prefill length, so two
        # requests' "identical" prefixes can hold different KV. Capacity-
        # limited MoE still pages memory but never shares content (its
        # prompt blocks stay out of the trie).
        self._prefix_share = model.cfg.family != "moe" \
            or model.supports_padded_prefill
        if not self._prefix_share:
            self._match_tail = False
        self._spec = spec
        # physical pages: pool blocks 1..n plus the id-0 trash page
        self.cache = self._place_cache(model.init_paged_cache(
            self.n_slots, self.n_blocks + 1, block_size, self._max_blocks))
        self._kv_key = kv_key = \
            "kv" if model.cfg.family == "hybrid" else "layers"
        kv_sh = self._cache_sh[kv_key] if self._cache_sh is not None else None
        # decode/verify are built lazily per live-block bucket (_decode_for /
        # _verify_for): attention gathers only up to the in-flight high-water
        # block instead of the full table width, so a mostly-shallow batch
        # streams a fraction of the padded KV (docs/kernels.md)
        if self._suffix_capable:
            self._suffix_prefill = self._build(
                "suffix_prefill",
                lambda p, b, pre, pl: model.prefill_suffix(
                    p, b, prefix=pre, prompt_len=pl),
                in_specs=(self._param_sh, self._rep, self._rep, self._rep),
                out_specs=self._rep)
        self._gather_prefix = self._build(
            "gather_prefix",
            functools.partial(_gather_prefix, cdtype=model.cfg.cdtype),
            in_specs=(kv_sh, self._rep), out_specs=self._rep)
        self._paged_write = self._build(
            "paged_write", functools.partial(_paged_write, kv_key=kv_key),
            donate=(0,),
            in_specs=(self._cache_sh,) + (self._rep,) * 6,
            out_specs=self._cache_sh)
        self._cow_copy = self._build(
            "cow_copy", functools.partial(_cow_copy, kv_key=kv_key),
            donate=(0,),
            in_specs=(self._cache_sh,) + (self._rep,) * 4,
            out_specs=self._cache_sh)
        self._clear_slot = self._build(
            "clear_slot", _clear_slot, donate=(0,),
            in_specs=(self._cache_sh, self._rep),
            out_specs=self._cache_sh)
        has_ssm = model.cfg.family == "hybrid"
        self._read_paged = self._build(
            "read_paged_slot",
            functools.partial(_read_paged_slot, has_ssm=has_ssm),
            in_specs=(self._cache_sh, self._rep), out_specs=self._rep)
        self._restore_paged = self._build(
            "restore_paged_slot",
            functools.partial(_restore_paged_slot, has_ssm=has_ssm),
            donate=(0,),
            in_specs=(self._cache_sh,) + (self._rep,) * 3,
            out_specs=self._cache_sh)
        self._prefix_hits = 0
        self._shared_block_hits = 0
        self._cow_count = 0
        self._admissions = 0
        self._block_occ_sum = 0.0
        self._peak_blocks = 0
        # attention KV traffic accounting (both numbers priced per tick from
        # the same cursors, independent of which backend actually ran):
        # gathered = what the jnp gather path streams (n_slots × high-water
        # bucket), fused = what the block-table kernel touches (live blocks
        # only). _kv_step_log keeps the per-tick (gathered, fused) pairs for
        # depth-resolved reporting (benchmarks/serving.py --backends).
        self._gathered_kv_bytes = 0
        self._fused_kv_bytes = 0
        self._kv_step_log: List[Tuple[int, int]] = []

    # ---- sharding + compile-cache plumbing ---------------------------------
    def _place_cache(self, cache):
        """Compute (and remember) the cache sharding tree and place the
        cache accordingly; identity on a mesh-less engine."""
        if self.mesh is None:
            return cache
        self._cache_sh = serve_cache_shardings(cache, self.mesh, self.rules,
                                               paged=self.paged)
        return jax.device_put(cache, self._cache_sh)

    def _ctx(self, fn):
        """Run ``fn`` inside this engine's sharding context (so
        ``constrain`` annotations bind at trace time); identity without a
        mesh."""
        if self.mesh is None:
            return fn
        mesh, rules = self.mesh, self.rules

        @functools.wraps(fn)
        def wrapped(*args):
            with activate(mesh, rules):
                return fn(*args)

        return wrapped

    def _build(self, name: str, fn, *, donate: Tuple[int, ...] = (),
               in_specs=None, out_specs=None, key_extra: tuple = ()):
        """Jit ``fn`` through the module compile cache.

        The key is ``(cfg, paged, mesh, rules, name, *key_extra)`` — two
        engines with the same model and cache layout share one jitted
        callable (and its per-shape executables). On a mesh the callable
        carries explicit NamedSharding in/out specs so no input or output
        ever reshards at the jit boundary (donation preserved).
        """
        key = self._jit_key + (name,) + tuple(key_extra)

        def builder():
            kwargs = {}
            if donate:
                kwargs["donate_argnums"] = donate
            if self.mesh is not None:
                if in_specs is not None:
                    kwargs["in_shardings"] = in_specs
                if out_specs is not None:
                    kwargs["out_shardings"] = out_specs
            return jax.jit(fn, **kwargs)

        return self._ctx(_cached_jit(key, builder))

    # ---- live-block bucketing (paged) --------------------------------------
    def _hw_buckets(self) -> List[int]:
        """The block-count buckets decode/verify compile against: powers of
        two up to the table width, plus the width itself."""
        buckets = []
        b = 1
        while b < self._max_blocks:
            buckets.append(b)
            b <<= 1
        buckets.append(self._max_blocks)
        return buckets

    def _live_blocks(self, window: int) -> int:
        """Bucketed high-water block count covering every in-flight slot's
        cursor plus ``window`` rows written this tick (1 for decode, k+1
        for a verify pass). Computed host-side from the same cursors the
        device cache holds, then rounded up to the next power of two so the
        number of compiled decode/verify shapes stays logarithmic in the
        table width."""
        need = 1
        for inf in self._inflight.values():
            top = inf.metrics.prompt_tokens + len(inf.generated) + window - 1
            need = max(need, top // self.block_size + 1)
        b = 1
        while b < need:
            b <<= 1
        return min(b, self._max_blocks)

    def _decode_for(self, hw: int):
        """Paged decode callable that reads only the first ``hw`` block-table
        columns (cached per bucket; attention output for every live slot is
        bit-identical to the full-width gather — trailing columns are fully
        masked, contributing exact zeros to the softmax)."""
        model = self.model
        return self._build(
            "decode",
            lambda p, c, t, _hw=hw: model.paged_decode_step(
                p, c, t, live_blocks=_hw),
            donate=(1,),
            in_specs=(self._param_sh, self._cache_sh, self._rep),
            out_specs=(self._rep, self._cache_sh),
            key_extra=(hw,))

    def _verify_for(self, hw: int):
        """Paged verify callable bounded to ``hw`` block-table columns; the
        bucket must cover the cursor plus the tentative k+1-row window."""
        model = self.model
        return self._build(
            "verify",
            lambda p, c, t, _hw=hw: model.paged_verify_step(
                p, c, t, live_blocks=_hw),
            donate=(1,),
            in_specs=(self._param_sh, self._cache_sh, self._rep),
            out_specs=(self._rep, self._cache_sh, self._rep),
            key_extra=(hw,))

    def _kv_bytes_tick(self, hw: int, window: int) -> Tuple[int, int]:
        """(gathered, fused) attention KV bytes for one tick at bucket
        ``hw``: the jnp gather path materializes ``n_slots × hw`` blocks
        whether live or not; the fused kernel touches only each slot's live
        blocks (dead pages are index-redirected and elided)."""
        blk = self._spec.kv_block_bytes(self.block_size)
        gathered = self.n_slots * hw * blk
        fused = 0
        for inf in self._inflight.values():
            top = inf.metrics.prompt_tokens + len(inf.generated) + window - 1
            fused += (top // self.block_size + 1) * blk
        return gathered, fused

    # ---- time --------------------------------------------------------------
    def _now(self, t_start: float) -> float:
        """Engine clock in seconds: wall time plus fast-forwarded idle."""
        return (self._clock() - t_start) + self._fast_forward_s

    # ---- lifecycle ---------------------------------------------------------
    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def _block_gate(self, req: Request) -> bool:
        """Invariant 6: admission needs enough free pool blocks for the
        request's worst-case lifetime (prefix hits count as free; spec
        mode adds the verify window's tentative-write margin)."""
        return self._pool.can_admit(req.prompt,
                                    req.max_new_tokens + self.spec_k,
                                    match_tail=self._match_tail)

    def _plan_tables(self, req: Request):
        """Reserve pool pages for one admission: share matched prefix
        pages, allocate the rest (plus the CoW spare for a matched tail),
        and build the slot's logical→physical table. In spec mode the
        plan covers ``spec_k`` rows past the worst-case length, so every
        tentative verify write lands on a slot-private page."""
        pool, bs = self._pool, self.block_size
        plan = pool.plan(req.prompt, req.max_new_tokens + self.spec_k,
                         match_tail=self._match_tail)
        # share before alloc: a matched evictable page must be revived
        # before allocation can consider evicting it
        for b in plan.full_matched:
            pool.share(b)
        if plan.tail_matched is not None:
            pool.share(plan.tail_matched)
        fresh = iter(pool.alloc(plan.new_needed))
        n_full = len(plan.full_matched)
        table = _SlotTable(blocks=list(plan.full_matched),
                           shared=set(range(n_full)))
        if plan.tail_matched is not None:
            table.tail_idx = n_full              # == prompt_len // bs
        for i in range(n_full, plan.n_logical):
            if i == table.tail_idx:
                table.blocks.append(plan.tail_matched)
                table.shared.add(i)
            else:
                table.blocks.append(next(fresh))
        if plan.tail_matched is not None:
            table.cow_spare = next(fresh)
        return plan, table

    def _register_prompt_blocks(self, req: Request, plan,
                                table: _SlotTable) -> None:
        """Publish this admission's privately-written prompt pages in the
        prefix trie (matched pages are already registered)."""
        if not self._prefix_share:
            return
        bs, p = self.block_size, req.prompt_len
        for i in range(len(plan.full_matched), p // bs):
            self._pool.register(table.blocks[i], req.prompt[: (i + 1) * bs])
        if self._match_tail and p % bs and plan.tail_matched is None:
            self._pool.register(table.blocks[p // bs], req.prompt)

    def _paged_prefill(self, slot: int, req: Request):
        """Prefill under the paged cache; returns the first-token logits.

        Dense family with a prefix hit: gather the cached prefix pages and
        run the *suffix-only* prefill — the O(prefix) projection/attention
        work is skipped, which is where the TTFT win on shared-prefix
        workloads comes from. Everything else: full (bucketed or
        exact-length) prefill; shared logical blocks write to the trash
        page so cached content is never clobbered.
        """
        pool, bs, p = self._pool, self.block_size, req.prompt_len
        plan, table = self._plan_tables(req)
        self._admissions += 1
        if plan.n_shared:
            self._prefix_hits += 1
            self._shared_block_hits += plan.n_shared
        prompt = req.prompt_array()
        # dense suffix path: recompute at least one position so the
        # last-token logits exist even when every prompt block matched
        n_pref = min(len(plan.full_matched), (p - 1) // bs) \
            if self._suffix_capable else 0
        if n_pref > 0:
            prefix = self._gather_prefix(
                self.cache[self._kv_key],
                jnp.asarray(table.blocks[:n_pref], jnp.int32))
            suffix = prompt[0, n_pref * bs:]
            pad = -len(suffix) % bs
            toks = np.zeros((1, len(suffix) + pad), np.int32)
            toks[0, : len(suffix)] = suffix
            logits, pre = self._suffix_prefill(
                self.params, {"tokens": toks}, prefix,
                jnp.asarray(p, jnp.int32))
            first_logical = n_pref
        else:
            if self._padded:
                bucket = self.scheduler.bucket_for(p)
                toks = np.zeros((1, bucket), np.int32)
                toks[0, :p] = prompt[0]
                logits, pre = self._prefill(self.params, {"tokens": toks},
                                            jnp.asarray(p, jnp.int32))
            else:
                logits, pre = self._prefill(self.params, {"tokens": prompt})
            first_logical = 0
        kv, state = self.model.split_prefill_cache(pre)
        n_written = kv["k"].shape[2] // bs
        write_ids = []
        for i in range(first_logical, first_logical + n_written):
            if i >= len(table.blocks) or i in table.shared:
                write_ids.append(TRASH_BLOCK)
            else:
                write_ids.append(table.blocks[i])
        row = np.full((self._max_blocks,), TRASH_BLOCK, np.int32)
        row[: len(table.blocks)] = table.blocks
        self.cache = self._paged_write(
            self.cache, kv, state, jnp.asarray(write_ids, jnp.int32),
            jnp.asarray(row), slot, pre["pos"])
        self._register_prompt_blocks(req, plan, table)
        self._tables[slot] = table
        return logits, n_pref * bs

    def _apply_cow(self, slot: int) -> None:
        """First divergent write is imminent (the request enters the decode
        loop): copy the shared tail page into the reserved spare."""
        table = self._tables[slot]
        if table.cow_spare is None:
            return
        src, dst = table.blocks[table.tail_idx], table.cow_spare
        self.cache = self._cow_copy(self.cache, src, dst, slot,
                                    table.tail_idx)
        self._pool.free(src)
        table.blocks[table.tail_idx] = dst
        table.shared.discard(table.tail_idx)
        table.cow_spare = None
        self._cow_count += 1

    def _release_paged(self, slot: int) -> None:
        table = self._tables.pop(slot)
        for b in table.blocks:
            self._pool.free(b)
        if table.cow_spare is not None:
            self._pool.free(table.cow_spare)
        self.cache = self._clear_slot(self.cache, slot)

    def _admission_gate(self, req: Request) -> bool:
        """Paged admission gate: a spilled request already holds its
        worst-case block reservation (revival allocates nothing), fresh
        requests must fit the pool (invariant 6)."""
        return req.uid in self._spilled or self._block_gate(req)

    def _admit(self, slot: int, req: Request, now_s: float,
               results: List[RequestResult]) -> None:
        """Bind ``req`` to ``slot``: revive it if it was spilled by a
        preemption, start a chunked prefill if its prompt exceeds the
        chunk budget, else prefill in one shot and seed its first token."""
        if req.uid in self._spilled:
            self._revive(slot, req)
            return
        if self._chunk is not None and req.prompt_len > self._chunk:
            self._begin_chunked(slot, req, now_s)
            return
        p = req.prompt_len
        cached_tokens = 0
        if self.paged:
            logits, cached_tokens = self._paged_prefill(slot, req)
        else:
            prompt = req.prompt_array()
            if self._padded:
                bucket = self.scheduler.bucket_for(p)
                toks = np.zeros((1, bucket), np.int32)
                toks[0, :p] = prompt[0]
                logits, pre = self._prefill(self.params, {"tokens": toks},
                                            jnp.asarray(p, jnp.int32))
            else:
                logits, pre = self._prefill(self.params, {"tokens": prompt})
            self.cache = self._write(self.cache, pre, slot)
        if self.drafter is not None:
            self.drafter.admit(slot, req.prompt)
        self._seed(slot, req, logits, now_s, cached_tokens, 1, results)

    def _seed(self, slot: int, req: Request, logits, admitted_s: float,
              cached_tokens: int, chunks: int,
              results: List[RequestResult]) -> None:
        """Sample the first token from prefill logits and move the request
        into the decode set (or finish it on the spot)."""
        first = int(np.asarray(req.sampler(
            logits[:, -1], None if req.sampler.greedy else self._next_key()))[0])
        t_first = self._now(self._t_start)
        metrics = RequestMetrics(arrival_s=req.arrival_s,
                                 admitted_s=admitted_s,
                                 first_token_s=t_first,
                                 prompt_tokens=req.prompt_len,
                                 cached_prompt_tokens=cached_tokens,
                                 deadline_s=req.deadline_s,
                                 prefill_chunks=chunks)
        inf = _Inflight(request=req, slot=slot, generated=[first],
                        next_token=first, metrics=metrics)
        if first == req.eos_id or req.max_new_tokens == 1:
            self._finish(inf, t_first, results)
        else:
            if self.paged:
                self._apply_cow(slot)
            self._inflight[slot] = inf

    # ---- chunked prefill ---------------------------------------------------
    def _begin_chunked(self, slot: int, req: Request, now_s: float) -> None:
        """Open a chunked prefill: reserve paged blocks up front (the slot's
        installed table row stays all-trash until the final chunk) and seed
        the recurrent families' carried state."""
        pf = _Prefilling(request=req, slot=slot, admitted_s=now_s, done=0)
        if self.paged:
            plan, table = self._plan_tables(req)
            self._admissions += 1
            if plan.n_shared:
                self._prefix_hits += 1
                self._shared_block_hits += plan.n_shared
            pf.plan, pf.table = plan, table
            if self._suffix_capable:
                # prefix-cache hit: skip the matched blocks' compute and
                # start the chunk cursor past them (same bound as the
                # one-shot suffix path: at least one position recomputed)
                n_pref = min(len(plan.full_matched),
                             (req.prompt_len - 1) // self.block_size)
                pf.done = pf.cached_tokens = n_pref * self.block_size
        fam = self.model.cfg.family
        if fam in ("ssm", "hybrid"):
            cache1 = self.model.init_cache(1, self.max_len)
            state_key = "layers" if fam == "ssm" else "ssm"
            pf.state = {state_key: cache1[state_key],
                        "pos": jnp.zeros((), jnp.int32)}
        self._prefilling[slot] = pf

    def _empty_prefix(self):
        """Zero-length prefix K/V tree — chunk 0 of an attention or hybrid
        chunked prefill is a suffix prefill with nothing in front."""
        key = self._kv_key if self.paged else self._chunk_kv_key
        kv = self.cache[key]
        cd = self.model.cfg.cdtype
        return {name: jnp.zeros(
            (kv[name].shape[0], 1, 0) + kv[name].shape[3:], cd)
            for name in ("k", "v")}

    def _chunk_prefix_kv(self, pf: _Prefilling):
        """Dense K/V over the first ``pf.done`` prompt tokens, feeding the
        next chunk's suffix prefill (paged: gathered back from the pool
        pages this prefill already wrote; dense slots: the accumulated
        device-side parts, merged lazily)."""
        if pf.done == 0:
            return self._empty_prefix()
        if self.paged:
            ids = pf.table.blocks[: pf.done // self.block_size]
            return self._gather_prefix(self.cache[self._kv_key],
                                       jnp.asarray(ids, jnp.int32))
        if len(pf.kv_parts) > 1:
            pf.kv_parts = [jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=2), *pf.kv_parts)]
        return pf.kv_parts[0]

    def _store_chunk_kv(self, pf: _Prefilling, kv, final: bool, state_final,
                        slot: int) -> None:
        """Bank one chunk's suffix K/V. Paged: scatter onto this chunk's
        pool pages now (shared/overhang logical blocks divert to the trash
        page; rows are zero-padded up to whole pages) and install the real
        table row + cursor + recurrent state only with the final chunk.
        Dense slots: accumulate on device, then write the whole slot row
        once. Rows past the prompt are garbage either way — masked by
        ``pos`` until decode overwrites them."""
        p = pf.request.prompt_len
        if self.paged:
            bs = self.block_size
            pad_rows = -kv["k"].shape[2] % bs
            if pad_rows:
                kv = jax.tree.map(
                    lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, pad_rows)]
                                      + [(0, 0)] * (x.ndim - 3)), kv)
            n_written = kv["k"].shape[2] // bs
            first_logical = pf.done // bs
            table = pf.table
            write_ids = []
            for i in range(first_logical, first_logical + n_written):
                if i >= len(table.blocks) or i in table.shared:
                    write_ids.append(TRASH_BLOCK)
                else:
                    write_ids.append(table.blocks[i])
            row = np.full((self._max_blocks,), TRASH_BLOCK, np.int32)
            if final:
                row[: len(table.blocks)] = table.blocks
            pos = jnp.asarray(p if final else 0, jnp.int32)
            self.cache = self._paged_write(
                self.cache, kv, state_final,
                jnp.asarray(write_ids, jnp.int32), jnp.asarray(row),
                slot, pos)
        else:
            pf.kv_parts.append(kv)
            if final:
                merged = self._chunk_prefix_kv(pf)
                pad_rows = self.max_len - merged["k"].shape[2]
                if pad_rows:
                    merged = jax.tree.map(
                        lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, pad_rows)]
                                          + [(0, 0)] * (x.ndim - 3)), merged)
                pre = {self._chunk_kv_key: merged,
                       "pos": jnp.asarray(p, jnp.int32)}
                if state_final is not None:
                    pre["ssm"] = state_final
                self.cache = self._write(self.cache, pre, slot)

    def _prefill_tick(self, results: List[RequestResult]) -> None:
        """Advance the lowest-numbered prefilling slot by one chunk; the
        final chunk installs the slot's cache state and seeds the first
        token exactly like a one-shot admission."""
        slot = min(self._prefilling)
        pf = self._prefilling[slot]
        req = pf.request
        p = req.prompt_len
        take = min(self._chunk, p - pf.done)
        end = pf.done + take
        final = end >= p
        pf.chunks += 1
        self._chunk_ticks += 1
        fam = self.model.cfg.family
        if fam == "ssm":
            toks = req.prompt_array()[:, pf.done:end]
            logits, pf.state = self._prefill_chunk(
                self.params, {"tokens": toks}, pf.state)
            if final:
                # the carried state IS the prefill cache
                self.cache = self._write(self.cache, pf.state, slot)
        elif fam == "hybrid":
            toks = req.prompt_array()[:, pf.done:end]
            prefix = self._chunk_prefix_kv(pf)
            logits, out = self._prefill_chunk(
                self.params, {"tokens": toks}, pf.state, prefix)
            pf.state = {"ssm": out["ssm"], "pos": out["pos"]}
            self._store_chunk_kv(pf, out["kv"], final,
                                 out["ssm"] if final else None, slot)
        else:
            prefix = self._chunk_prefix_kv(pf)
            toks = np.zeros((1, take), np.int32)
            toks[0, :] = req.prompt[pf.done:end]
            logits, pre = self._suffix_prefill(
                self.params, {"tokens": toks}, prefix,
                jnp.asarray(end, jnp.int32))
            self._store_chunk_kv(pf, pre["layers"], final, None, slot)
        pf.done = end
        if final:
            self._prefilling.pop(slot)
            if self.paged:
                self._register_prompt_blocks(req, pf.plan, pf.table)
                self._tables[slot] = pf.table
            if self.drafter is not None:
                self.drafter.admit(slot, req.prompt)
            self._seed(slot, req, logits, pf.admitted_s, pf.cached_tokens,
                       pf.chunks, results)

    # ---- preemption --------------------------------------------------------
    def preempt(self, slot: int) -> None:
        """Spill the request in ``slot`` and return it to the ready queue.

        A decoding request's device state is snapshotted (dense slots: the
        whole slot row; paged: only the per-slot cursor/recurrent state —
        its pool pages stay pinned under their refcounts, which also makes
        them immune to eviction storms) and revived bit-identically at its
        next admission. A mid-prefill request is cheaper: progress is
        discarded, its pages are freed, and it restarts from scratch — no
        token was emitted yet, so nothing observable is lost.
        """
        now = self._now(self._t_start)
        if slot in self._inflight:
            inf = self._inflight.pop(slot)
            inf.metrics.preempted += 1
            rec = {"request": inf.request, "generated": inf.generated,
                   "next_token": inf.next_token, "metrics": inf.metrics}
            if self.paged:
                rec["snap"] = self._read_paged(self.cache, slot)
                rec["table"] = self._tables.pop(slot)
                self.cache = self._clear_slot(self.cache, slot)
            else:
                rec["snap"] = self._read(self.cache, slot)
            self._spilled[inf.request.uid] = rec
            self._spills += 1
        elif slot in self._prefilling:
            pf = self._prefilling.pop(slot)
            if self.paged:
                for b in pf.table.blocks:
                    self._pool.free(b)
                if pf.table.cow_spare is not None:
                    self._pool.free(pf.table.cow_spare)
                self.cache = self._clear_slot(self.cache, slot)
        else:
            raise KeyError(f"slot {slot} has no preemptible request")
        self.scheduler.preempt(slot, now)
        self._preemptions += 1

    def _revive(self, slot: int, req: Request) -> None:
        """Reinstall a spilled request into ``slot`` and resume decoding
        exactly where it left off (its TTFT was banked at first
        admission; only queueing-for-revival time is added)."""
        rec = self._spilled.pop(req.uid)
        if self.paged:
            table = rec["table"]
            row = np.full((self._max_blocks,), TRASH_BLOCK, np.int32)
            row[: len(table.blocks)] = table.blocks
            self.cache = self._restore_paged(self.cache, rec["snap"],
                                             jnp.asarray(row), slot)
            self._tables[slot] = table
        else:
            self.cache = self._write(self.cache, rec["snap"], slot)
        self._inflight[slot] = _Inflight(
            request=req, slot=slot, generated=rec["generated"],
            next_token=rec["next_token"], metrics=rec["metrics"])
        self._revivals += 1

    def _maybe_preempt(self, now_s: float) -> None:
        """SLO policy: when no slot is free and the best waiting request
        strictly outranks the worst running one, preempt the latter — at
        most one preemption per tick; the strict-rank requirement plus
        uid tiebreak means a preempted pair can never thrash."""
        if self.scheduler.has_free or not self._inflight:
            return
        cand = self.scheduler.ready_head(now_s)
        if cand is None:
            return
        if self.paged and not self._admission_gate(cand):
            return   # freeing a slot would not make the candidate fit

        def rank(r):
            return (-r.priority,
                    r.deadline_s if r.deadline_s is not None
                    else float("inf"))

        cand_rank = rank(cand)
        victims = [(rank(inf.request), inf.request.uid, s)
                   for s, inf in self._inflight.items()
                   if rank(inf.request) > cand_rank]
        if not victims:
            return
        self.preempt(max(victims)[2])

    def _finish(self, inf: _Inflight, now_s: float,
                results: List[RequestResult]) -> None:
        """Close out a request: metrics and slot release (MOA pricing is
        deferred to the end of ``run`` — it is an O(new_tokens) host loop
        and must not stall the decode ticks of the remaining slots)."""
        m = inf.metrics
        m.finished_s = now_s
        m.new_tokens = len(inf.generated)
        reason = (FinishReason.EOS
                  if inf.generated[-1] == inf.request.eos_id
                  else FinishReason.LENGTH)
        results.append(RequestResult(
            uid=inf.request.uid,
            tokens=np.asarray(inf.generated, np.int32),
            prompt_len=m.prompt_tokens, slot=inf.slot,
            finish_reason=reason, metrics=m))
        if self.paged:
            self._release_paged(inf.slot)
        if self.drafter is not None:
            self.drafter.release(inf.slot)
            self._tick_contexts[inf.request.uid] = inf.tick_contexts
        self.scheduler.release(inf.slot)
        self._inflight.pop(inf.slot, None)

    def _decode_tick(self, results: List[RequestResult]) -> None:
        """One batched decode step over all slots; advance active requests."""
        toks = np.zeros((self.n_slots, 1), np.int32)
        temps = np.zeros((self.n_slots,), np.float32)
        greedy = np.ones((self.n_slots,), bool)
        for slot, inf in self._inflight.items():
            toks[slot, 0] = inf.next_token
            temps[slot] = max(inf.request.sampler.temperature, 0.0)
            greedy[slot] = inf.request.sampler.greedy
        if self.paged:
            hw = self._live_blocks(1)
            decode = self._decode_for(hw)
        else:
            decode = self._decode
        logits, self.cache = decode(self.params, self.cache,
                                    jnp.asarray(toks))
        next_toks = np.asarray(self._sample(
            logits[:, -1], jnp.asarray(temps), jnp.asarray(greedy),
            self._next_key()))
        self._steps += 1
        self._occupancy_sum += len(self._inflight) / self.n_slots
        if self.paged:
            self._block_occ_sum += self._pool.in_use / self.n_blocks
            self._peak_blocks = max(self._peak_blocks, self._pool.in_use)
            g, f = self._kv_bytes_tick(hw, 1)
            self._gathered_kv_bytes += g
            self._fused_kv_bytes += f
            self._kv_step_log.append((g, f))
        now = self._now(self._t_start)
        for slot in sorted(self._inflight):
            inf = self._inflight[slot]
            tok = int(next_toks[slot])
            inf.generated.append(tok)
            inf.next_token = tok
            if tok == inf.request.eos_id \
                    or len(inf.generated) >= inf.request.max_new_tokens:
                self._finish(inf, now, results)

    def _spec_tick(self, results: List[RequestResult]) -> None:
        """One speculative tick: draft → verify → accept → commit.

        The drafter proposes ``k`` tokens per active slot; one
        ``verify_step`` scores the pending token plus the draft window,
        writing all ``k + 1`` K/V rows tentatively; the jitted acceptance
        picks each slot's accepted prefix (greedy exact-match or exact
        rejection sampling); the commit advances each slot's cursor by
        ``accepted + 1`` (0 for idle slots), which *is* the rejection
        rollback — rejected rows are masked garbage until overwritten.
        Each slot emits ``accepted + 1`` tokens, the last becoming its
        pending next token.
        """
        k = self.spec_k
        histories = {slot: tuple(inf.request.prompt) + tuple(inf.generated)
                     for slot, inf in self._inflight.items()}
        proposals = self.drafter.propose(histories)
        toks = np.zeros((self.n_slots, k + 1), np.int32)
        temps = np.zeros((self.n_slots,), np.float32)
        greedy = np.ones((self.n_slots,), bool)
        for slot, inf in self._inflight.items():
            toks[slot, 0] = inf.next_token
            toks[slot, 1:] = proposals[slot]
            temps[slot] = max(inf.request.sampler.temperature, 0.0)
            greedy[slot] = inf.request.sampler.greedy
        if self.paged:
            hw = self._live_blocks(k + 1)
            verify = self._verify_for(hw)
        else:
            verify = self._verify
        logits, self.cache, aux = verify(self.params, self.cache,
                                         jnp.asarray(toks))
        out, n_acc = self._accept(logits, jnp.asarray(toks[:, 1:]),
                                  jnp.asarray(temps), jnp.asarray(greedy),
                                  self._next_key())
        out, n_acc = np.asarray(out), np.asarray(n_acc)
        keep = np.zeros((self.n_slots,), np.int32)
        for slot in self._inflight:
            keep[slot] = n_acc[slot] + 1
        self.cache = self._commit(self.cache, jnp.asarray(keep), aux)
        self._steps += 1
        self._spec_ticks += 1
        self._occupancy_sum += len(self._inflight) / self.n_slots
        self._spec_slot_steps += len(self._inflight)
        if self.paged:
            self._block_occ_sum += self._pool.in_use / self.n_blocks
            self._peak_blocks = max(self._peak_blocks, self._pool.in_use)
            g, f = self._kv_bytes_tick(hw, k + 1)
            self._gathered_kv_bytes += g
            self._fused_kv_bytes += f
            self._kv_step_log.append((g, f))
        now = self._now(self._t_start)
        for slot in sorted(self._inflight):
            inf = self._inflight[slot]
            inf.tick_contexts.append(
                inf.request.prompt_len + len(inf.generated) - 1)
            accepted = int(n_acc[slot])
            self._accept_hist[accepted] += 1
            done = False
            for tok in out[slot, : accepted + 1]:
                tok = int(tok)
                inf.generated.append(tok)
                inf.next_token = tok
                self._spec_emitted += 1
                if tok == inf.request.eos_id \
                        or len(inf.generated) >= inf.request.max_new_tokens:
                    done = True
                    break
            if done:
                self._finish(inf, now, results)

    # ---- warmup ------------------------------------------------------------
    def _warmup_tick(self) -> None:
        """Compile the tick-critical callables with throwaway inputs.

        Runs one unmeasured prefill per prompt bucket (padded-prefill
        families — exact-length families still compile per novel prompt
        length at admission), the fixed-shape paged helpers (slot write /
        CoW / release), and one decode / verify tick before the engine
        clock starts, so first-call XLA compile time lands in
        ``compile_s`` instead of skewing ``wall_s`` / TTFT / per-token
        metrics. Not covered (inherently variable-shape): the prefix-hit
        gather and suffix prefill, which compile per distinct (prefix
        blocks, suffix bucket) pair on the first hit. All warmup writes
        are harmless by construction: dense-slot rows are overwritten at
        the next admission, paged writes are redirected to the trash page,
        and a spec commit with ``keep=0`` restores recurrent state from
        the pre-verify snapshot.
        """
        n = self.n_slots
        key = jax.random.PRNGKey(0)     # never draws from the engine stream
        pre = None
        if self._padded:
            for bucket in self.scheduler.buckets:
                toks = np.zeros((1, bucket), np.int32)
                _, pre = self._prefill(self.params, {"tokens": toks},
                                       np.asarray(bucket, np.int32))
        if self.paged and pre is not None:
            kv, state = self.model.split_prefill_cache(pre)
            n_written = kv["k"].shape[2] // self.block_size
            trash = np.full((n_written,), TRASH_BLOCK, np.int32)
            row = np.full((self._max_blocks,), TRASH_BLOCK, np.int32)
            self.cache = self._paged_write(
                self.cache, kv, state, jnp.asarray(trash), jnp.asarray(row),
                0, jnp.asarray(0, jnp.int32))
        elif pre is not None:
            self.cache = self._write(self.cache, pre, 0)
        if self.paged:
            # release + CoW are fixed-shape: compile them on the trash page
            # (copying page 0 onto itself and re-clearing an empty slot are
            # no-ops by construction)
            self.cache = self._cow_copy(self.cache, 0, 0, 0, 0)
            self.cache = self._clear_slot(self.cache, 0)
        if self.drafter is not None:
            toks = np.zeros((n, self.spec_k + 1), np.int32)
            if self.paged:
                # compile every live-block bucket now (a growing batch walks
                # the buckets in order; each is a distinct executable, and a
                # mid-run compile would land in wall_s) — verify + keep=0
                # commit restores the pre-verify cache bit-identically
                toks_j = jnp.asarray(toks)
                keep0 = jnp.zeros((n,), jnp.int32)
                for hw in self._hw_buckets():
                    logits, cache, aux = self._verify_for(hw)(
                        self.params, self.cache, toks_j)
                    self.cache = self._commit(cache, keep0, aux)
            else:
                logits, cache, aux = self._verify(self.params, self.cache,
                                                  jnp.asarray(toks))
                self.cache = self._commit(cache, jnp.zeros((n,), jnp.int32),
                                          aux)
            self._accept(logits, jnp.asarray(toks[:, 1:]),
                         jnp.zeros((n,), jnp.float32),
                         jnp.ones((n,), bool), key)
        else:
            if self.paged:
                # warmup decode writes land on the trash page and idle-slot
                # cursors are reset at admission, so ticking once per bucket
                # is as harmless as ticking once
                toks0 = jnp.zeros((n, 1), jnp.int32)
                for hw in self._hw_buckets():
                    logits, self.cache = self._decode_for(hw)(
                        self.params, self.cache, toks0)
            else:
                logits, self.cache = self._decode(self.params, self.cache,
                                                  jnp.zeros((n, 1),
                                                            jnp.int32))
            self._sample(logits[:, -1], jnp.zeros((n,), jnp.float32),
                         jnp.ones((n,), bool), key)
        jax.block_until_ready(self.cache)

    # ---- public API --------------------------------------------------------
    def submit(self, request: Request) -> None:
        """Queue a request (admitted when arrived, a slot frees up, and —
        paged — the pool can cover its worst-case block need)."""
        if self.paged:
            need = blocks_needed(request.prompt_len,
                                 request.max_new_tokens + self.spec_k,
                                 self.block_size)
            if need > self.n_blocks:
                raise ValueError(
                    f"request {request.uid}: needs {need} blocks but the "
                    f"pool only has {self.n_blocks} — it could never be "
                    "admitted")
        self.scheduler.submit(request)

    def reload_params(self, params) -> None:
        """Swap the weight tree in place (live reload between ticks).

        The new tree must match the current one's structure, shapes, and
        dtypes; on a mesh engine it is ``device_put`` onto the engine's
        parameter shardings. The jitted callables take params as a plain
        (non-donated) argument, so the swap is just a reference change —
        the next prefill/decode tick reads the new weights. In-flight
        slots keep decoding, now against the new weights; callers that
        need every generation pinned to one weight version (the replica
        router's rolling reload) drain the engine first.
        """
        old_leaves, old_def = jax.tree_util.tree_flatten(self.params)
        new_leaves, new_def = jax.tree_util.tree_flatten(params)
        if old_def != new_def:
            raise ValueError(
                "reload_params: new weight tree structure differs from the "
                f"serving one ({new_def} vs {old_def})")
        for i, (old, new) in enumerate(zip(old_leaves, new_leaves)):
            if (tuple(old.shape) != tuple(np.shape(new))
                    or old.dtype != np.asarray(new).dtype):
                raise ValueError(
                    f"reload_params: leaf {i} changed layout "
                    f"({np.shape(new)}/{np.asarray(new).dtype} vs "
                    f"{tuple(old.shape)}/{old.dtype}) — a reload may not "
                    "change the architecture")
        if self.mesh is not None:
            params = jax.device_put(params, self._param_sh)
        self.params = params

    def start_run(self, *, warmup: bool = False,
                  t_origin: Optional[float] = None) -> None:
        """Reset per-run counters and start the engine clock.

        Part of the tick-level API (``start_run`` / ``tick`` /
        ``finish_run``) that :meth:`run` is built from and that the replica
        router drives directly. ``t_origin`` pins the clock origin instead
        of reading the clock — the router passes one shared origin so every
        replica (including ones constructed mid-run on revival) reports on
        the same fleet timeline.
        """
        self._compile_s = 0.0
        if warmup:
            t0 = self._clock()
            self._warmup_tick()
            self._compile_s = self._clock() - t0
        # per-run counters: a reused engine (submit + repeated run) must not
        # carry stale fast-forward offsets, occupancy sums, or prior-run
        # admissions into its report
        self._steps = 0
        self._occupancy_sum = 0.0
        self._fast_forward_s = 0.0
        if self.drafter is not None:
            self._spec_ticks = 0
            self._spec_emitted = 0
            self._spec_slot_steps = 0.0
            self._accept_hist = [0] * (self.spec_k + 1)
            self._draft_steps_start = self.drafter.draft_steps
            self._tick_contexts: Dict[int, List[int]] = {}
        if self.paged:
            self._prefix_hits = 0
            self._shared_block_hits = 0
            self._cow_count = 0
            self._admissions = 0
            self._block_occ_sum = 0.0
            self._peak_blocks = 0
            self._gathered_kv_bytes = 0
            self._fused_kv_bytes = 0
            self._kv_step_log = []
        self._preemptions = 0
        self._spills = 0
        self._revivals = 0
        self._chunk_ticks = 0
        self._log_start = len(self.scheduler.admission_log)
        self._t_start = self._clock() if t_origin is None else t_origin

    def tick(self, results: List[RequestResult]) -> None:
        """One scheduling tick: admit what arrived, advance one prefill
        chunk set, one decode/verify step. Appends newly finished requests
        to ``results``. No-op when the scheduler has no work (so a router
        may tick an idle replica safely)."""
        if self.scheduler.done:
            return
        now = self._now(self._t_start)
        if not self.scheduler.active and not self.scheduler.has_ready \
                and self.scheduler.next_arrival_s > now:
            # idle: fast-forward the engine clock to the next arrival
            # (a gate-vetoed head sits in the ready queue, so has_ready
            # guards against fast-forwarding past work that only needs
            # blocks, not time)
            self._fast_forward_s += self.scheduler.next_arrival_s - now
            now = self._now(self._t_start)
        if self.scheduling == "slo":
            self._maybe_preempt(now)
        gate = self._admission_gate if self.paged else None
        while True:
            # one at a time so each admission's block allocation is
            # visible to the next gate evaluation
            admitted = self.scheduler.admit_ready(now, gate=gate,
                                                  limit=1)
            if not admitted:
                break
            self._admit(admitted[0][0], admitted[0][1], now, results)
        if self.paged and not self._inflight and not self._prefilling \
                and self._spilled:
            # stall escape: every runnable request is spilled but the
            # gate vetoes the (fresh) ready head — revive a spilled one
            # out of order; it holds its reservation, so it always fits
            got = self.scheduler.admit_revivable(now, set(self._spilled))
            if got is not None:
                self._admit(got[0], got[1], now, results)
        if self._prefilling:
            self._prefill_tick(results)
        if self._inflight:
            if self.drafter is not None:
                self._spec_tick(results)
            else:
                self._decode_tick(results)

    def run(self, requests: Sequence[Request] = (),
            max_steps: Optional[int] = None, *, warmup: bool = False
            ) -> Tuple[List[RequestResult], dict]:
        """Serve until every submitted request completes.

        Returns ``(results sorted by uid, report)`` where ``report`` is the
        JSON-able aggregate from :func:`repro.serve.metrics.aggregate` plus
        ``slot_reuse`` (admissions into a previously-used slot this run)
        and — paged — a ``paged`` sub-report (block occupancy, prefix-hit
        rate, resident bytes). ``max_steps`` is a runaway backstop, not a
        budget: exceeding it raises RuntimeError (default 1e6 decode
        ticks).

        ``warmup=True`` executes one throwaway prefill + decode/verify tick
        *before* the engine clock starts, so first-call XLA compilation
        lands in the report's ``compile_s`` instead of inflating
        ``wall_s`` / TTFT / ``tok_per_s`` (a warm engine pays ~0 here).
        """
        self.start_run(warmup=warmup)
        for r in requests:
            self.submit(r)
        results: List[RequestResult] = []
        limit = max_steps if max_steps is not None else 1_000_000
        while not self.scheduler.done:
            self.tick(results)
            if self._steps + self._chunk_ticks >= limit:
                raise RuntimeError(
                    f"serve engine exceeded {limit} decode steps with "
                    f"{len(self._inflight)} requests still in flight")
        return self.finish_run(results)

    def finish_run(self, results: List[RequestResult]
                   ) -> Tuple[List[RequestResult], dict]:
        """Price completed requests and build the run report; the closing
        half of the tick-level API."""
        compile_s = self._compile_s
        log_start = self._log_start
        wall = self._now(self._t_start)
        for r in results:
            if self.drafter is not None:
                # acceptance-aware: every (k+1)-token verify pass this
                # request sat through is compute spent, accepted or not
                r.metrics.moa_flops = spec_request_decode_cost(
                    self.model.cfg, k=self.spec_k,
                    tick_contexts=self._tick_contexts.get(r.uid, ()))
            else:
                r.metrics.moa_flops = request_decode_cost(
                    self.model.cfg, prompt_tokens=r.metrics.prompt_tokens,
                    new_tokens=r.metrics.new_tokens)
        report = aggregate(results, n_slots=self.n_slots,
                           decode_steps=self._steps,
                           occupancy_sum=self._occupancy_sum, wall_s=wall,
                           compile_s=compile_s)
        report["slot_reuse"] = self.scheduler.slot_reuse_count(log_start)
        report["arch"] = self.model.cfg.name
        report["moa"] = self.model.cfg.moa_strategy.spec
        report["scheduling"] = self.scheduling
        if self.scheduling == "slo" or any(
                r.metrics.deadline_s is not None for r in results):
            report["slo"] = slo_report(
                results, wall_s=wall, preemptions=self._preemptions,
                spills=self._spills, revivals=self._revivals,
                prefill_chunk_tokens=self._chunk or 0,
                prefill_chunk_count=self._chunk_ticks)
        if self.drafter is not None:
            report["spec"] = spec_report(
                k=self.spec_k, verify_ticks=self._spec_ticks,
                emitted_tokens=self._spec_emitted,
                slot_steps=self._spec_slot_steps,
                accepted_hist=self._accept_hist,
                draft_steps=self.drafter.draft_steps
                - self._draft_steps_start)
        if self.paged:
            report["paged"] = paged_report(
                spec=self._spec, n_slots=self.n_slots, max_len=self.max_len,
                block_size=self.block_size, n_blocks=self.n_blocks,
                admissions=self._admissions, prefix_hits=self._prefix_hits,
                shared_block_hits=self._shared_block_hits,
                cow_count=self._cow_count,
                block_occ_sum=self._block_occ_sum, decode_steps=self._steps,
                peak_blocks=self._peak_blocks,
                attn_backend=resolve_attn_backend(self.model.cfg.attn_backend),
                gathered_kv_bytes=self._gathered_kv_bytes,
                fused_kv_bytes=self._fused_kv_bytes)
        results.sort(key=lambda r: r.uid)
        return results, report
