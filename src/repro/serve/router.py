"""Fault-tolerant replica-set serving: N engines behind one router.

The :class:`ReplicaSet` drives N :class:`~repro.serve.engine.ServeEngine`
replicas tick-by-tick on one shared clock (a
:class:`~repro.serve.clock.StepClock` makes the whole fleet a pure
function of (model, workload, failure schedule, dt) — bit-identical
metrics JSON across runs). Per router step, in a fixed order:

1. **Chaos** — each replica's :class:`~repro.runtime.failures
   .FailureInjector` fires at its scheduled steps; a
   :class:`SimulatedFailure` kills that replica (engine and device state
   discarded).
2. **Reload** — poll the :class:`~repro.checkpoint.watcher
   .CheckpointWatcher`; a new checkpoint step starts a rolling reload:
   one replica at a time is drained (no new routes), its weights swapped
   between ticks once it owns zero requests, then it rejoins. No
   in-flight request is dropped and none straddles two weight versions.
3. **Detect** — the :class:`~repro.runtime.heartbeat.HeartbeatMonitor`
   flags replicas whose beats stopped (``miss_limit`` silent steps); the
   dead replica's requests re-enter the router queue.
4. **Dispatch** — arrived requests route by session affinity: rendezvous
   (highest-random-weight) hash of the prompt's prefix-trie key (its
   first KV-block of tokens) over *accepting* replicas. HRW moves only
   the dead replica's keys when the fleet shrinks, so prefix-cache
   locality survives routing and affinity is stable for live replicas.
5. **Tick** — every live replica advances one engine tick and heartbeats
   its measured duration.

Requeued requests restart from the prompt on the new replica: a crashed
replica's KV pages and slot snapshots are gone, but requests are
self-contained and greedy decode is deterministic, so the regenerated
stream is bit-identical to the one the dead replica was producing (the
chaos suite and ``serving-v7`` assert this against a failure-free
baseline).
"""

from __future__ import annotations

import zlib
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Set, Tuple

from repro.launch.costing import request_decode_cost
from repro.runtime.failures import FailureInjector, SimulatedFailure
from repro.runtime.heartbeat import HeartbeatMonitor
from repro.serve.engine import ServeEngine
from repro.serve.metrics import _dist
from repro.serve.replica import DEAD, DRAINING, HEALTHY, Replica
from repro.serve.request import Request, RequestResult

__all__ = ["ReplicaSet"]


class ReplicaSet:
    """Router + N replicas (see module docstring for the step protocol).

    ``engine_factory`` must build engines that share the router's
    ``clock`` (the fleet runs on one timeline). ``failure_injectors``
    maps replica id → :class:`FailureInjector` whose scheduled steps are
    *router* steps. ``watcher``/``load_params`` enable rolling weight
    reloads: when the watcher reports a new checkpoint step,
    ``load_params(step)`` is called once and the fleet drains/swaps one
    replica at a time.
    """

    def __init__(self, engine_factory: Callable[[], ServeEngine], *,
                 n_replicas: int, clock: Callable[[], float],
                 miss_limit: int = 3,
                 failure_injectors: Optional[
                     Mapping[int, FailureInjector]] = None,
                 watcher=None,
                 load_params: Optional[Callable[[int], object]] = None,
                 affinity_block: Optional[int] = None):
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        self._clock = clock
        self.replicas = [Replica(rid, engine_factory, t_origin=0.0)
                         for rid in range(n_replicas)]
        engine = self.replicas[0].engine
        self._cfg = engine.model.cfg
        if affinity_block is None:
            affinity_block = engine.block_size if engine.paged else 16
        self.affinity_block = max(1, affinity_block)
        self.monitor = HeartbeatMonitor(n_replicas, miss_limit=miss_limit)
        self.injectors = dict(failure_injectors or {})
        self.watcher = watcher
        self._load_params = load_params

        self._step = 0
        self._last_now = 0.0
        self._requests: Dict[int, Request] = {}
        self._queue: List[Request] = []          # awaiting dispatch
        self._assigned: Dict[int, int] = {}      # uid -> rid
        self._results: Dict[int, RequestResult] = {}
        #: rid -> uids lost in a crash, awaiting heartbeat detection
        self._pending_loss: Dict[int, Set[int]] = {}
        self._requeue_count: Dict[int, int] = {}
        self._requeued_at: Dict[int, float] = {}
        self._requeue_latencies: List[float] = []
        self.requeues = 0
        self.deaths_detected = 0
        self.reloads_completed = 0
        self.reload_dropped = 0
        self._reload_queue: List[int] = []
        self._reload_params = None
        self._reload_version = 0
        self._reload_next: Optional[Tuple[int, object]] = None

    # ---- affinity ----------------------------------------------------------
    def _affinity_key(self, prompt: Sequence[int]) -> Tuple[int, ...]:
        """The prompt's prefix-trie key: its first KV-block of tokens (the
        unit the paged pool's prefix cache dedups on), so requests sharing
        a cached prefix land on the replica whose trie is warm."""
        return tuple(prompt[: self.affinity_block])

    def route(self, prompt: Sequence[int]) -> Optional[int]:
        """Rendezvous-hash the prompt's prefix key over accepting
        replicas; None when no replica accepts routes right now."""
        key = ",".join(str(t) for t in self._affinity_key(prompt))
        best_rid, best_w = None, -1
        for rep in self.replicas:
            if not rep.accepting:
                continue
            w = zlib.crc32(f"{key}|{rep.rid}".encode())
            if w > best_w:
                best_rid, best_w = rep.rid, w
        return best_rid

    # ---- public ops (also the chaos suite's op vocabulary) -----------------
    def submit(self, request: Request) -> None:
        if request.uid in self._requests:
            raise ValueError(f"duplicate request uid {request.uid}")
        self._requests[request.uid] = request
        self._queue.append(request)

    def kill(self, rid: int) -> bool:
        """Crash a replica (chaos op / injector target). Idempotent: a
        dead replica stays dead. Its requests are requeued only once the
        heartbeat monitor notices the missing beats."""
        rep = self.replicas[rid]
        if not rep.alive:
            return False
        lost = rep.kill()
        self._pending_loss[rid] = lost
        return True

    def revive(self, rid: int) -> bool:
        """Bring a dead replica back with a fresh engine. Idempotent on
        live replicas. A rejoining node announces it holds no state, so
        any crash loss not yet detected by heartbeat is requeued now."""
        rep = self.replicas[rid]
        if rep.alive:
            return False
        if rid in self._pending_loss:
            self._requeue(rid)
        rep.revive()
        return True

    def begin_reload(self, version: int, params) -> None:
        """Start a rolling weight reload (normally triggered by the
        checkpoint watcher). If one is already in progress the new target
        is deferred until it completes — versions are never skipped."""
        if self._reload_queue:
            self._reload_next = (version, params)
            return
        self._reload_version = version
        self._reload_params = params
        self._reload_queue = [r.rid for r in self.replicas if r.alive]

    @property
    def reloading(self) -> bool:
        return bool(self._reload_queue)

    @property
    def outstanding(self) -> int:
        """Submitted requests that have not completed."""
        return len(self._requests) - len(self._results)

    @property
    def alive_replicas(self) -> List[int]:
        return [r.rid for r in self.replicas if r.alive]

    # ---- internals ---------------------------------------------------------
    def _requeue(self, rid: int) -> None:
        lost = self._pending_loss.pop(rid)
        for uid in sorted(lost):
            # the dead replica can never surface this uid; restart it
            # from its self-contained Request on whoever affinity picks
            del self._assigned[uid]
            self._queue.append(self._requests[uid])
            self._requeue_count[uid] = self._requeue_count.get(uid, 0) + 1
            self._requeued_at[uid] = self._last_now
            self.requeues += 1
        self.deaths_detected += 1

    def _dispatch(self, now: float) -> None:
        # arrival order, uid tie-break; requeued requests arrived long ago
        # so they naturally lead the queue
        self._queue.sort(key=lambda r: (r.arrival_s, r.uid))
        held: List[Request] = []
        for req in self._queue:
            if req.arrival_s > now:
                held.append(req)
                continue
            rid = self.route(req.prompt)
            if rid is None:
                held.append(req)  # nobody accepting; retry next step
                continue
            self.replicas[rid].submit(req)
            self._assigned[req.uid] = rid
        self._queue = held

    def _advance_reload(self) -> None:
        if self.watcher is not None and self._load_params is not None:
            new_step = self.watcher.poll()
            if new_step is not None:
                self.begin_reload(new_step, self._load_params(new_step))
        while self._reload_queue:
            rep = self.replicas[self._reload_queue[0]]
            if rep.state == DEAD:
                self._reload_queue.pop(0)  # crashed mid-drain: skip it
                continue
            if rep.state == HEALTHY:
                rep.begin_drain()
            if rep.state == DRAINING and rep.drained:
                # proof obligation for "no request dropped": count what a
                # buggy drain would have abandoned (always zero)
                self.reload_dropped += len(rep.uids)
                rep.reload(self._reload_params, self._reload_version)
                self._reload_queue.pop(0)
                continue
            break  # head is mid-drain: one replica at a time
        if not self._reload_queue and self._reload_params is not None:
            self._reload_params = None
            self.reloads_completed += 1
            if self._reload_next is not None:
                version, params = self._reload_next
                self._reload_next = None
                self.begin_reload(version, params)

    def _on_result(self, r: RequestResult) -> None:
        if r.uid in self._results:
            raise RuntimeError(f"request {r.uid} completed twice")
        self._results[r.uid] = r
        self._assigned.pop(r.uid, None)
        if r.uid in self._requeued_at:
            self._requeue_latencies.append(
                r.metrics.admitted_s - self._requeued_at.pop(r.uid))

    # ---- the router tick ---------------------------------------------------
    def step(self) -> None:
        """One router step (see module docstring for the phase order)."""
        now = self._last_now = self._clock()
        for rid in sorted(self.injectors):
            try:
                self.injectors[rid].maybe_fail(self._step)
            except SimulatedFailure:
                self.kill(rid)
        self._advance_reload()
        for rid in self.monitor.dead_workers(self._step):
            if rid in self._pending_loss:
                self._requeue(rid)
        self._dispatch(now)
        for rep in self.replicas:
            if not rep.alive:
                continue  # no beat: this silence is what detection reads
            t0 = self._clock()
            finished = rep.tick()
            self.monitor.beat(rep.rid, self._step, self._clock() - t0)
            for r in finished:
                self._on_result(r)
        self._step += 1

    def run(self, requests: Sequence[Request] = (), *,
            max_steps: Optional[int] = None,
            actions: Optional[Mapping[int, Callable[["ReplicaSet"], None]]]
            = None) -> Tuple[List[RequestResult], dict]:
        """Serve until every request completes and any rolling reload
        finishes. ``actions`` maps router step → callback (used by the CLI
        and benchmarks to schedule checkpoint saves mid-run). Raises
        :class:`SimulatedFailure` if the whole fleet is dead with work
        outstanding — the condition a training-style
        :class:`~repro.runtime.supervisor.Supervisor` would restart on."""
        for req in sorted(requests, key=lambda r: (r.arrival_s, r.uid)):
            self.submit(req)
        limit = max_steps if max_steps is not None else 1_000_000
        while self.outstanding or self._reload_queue:
            if actions and self._step in actions:
                actions[self._step](self)
            if self.outstanding and not self.alive_replicas:
                raise SimulatedFailure(
                    f"all {len(self.replicas)} replicas dead with "
                    f"{self.outstanding} requests outstanding")
            self.step()
            if self._step >= limit:
                raise RuntimeError(
                    f"replica router exceeded {limit} steps with "
                    f"{self.outstanding} requests outstanding")
        return self.finish()

    def finish(self) -> Tuple[List[RequestResult], dict]:
        """Price completed requests and build the fleet report (the
        deterministic metrics JSON the chaos suite compares)."""
        results = sorted(self._results.values(), key=lambda r: r.uid)
        for r in results:
            r.metrics.moa_flops = request_decode_cost(
                self._cfg, prompt_tokens=r.metrics.prompt_tokens,
                new_tokens=r.metrics.new_tokens)
        total_new = sum(r.metrics.new_tokens for r in results)
        wall = self._last_now
        report = {
            "n_replicas": len(self.replicas),
            "router_steps": self._step,
            "wall_s": wall,
            "requests": len(self._requests),
            "completed": len(results),
            "lost_requests": len(self._requests) - len(self._results),
            "kills": sum(r.kills for r in self.replicas),
            "deaths_detected": self.deaths_detected,
            "requeues": self.requeues,
            "requeued_requests": len(self._requeue_count),
            "requeue_latency_ms": _dist(
                [1e3 * v for v in self._requeue_latencies]),
            "reloads_completed": self.reloads_completed,
            "reload_dropped": self.reload_dropped,
            "stragglers": len(self.monitor.reports),
            "total_new_tokens": total_new,
            "tok_per_s": total_new / max(wall, 1e-9),
            "replicas": [r.summary() for r in self.replicas],
        }
        return results, report

    # ---- invariants (exercised after every chaos-suite op) -----------------
    def check(self) -> None:
        """Audit router bookkeeping; raises AssertionError on violation.

        R1: queued/assigned/completed partition the submitted uids.
        R2: every uid assigned to a dead replica is awaiting requeue in
            its ``_pending_loss`` entry (nothing can be silently lost).
        R3: a live replica's engine owns exactly the uids the router
            assigned to it.
        R4: at most one replica is draining (rolling reload is serial)
            and any draining replica is the head of the reload queue.
        """
        queued = {r.uid for r in self._queue}
        assigned = set(self._assigned)
        done = set(self._results)
        assert not (queued & assigned), "R1: uid both queued and assigned"
        assert not (queued & done), "R1: uid both queued and completed"
        assert not (assigned & done), "R1: uid both assigned and completed"
        assert queued | assigned | done == set(self._requests), \
            "R1: a submitted uid is unaccounted for (lost)"
        pending = {u for s in self._pending_loss.values() for u in s}
        for uid, rid in self._assigned.items():
            if not self.replicas[rid].alive:
                assert uid in pending, \
                    f"R2: uid {uid} stuck on dead replica {rid}"
        for rep in self.replicas:
            if rep.alive:
                owned = {u for u, rid in self._assigned.items()
                         if rid == rep.rid}
                assert rep.uids == owned, \
                    f"R3: replica {rep.rid} owns {rep.uids} != {owned}"
        draining = [r.rid for r in self.replicas if r.state == DRAINING]
        assert len(draining) <= 1, f"R4: concurrent drains {draining}"
        if draining:
            assert self._reload_queue \
                and self._reload_queue[0] == draining[0], \
                "R4: draining replica is not the reload head"
