"""Per-request and aggregate serving metrics.

Units: times in **seconds** on the engine clock unless a key says ``_ms``
(milliseconds); rates in **tokens per second**; ``moa_flops`` in FLOPs as
priced by the configured MOA strategy (see
:func:`repro.launch.costing.request_decode_cost` — approximate strategies
like LOA inflate this relative to the exact one-shot count).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

__all__ = ["RequestMetrics", "aggregate", "paged_report", "slo_report",
           "spec_report"]


@dataclasses.dataclass
class RequestMetrics:
    """Lifecycle timestamps and derived latencies for one request.

    ``arrival_s <= admitted_s <= first_token_s <= finished_s``; the gap
    ``admitted_s - arrival_s`` is queueing delay (all slots busy), and
    ``first_token_s - admitted_s`` is the prefill time.
    """

    arrival_s: float
    admitted_s: float = 0.0
    first_token_s: float = 0.0
    finished_s: float = 0.0
    prompt_tokens: int = 0
    new_tokens: int = 0
    moa_flops: float = 0.0
    #: prompt tokens whose prefill compute was skipped via a prefix-cache
    #: hit (paged engine, dense family; 0 elsewhere)
    cached_prompt_tokens: int = 0
    #: absolute engine-clock TTFT deadline copied from the request (None =
    #: no SLO on this request)
    deadline_s: Optional[float] = None
    #: times this request was preempted (slot taken away mid-generation
    #: and later revived; 0 under the FIFO policy)
    preempted: int = 0
    #: prefill chunks this request's prompt was split into (1 = one-shot)
    prefill_chunks: int = 1

    @property
    def ttft_s(self) -> float:
        """Time to first token: arrival → prefill logits ready (seconds)."""
        return self.first_token_s - self.arrival_s

    @property
    def decode_s(self) -> float:
        """Time spent in the decode loop after the first token (seconds)."""
        return self.finished_s - self.first_token_s

    @property
    def per_token_ms(self) -> float:
        """Mean decode latency per generated token (milliseconds).

        The first token is priced by ``ttft_s``, so this averages over the
        remaining ``new_tokens - 1`` decode steps.
        """
        steps = max(self.new_tokens - 1, 1)
        return 1e3 * self.decode_s / steps

    @property
    def tok_per_s(self) -> float:
        """Request-level generation rate over its full lifetime."""
        lifetime = max(self.finished_s - self.arrival_s, 1e-9)
        return self.new_tokens / lifetime

    @property
    def deadline_met(self) -> Optional[bool]:
        """True iff the first token beat the TTFT deadline (None when the
        request carries no deadline). Both sides are absolute engine-clock
        seconds, so queueing delay counts against the SLO."""
        if self.deadline_s is None:
            return None
        return self.first_token_s <= self.deadline_s

    def to_json(self) -> dict:
        out = {
            "arrival_s": self.arrival_s,
            "admitted_s": self.admitted_s,
            "ttft_ms": 1e3 * self.ttft_s,
            "per_token_ms": self.per_token_ms,
            "tok_per_s": self.tok_per_s,
            "moa_flops": self.moa_flops,
            "cached_prompt_tokens": self.cached_prompt_tokens,
            "preempted": self.preempted,
            "prefill_chunks": self.prefill_chunks,
        }
        if self.deadline_s is not None:
            out["deadline_s"] = self.deadline_s
            out["deadline_met"] = bool(self.deadline_met)
        return out


def _dist(values: List[float]) -> Dict[str, float]:
    """mean/p50/p95/p99 summary of a latency list (empty → zeros)."""
    if not values:
        return {"mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
    a = np.asarray(values, np.float64)
    return {"mean": float(a.mean()),
            "p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99))}


def aggregate(results, *, n_slots: int, decode_steps: int,
              occupancy_sum: float, wall_s: float,
              compile_s: float = 0.0) -> dict:
    """Fleet-level summary over completed requests.

    ``occupancy_sum`` is the sum over decode steps of
    ``active_slots / n_slots``; divided by ``decode_steps`` it gives mean
    slot occupancy in [0, 1]. ``wall_s`` is total engine run time in
    seconds. ``compile_s`` is the time the engine's warmup tick spent
    compiling *before* the clock started (``ServeEngine.run(warmup=True)``)
    — reported separately exactly so it can never fold into ``wall_s`` and
    skew ``tok_per_s`` / TTFT.
    """
    total_new = sum(r.metrics.new_tokens for r in results)
    return {
        "n_requests": len(results),
        "n_slots": n_slots,
        "decode_steps": decode_steps,
        "wall_s": wall_s,
        "compile_s": compile_s,
        "total_new_tokens": total_new,
        "tok_per_s": total_new / max(wall_s, 1e-9),
        "ttft_ms": _dist([1e3 * r.metrics.ttft_s for r in results]),
        "per_token_ms": _dist([r.metrics.per_token_ms for r in results]),
        "slot_occupancy": occupancy_sum / max(decode_steps, 1),
        "moa_flops_total": sum(r.metrics.moa_flops for r in results),
    }


def paged_report(*, spec, n_slots: int, max_len: int, block_size: int,
                 n_blocks: int, admissions: int, prefix_hits: int,
                 shared_block_hits: int, cow_count: int,
                 block_occ_sum: float, decode_steps: int,
                 peak_blocks: int, attn_backend: str = "jnp",
                 gathered_kv_bytes: int = 0,
                 fused_kv_bytes: int = 0) -> dict:
    """Paged-pool sub-report for the engine's aggregate.

    ``block_occupancy`` averages ``blocks_in_use / n_blocks`` over decode
    steps; ``prefix_hit_rate`` is the fraction of admissions that mapped at
    least one prompt block to an already-resident page.
    ``resident_kv_bytes`` prices the *peak* pages actually holding live
    request state — the number to compare against
    ``dense_equiv_kv_bytes = n_slots · max_len`` worth of statically
    reserved cache (``spec`` is a :class:`repro.models.api.CacheSpec`).
    ``gathered_kv_bytes`` / ``fused_kv_bytes`` price the run's attention
    KV traffic under the two backends — the padded high-water gather
    stream vs. the live blocks the fused block-table kernel actually
    touches (both accumulated per tick from the same cursors, so
    ``fused <= gathered`` at every step; ``attn_backend`` records which
    one actually ran).
    """
    return {
        "block_size": block_size,
        "n_blocks": n_blocks,
        "admissions": admissions,
        "prefix_hits": prefix_hits,
        "prefix_hit_rate": prefix_hits / max(admissions, 1),
        "shared_block_hits": shared_block_hits,
        "cow_count": cow_count,
        "block_occupancy": block_occ_sum / max(decode_steps, 1),
        "peak_blocks_in_use": peak_blocks,
        "resident_kv_bytes": peak_blocks * spec.kv_block_bytes(block_size),
        "dense_equiv_kv_bytes": spec.dense_kv_bytes(n_slots, max_len),
        "attn_backend": attn_backend,
        "gathered_kv_bytes": gathered_kv_bytes,
        "fused_kv_bytes": fused_kv_bytes,
        "gathered_kv_bytes_per_step": gathered_kv_bytes
        / max(decode_steps, 1),
        "fused_kv_bytes_per_step": fused_kv_bytes / max(decode_steps, 1),
    }


def slo_report(results, *, wall_s: float, preemptions: int, spills: int,
               revivals: int, prefill_chunk_tokens: int = 0,
               prefill_chunk_count: int = 0) -> dict:
    """SLO sub-report for the engine's aggregate.

    ``attainment`` is the fraction of deadline-carrying requests whose
    first token beat their absolute TTFT deadline;
    ``goodput_tok_per_s`` counts only tokens generated by requests that
    *met* their deadline (tokens from missed-deadline requests are wasted
    work under the SLO lens) — requests without a deadline always count.
    ``preemptions`` is scheduler-level (slot taken away), ``spills`` /
    ``revivals`` are the engine-level state round-trips backing them
    (mid-prefill preemptions discard progress instead of spilling, so
    ``spills <= preemptions``).
    """
    with_deadline = [r for r in results if r.metrics.deadline_s is not None]
    met = [r for r in with_deadline if r.metrics.deadline_met]
    no_deadline = [r for r in results if r.metrics.deadline_s is None]
    good_tokens = sum(r.metrics.new_tokens for r in met + no_deadline)
    return {
        "deadline_requests": len(with_deadline),
        "deadline_met": len(met),
        "attainment": len(met) / max(len(with_deadline), 1),
        "goodput_tok_per_s": good_tokens / max(wall_s, 1e-9),
        "deadline_ttft_ms": _dist(
            [1e3 * r.metrics.ttft_s for r in with_deadline]),
        "preemptions": preemptions,
        "spills": spills,
        "revivals": revivals,
        "preempted_requests": sum(
            1 for r in results if r.metrics.preempted > 0),
        "prefill_chunk_tokens": prefill_chunk_tokens,
        "prefill_chunk_count": prefill_chunk_count,
    }


def spec_report(*, k: int, verify_ticks: int, emitted_tokens: int,
                slot_steps: float, accepted_hist, draft_steps: int) -> dict:
    """Speculative-decode sub-report for the engine's aggregate.

    ``tokens_per_step`` is **slot-step normalized**: emitted tokens over
    the sum of active slots across verify ticks, so plain decode scores
    exactly 1.0 and a fully-accepted window of ``k`` drafts scores
    ``k + 1`` — the "did the multiplexing gamble pay" number.
    ``accepted_hist[i]`` counts verify ticks (per slot) that accepted
    exactly ``i`` draft tokens; ``draft_steps`` is the drafter's model
    calls (0 for lookup drafters) — the overhead side of the bet.
    """
    hist = [int(c) for c in accepted_hist]
    total = sum(hist)
    return {
        "k": k,
        "verify_ticks": verify_ticks,
        "emitted_tokens": emitted_tokens,
        "tokens_per_step": emitted_tokens / max(slot_steps, 1e-9),
        "accepted_hist": hist,
        "accept_rate": (sum(i * c for i, c in enumerate(hist))
                        / max(total * k, 1)),
        "mean_accepted": sum(i * c for i, c in enumerate(hist))
                         / max(total, 1),
        "draft_steps": draft_steps,
        "draft_steps_per_tick": draft_steps / max(verify_ticks, 1),
    }
