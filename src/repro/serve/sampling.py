"""Token sampling for the serve paths (static ``serve_batch`` and engine).

One abstraction serves both: a :class:`Sampler` carries the per-request
policy, and :func:`sample_batch` applies a *mixed* batch of policies in one
jit-able call (greedy and sampled requests share a decode step).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.layers.numerics import f32_upcast

__all__ = ["Sampler", "GREEDY", "sample_batch"]


@dataclasses.dataclass(frozen=True)
class Sampler:
    """Next-token policy: ``temperature <= 0`` is greedy argmax, otherwise
    categorical sampling over ``logits / temperature``."""

    temperature: float = 0.0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def __call__(self, logits, rng=None):
        """Sample next tokens from ``logits (B, vocab)`` → ``(B,) int32``.

        ``rng`` is required (a ``jax.random`` key) unless greedy.
        """
        if self.greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if rng is None:
            raise ValueError("non-greedy Sampler needs an rng key")
        scaled = f32_upcast(logits) / self.temperature
        return jax.random.categorical(rng, scaled, axis=-1).astype(jnp.int32)


#: the default policy (argmax decode)
GREEDY = Sampler(0.0)


def sample_batch(logits, temperature, greedy_mask, rng):
    """Per-row mixed sampling: ``logits (B, vocab)`` → ``(B,) int32``.

    ``temperature (B,)`` and ``greedy_mask (B,)`` carry each slot's policy;
    greedy rows take the argmax, the rest sample categorically at their own
    temperature. Shapes are fixed in the slot count, so the engine jits
    this once.
    """
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    temp = jnp.maximum(temperature, 1e-6)[:, None]
    sampled = jax.random.categorical(
        rng, f32_upcast(logits) / temp, axis=-1).astype(jnp.int32)
    return jnp.where(greedy_mask, greedy_tok, sampled)
