"""Synthetic open-loop workload: Poisson arrivals, mixed prompt/gen lengths.

Open-loop means arrivals do not wait for the server (unlike a closed loop
where each client waits for its previous request): inter-arrival gaps are
exponential with rate ``rate_rps`` requests/second, so queueing shows up in
TTFT whenever the engine falls behind.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.serve.request import Request
from repro.serve.sampling import GREEDY, Sampler

__all__ = ["bursty_workload", "poisson_workload", "shared_prefix_workload"]


def poisson_workload(*, n_requests: int, vocab: int, rate_rps: float = 50.0,
                     prompt_len_range: Tuple[int, int] = (4, 32),
                     gen_len_range: Tuple[int, int] = (4, 16),
                     sampler: Sampler = GREEDY,
                     eos_id: Optional[int] = None,
                     seed: int = 0) -> List[Request]:
    """Generate ``n_requests`` requests with Poisson arrivals.

    Prompt and generation lengths are drawn uniformly (inclusive) from
    their ranges, token ids uniformly from ``[0, vocab)``. Deterministic
    for a fixed ``seed``. Units: ``rate_rps`` in requests/second, lengths
    in tokens, arrivals in seconds from engine start.
    """
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    if rate_rps <= 0:
        raise ValueError("rate_rps must be > 0")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    requests = []
    for i in range(n_requests):
        p = int(rng.integers(prompt_len_range[0], prompt_len_range[1] + 1))
        g = int(rng.integers(gen_len_range[0], gen_len_range[1] + 1))
        prompt = tuple(int(t) for t in rng.integers(0, vocab, p))
        requests.append(Request(
            uid=i, prompt=prompt, max_new_tokens=g,
            arrival_s=float(arrivals[i]), sampler=sampler, eos_id=eos_id))
    return requests


def bursty_workload(*, vocab: int, n_long: int, n_burst: int,
                    long_prompt_len: int = 24, long_gen_len: int = 48,
                    burst_prompt_len: int = 8, burst_gen_len: int = 4,
                    burst_at_s: float = 0.05,
                    burst_deadline_s: float = 0.25,
                    long_deadline_s: Optional[float] = None,
                    sampler: Sampler = GREEDY,
                    eos_id: Optional[int] = None,
                    seed: int = 0) -> List[Request]:
    """The SLO-scheduling stress shape: long generations first, then a
    burst of short, tight-deadline requests.

    ``n_long`` long-generation requests arrive near t=0 (microsecond
    stagger keeps arrival order deterministic) with a generous deadline of
    ``long_deadline_s`` seconds after arrival (None = no deadline at all);
    once they occupy every slot, ``n_burst`` short requests land together
    at ``burst_at_s`` with deadlines ``burst_deadline_s`` seconds after
    arrival. FIFO queues the burst behind the long decodes and blows its
    p99 TTFT; an SLO scheduler preempts the longs (their first token is
    already banked) and revives them later. Deterministic per ``seed``;
    uids order longs before burst requests.
    """
    if n_long < 1 or n_burst < 1:
        raise ValueError("need at least one long and one burst request")
    rng = np.random.default_rng(seed)
    requests = []
    for i in range(n_long):
        arrival = 1e-6 * i
        prompt = tuple(int(t) for t in rng.integers(0, vocab,
                                                    long_prompt_len))
        requests.append(Request(
            uid=i, prompt=prompt, max_new_tokens=long_gen_len,
            arrival_s=arrival, sampler=sampler, eos_id=eos_id,
            deadline_s=(None if long_deadline_s is None
                        else arrival + long_deadline_s)))
    for j in range(n_burst):
        arrival = burst_at_s + 1e-6 * j
        prompt = tuple(int(t) for t in rng.integers(0, vocab,
                                                    burst_prompt_len))
        requests.append(Request(
            uid=n_long + j, prompt=prompt, max_new_tokens=burst_gen_len,
            arrival_s=arrival, sampler=sampler, eos_id=eos_id,
            deadline_s=arrival + burst_deadline_s))
    return requests


def shared_prefix_workload(*, n_requests: int, vocab: int,
                           rate_rps: float = 50.0, n_prefixes: int = 2,
                           prefix_len: int = 16,
                           suffix_len_range: Tuple[int, int] = (0, 8),
                           gen_len_range: Tuple[int, int] = (4, 16),
                           sampler: Sampler = GREEDY,
                           eos_id: Optional[int] = None,
                           seed: int = 0) -> List[Request]:
    """Poisson workload whose prompts share system-prompt-style prefixes.

    ``n_prefixes`` distinct prefixes of ``prefix_len`` tokens are drawn
    once; each request takes one (round-robin over arrival order — the
    worst case for slot-affinity tricks, the best case for a shared
    physical prefix cache) and appends a random suffix of length drawn
    from ``suffix_len_range`` (0 allowed: identical prompts, which is what
    exercises shared-tail copy-on-write). Deterministic per ``seed``;
    arrival semantics as :func:`poisson_workload`.
    """
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    if rate_rps <= 0:
        raise ValueError("rate_rps must be > 0")
    if n_prefixes < 1 or prefix_len < 1:
        raise ValueError("need at least one prefix of at least one token")
    rng = np.random.default_rng(seed)
    prefixes = [tuple(int(t) for t in rng.integers(0, vocab, prefix_len))
                for _ in range(n_prefixes)]
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    requests = []
    for i in range(n_requests):
        s = int(rng.integers(suffix_len_range[0], suffix_len_range[1] + 1))
        suffix = tuple(int(t) for t in rng.integers(0, vocab, s))
        g = int(rng.integers(gen_len_range[0], gen_len_range[1] + 1))
        requests.append(Request(
            uid=i, prompt=prefixes[i % n_prefixes] + suffix,
            max_new_tokens=g, arrival_s=float(arrivals[i]),
            sampler=sampler, eos_id=eos_id))
    return requests
