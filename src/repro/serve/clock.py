"""Deterministic virtual clocks for the serve engine and its tests.

The engine reads time only through its injected ``clock`` callable
(default ``time.monotonic``). Swapping in a :class:`StepClock` turns the
whole serve stack into a deterministic discrete-event simulator: every
clock read advances virtual time by a fixed ``dt``, so TTFT, queueing
delay, and deadline attainment become exact, replayable numbers — no
wall-clock sleeps, no flaky timing assertions.

A frozen clock (``lambda: 0.0``) also works and is what the legacy tests
use, but it hides queueing delay entirely (time never passes, so every
request's TTFT is 0 unless the engine fast-forwards to an arrival). The
StepClock is what makes FIFO-vs-SLO scheduling *observable*: a request
stuck behind a long generation accumulates dt per engine clock read.
"""

from __future__ import annotations

__all__ = ["StepClock"]


class StepClock:
    """Virtual clock: each call returns the current time, then advances
    it by ``dt`` seconds. Deterministic and monotonic by construction.

    ``dt`` is the simulated cost of one engine clock read; the engine
    reads the clock a small, deterministic number of times per tick, so
    simulated time scales with scheduling work, not host speed.
    """

    def __init__(self, dt: float = 1e-3, start: float = 0.0):
        if dt < 0:
            raise ValueError("dt must be >= 0")
        self.dt = float(dt)
        self.now = float(start)
        #: total number of reads (handy for asserting determinism)
        self.reads = 0

    def __call__(self) -> float:
        t = self.now
        self.now += self.dt
        self.reads += 1
        return t

    def advance(self, seconds: float) -> None:
        """Jump forward without counting a read (test convenience)."""
        if seconds < 0:
            raise ValueError("cannot move a monotonic clock backwards")
        self.now += float(seconds)
