"""Continuous-batching serve engine (the paper's serial accumulator at the
system level).

A fixed pool of decode *slots* plays the role of the constant-size
accumulator state: requests stream in (Poisson open-loop or interactive
``submit``), a freed slot immediately admits the next arrived request via a
bucketed prefill, and one batched :meth:`~repro.models.api.Model.decode_step`
per engine tick folds one token per active slot into the per-slot KV/SSM
state. ``ServeEngine(paged=True)`` swaps the dense per-slot cache regions
for a shared paged block pool with ref-counted prefix caching and
memory-aware admission (:mod:`repro.serve.kv_pool`);
``ServeEngine(drafter=...)`` switches the decode tick to speculative
decoding — draft ``k`` tokens, verify in one pass, commit the accepted
prefix (:mod:`repro.serve.spec`);
``ServeEngine(scheduling="slo", prefill_chunk_tokens=...)`` serves under
TTFT deadlines — chunked prefill interleaves long prompts with decode
ticks and deadline-aware preemption spills/revives running requests
bit-identically (:mod:`repro.serve.clock` makes it a deterministic
simulator). See ``docs/serving.md``, ``docs/paged-kv.md``,
``docs/spec-decode.md`` and ``docs/slo-scheduling.md`` for the design and
scheduler/pool invariants.

Public surface::

    from repro.serve import (Request, Sampler, ServeEngine, poisson_workload)

    engine = ServeEngine(model, params, n_slots=4, max_len=64)
    results, report = engine.run(poisson_workload(
        n_requests=8, rate_rps=50.0, vocab=model.cfg.vocab))
"""

from repro.serve.clock import StepClock
from repro.serve.engine import ServeEngine
from repro.serve.kv_pool import AdmissionPlan, BlockPool, blocks_needed
from repro.serve.metrics import RequestMetrics, aggregate, slo_report
from repro.serve.replica import Replica
from repro.serve.request import FinishReason, Request, RequestResult
from repro.serve.router import ReplicaSet
from repro.serve.sampling import GREEDY, Sampler, sample_batch
from repro.serve.scheduler import SlotScheduler
from repro.serve.spec import (Drafter, DraftModelDrafter, NgramDrafter,
                              OracleDrafter, resolve_drafter, verify_accept)
from repro.serve.workload import (bursty_workload, poisson_workload,
                                  shared_prefix_workload)

__all__ = [
    "AdmissionPlan", "BlockPool", "Drafter", "DraftModelDrafter",
    "FinishReason", "GREEDY", "NgramDrafter", "OracleDrafter", "Request",
    "Replica", "ReplicaSet", "RequestMetrics", "RequestResult", "Sampler", "ServeEngine",
    "SlotScheduler", "StepClock", "aggregate", "blocks_needed",
    "bursty_workload", "resolve_drafter", "sample_batch", "slo_report",
    "verify_accept", "poisson_workload", "shared_prefix_workload",
]
