"""Speculative decoding: draft proposers + acceptance rules.

The paper's core lesson is that a time-multiplexing trick which looks good
on paper (§3.1 serialization) must be validated end-to-end on the real
target — synthesis, not arithmetic, decides whether it pays. Speculative
decoding is the serving-level version of the same gamble: spend one
``k+1``-token verify pass (plus draft work) to collapse up to ``k+1``
serial decode steps into one engine tick. Whether it pays is decided by
the *measured* accept rate, not the proposal heuristic — the engine
reports it (``report["spec"]``) and ``repro.launch.costing``'s
acceptance-aware estimator prices the bet up front
(:func:`repro.launch.costing.spec_decode_cost`).

Pieces:

* :class:`Drafter` — the proposer interface. Per engine tick it sees every
  active slot's token history (prompt + generated, ending with the pending
  next token) and must return exactly ``k`` proposed continuation tokens
  per slot. Proposals are **deterministic** (greedy / lookup): that makes
  the temperature acceptance rule below exact without carrying draft
  distributions around.
* :class:`NgramDrafter` — prompt-lookup decoding: match the history's last
  n-gram against its own earlier occurrences and propose what followed.
  Zero model cost; wins on repetitive/agentic traffic.
* :class:`DraftModelDrafter` — a small draft model greedily continuing
  each slot on its own slot cache, teacher-forced on the committed tokens
  each tick through its own verify/commit machinery (so any family with
  an exact verify can draft).
* :class:`OracleDrafter` — the target model drafting for itself: greedy
  proposals match the target's greedy continuation exactly, forcing accept
  rate 1 (``accept_prob < 1`` corrupts tokens independently to sweep the
  measured accept rate — the benchmark's knob).
* :func:`verify_accept` — the jitted acceptance rule: greedy exact-match
  rows and temperature rejection-sampling rows share one call. With a
  deterministic proposal the rejection-sampling scheme (accept token ``d``
  w.p. ``p(d)``; on rejection sample from ``p`` with ``d`` zeroed and
  renormalized) provably preserves the target distribution.
* :func:`resolve_drafter` — spec-string registry (``"ngram?n=3"``,
  ``"oracle?accept=0.5"``) mirroring the MOA strategy registry grammar.
"""

from __future__ import annotations

import abc
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.layers.numerics import f32_upcast

__all__ = ["Drafter", "NgramDrafter", "DraftModelDrafter", "OracleDrafter",
           "verify_accept", "resolve_drafter"]


# ---------------------------------------------------------------------------
# acceptance
# ---------------------------------------------------------------------------


def verify_accept(logits, draft, temps, greedy, rng):
    """Mixed-policy acceptance over one verify window.

    ``logits (B, T, V)`` are the verify pass's per-position target logits
    (position ``i`` is the distribution of the token *after* the ``i``-th
    fed token), ``draft (B, T-1)`` the proposed tokens, ``temps (B,)`` and
    ``greedy (B,)`` each slot's sampling policy. Returns
    ``(out (B, T) int32, n_acc (B,) int32)``: slot ``b`` emits
    ``out[b, : n_acc[b] + 1]`` — its accepted drafts followed by one
    correction/bonus token (which is *not* yet in the cache: it becomes
    the slot's pending next token).

    Greedy rows accept a draft token iff it equals the target argmax, and
    the emitted tokens are the argmax sequence itself — so a drafter that
    proposes the target's greedy continuation yields bit-identical output
    to plain greedy decode, just fewer ticks. Temperature rows run exact
    rejection sampling against the deterministic proposal (see module
    docstring); all randomness comes from ``rng``, so a fixed engine seed
    reproduces the run.
    """
    B, T, V = logits.shape
    g = jnp.argmax(logits, axis=-1).astype(jnp.int32)            # (B, T)
    lp = f32_upcast(logits) \
        / jnp.maximum(temps, 1e-6)[:, None, None]
    p = jax.nn.softmax(lp, axis=-1)
    ku, kr, kb = jax.random.split(rng, 3)

    p_draft = jnp.take_along_axis(p[:, :-1], draft[..., None],
                                  axis=-1)[..., 0]               # (B, T-1)
    acc_sampled = jax.random.uniform(ku, (B, T - 1)) < p_draft
    acc_greedy = draft == g[:, :-1]
    acc = jnp.where(greedy[:, None], acc_greedy, acc_sampled)
    n_acc = jnp.sum(jnp.cumprod(acc.astype(jnp.int32), axis=1), axis=1)

    # temperature continuation: residual sample at the rejection position,
    # bonus sample after a fully-accepted window
    resid = p[:, :-1] * (1.0 - jax.nn.one_hot(draft, V, dtype=p.dtype))
    resid_tok = jax.random.categorical(
        kr, jnp.log(jnp.maximum(resid, 1e-30)), axis=-1)         # (B, T-1)
    bonus_tok = jax.random.categorical(kb, lp[:, -1], axis=-1)   # (B,)
    idx = jnp.arange(T - 1)[None]
    cont = jnp.where(idx < n_acc[:, None], draft, resid_tok)
    out_sampled = jnp.concatenate([cont, bonus_tok[:, None]], axis=1)
    out = jnp.where(greedy[:, None], g, out_sampled).astype(jnp.int32)
    return out, n_acc.astype(jnp.int32)


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------


class Drafter(abc.ABC):
    """Draft proposer for the serve engine's speculative decode tick.

    Lifecycle: the engine calls :meth:`bind` once at construction (the
    drafter sees slot count, capacity, and the target model), then
    :meth:`admit` / :meth:`release` as requests enter and leave slots, and
    :meth:`propose` once per verify tick. ``draft_steps`` counts draft
    model calls (0 for model-free drafters) — the engine surfaces it as
    the draft-overhead metric.
    """

    def __init__(self, k: int):
        if k < 1:
            raise ValueError(f"draft window k must be >= 1, got {k}")
        self.k = k
        self.draft_steps = 0

    def bind(self, engine) -> None:
        """Called once by the engine before serving starts."""

    def admit(self, slot: int, prompt: Sequence[int]) -> None:
        """A request entered ``slot`` with this prompt."""

    def release(self, slot: int) -> None:
        """The request in ``slot`` finished."""

    @abc.abstractmethod
    def propose(self, histories: Dict[int, Sequence[int]]
                ) -> Dict[int, List[int]]:
        """Propose exactly ``k`` continuation tokens per active slot.

        ``histories[slot]`` is the slot's full token stream — prompt plus
        every committed token, the last being the pending next token whose
        K/V the coming verify writes first. Short heuristic matches must
        be padded to ``k`` (padding is just extra rejected positions).
        """


class NgramDrafter(Drafter):
    """Prompt-lookup decoding: propose what followed the last n-gram.

    For each slot, the longest suffix n-gram (``max_ngram`` down to 1)
    that reoccurs earlier in the history selects its most recent prior
    occurrence, and the ``k`` tokens that followed it become the draft
    (padded by repeating the last token). No model, no state — the whole
    bet is that generation revisits its own context (quoting, code edits,
    agent loops).
    """

    def __init__(self, k: int, *, max_ngram: int = 3):
        super().__init__(k)
        if max_ngram < 1:
            raise ValueError(f"max_ngram must be >= 1, got {max_ngram}")
        self.max_ngram = max_ngram

    def propose(self, histories):
        return {slot: self._lookup(list(hist))
                for slot, hist in histories.items()}

    def _lookup(self, hist: List[int]) -> List[int]:
        pad = [hist[-1]] * self.k
        for n in range(min(self.max_ngram, len(hist) - 1), 0, -1):
            pat = hist[-n:]
            for start in range(len(hist) - n - 1, -1, -1):
                if hist[start:start + n] == pat:
                    cont = hist[start + n:start + n + self.k]
                    if cont:
                        return cont + pad[:self.k - len(cont)]
        return pad


class DraftModelDrafter(Drafter):
    """A small model greedily continuing every slot on its own slot cache.

    The drafter owns a dense slot cache shaped like the engine's
    (``n_slots × max_len``) and keeps it in sync by *teacher-forcing* the
    committed tokens each tick before rolling out ``k`` greedy steps.
    Sync uses the draft model's own verify/commit machinery — a
    ``verify_step`` over the padded per-slot deltas committed at each
    slot's true delta length handles heterogeneous lengths exactly, for
    attention *and* recurrent families alike — and the greedy rollout runs
    on a throwaway copy of the cache, so speculation never pollutes the
    synced state. The draft model can be any family with an exact verify
    (``Model.supports_spec_decode``).
    """

    def __init__(self, model, params, k: int):
        super().__init__(k)
        if not model.supports_spec_decode:
            raise ValueError(
                f"draft model family {model.cfg.family!r} has no exact "
                "multi-token verify, so its state cannot be re-synced "
                "after a rejected speculation")
        self.model = model
        self.params = params

    def bind(self, engine) -> None:
        # cycle-free at runtime; the compile cache is shared with the
        # engine so repeated drafters on one model (the benchmark's oracle
        # accept-rate sweep) never recompile
        from repro.serve.engine import _cached_jit, _write_slot

        model = self.model
        max_len = self.max_len = engine.max_len
        self.n_slots = engine.n_slots
        self._bucket_for = engine.scheduler.bucket_for
        cache = model.init_cache(self.n_slots, self.max_len)
        cache["pos"] = jnp.zeros((self.n_slots,), jnp.int32)
        self.cache = cache
        key = (model.cfg, "drafter")
        if model.supports_padded_prefill:
            self._prefill = _cached_jit(
                key + ("prefill", max_len),
                lambda: jax.jit(lambda p, b, pl: model.prefill(
                    p, b, max_len=max_len, prompt_len=pl)))
        else:
            self._prefill = _cached_jit(
                key + ("prefill", max_len),
                lambda: jax.jit(lambda p, b: model.prefill(
                    p, b, max_len=max_len)))
        self._write = _cached_jit(
            key + ("write",),
            lambda: jax.jit(_write_slot, donate_argnums=(0,)))
        # teacher-force sync: verify + commit (no donation on verify — the
        # rollout snapshot must survive)
        self._tf = _cached_jit(key + ("tf",),
                               lambda: jax.jit(model.verify_step))
        self._commit = _cached_jit(
            key + ("commit",),
            lambda: jax.jit(model.commit_verified, donate_argnums=(0,)))

        def step(params, cache, tokens):
            """One greedy draft decode step."""
            logits, cache = model.decode_step(params, cache, tokens)
            return (jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32),
                    cache)

        self._step = _cached_jit(
            key + ("step",), lambda: jax.jit(step, donate_argnums=(1,)))
        self._consumed: Dict[int, int] = {}

    def admit(self, slot, prompt):
        p = len(prompt)
        toks = np.asarray(prompt, np.int32)[None, :]
        if self.model.supports_padded_prefill:
            bucket = self._bucket_for(p)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :p] = toks[0]
            _, pre = self._prefill(self.params, {"tokens": padded},
                                   jnp.asarray(p, jnp.int32))
        else:
            _, pre = self._prefill(self.params, {"tokens": toks})
        self.cache = self._write(self.cache, pre, slot)
        self._consumed[slot] = p
        self.draft_steps += 1

    def release(self, slot):
        self._consumed.pop(slot, None)

    def propose(self, histories):
        slots = sorted(histories)
        hists = {s: list(histories[s]) for s in slots}
        deltas = {s: hists[s][self._consumed[s]:] for s in slots}
        B, k = self.n_slots, self.k
        # teacher-force the committed deltas in one verify window (the
        # pending next token is always unconsumed, so every active slot
        # has at least one delta token; padding past a slot's delta is
        # committed away by its keep count). Fixed k+1 width — a tick
        # commits at most k accepted drafts + 1 correction — so the
        # verify compiles exactly once.
        n_tf = max(max(len(d) for d in deltas.values()), k + 1)
        tf_toks = np.zeros((B, n_tf), np.int32)
        keep = np.zeros((B,), np.int32)
        for s in slots:
            tf_toks[s, : len(deltas[s])] = deltas[s]
            keep[s] = len(deltas[s])
        logits, cache, aux = self._tf(self.params, self.cache,
                                      jnp.asarray(tf_toks))
        self.cache = self._commit(cache, jnp.asarray(keep), aux)
        self.draft_steps += n_tf
        logits = np.asarray(logits, np.float32)
        drafts = np.zeros((B, k), np.int32)
        for s in slots:
            drafts[s, 0] = int(np.argmax(logits[s, len(deltas[s]) - 1]))
        # greedy rollout of the remaining k-1 drafts on a throwaway cache
        # copy — speculation must not pollute the synced state
        if k > 1:
            synced = self.cache
            self.cache = jax.tree.map(jnp.copy, synced)
            cur = jnp.asarray(drafts[:, 0])
            for j in range(1, k):
                cur, self.cache = self._step(self.params, self.cache,
                                             cur[:, None])
                drafts[:, j] = np.asarray(cur)
                self.draft_steps += 1
            self.cache = synced
        for s in slots:
            self._consumed[s] = len(hists[s])
        return {s: drafts[s].tolist() for s in slots}


class OracleDrafter(DraftModelDrafter):
    """The target model drafting for itself (the accept-rate dial).

    Greedy proposals from the target's own weights match the target's
    greedy continuation token-for-token, so greedy requests accept every
    draft — the forced accept-rate-1 configuration the parity tests and
    the benchmark's upper bound use. ``accept_prob < 1`` independently
    corrupts each proposed token (off-by-one mod vocab — guaranteed to
    miss the greedy argmax), sweeping the *measured* accept rate for the
    "does the gamble pay" curve. Real draft compute is spent either way;
    this drafter measures the acceptance mechanism, not end-to-end win.
    """

    def __init__(self, k: int, *, accept_prob: float = 1.0, seed: int = 0):
        Drafter.__init__(self, k)
        if not 0.0 <= accept_prob <= 1.0:
            raise ValueError(f"accept_prob must be in [0, 1], "
                             f"got {accept_prob}")
        self.accept_prob = accept_prob
        self._corrupt_rng = np.random.default_rng(seed)

    def bind(self, engine) -> None:
        self.model = engine.model
        self.params = engine.params
        super().bind(engine)

    def propose(self, histories):
        out = super().propose(histories)
        if self.accept_prob >= 1.0:
            return out
        vocab = self.model.cfg.vocab
        for s, toks in out.items():
            corrupt = self._corrupt_rng.random(self.k) >= self.accept_prob
            out[s] = [int((t + 1) % vocab) if c else int(t)
                      for t, c in zip(toks, corrupt)]
        return out


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def resolve_drafter(spec: str, k: int) -> Drafter:
    """Build a drafter from a spec string (MOA-registry grammar:
    ``name?key=val&key=val``).

    ``"ngram"`` / ``"ngram?n=3"`` → :class:`NgramDrafter`;
    ``"oracle"`` / ``"oracle?accept=0.5&seed=1"`` → :class:`OracleDrafter`.
    :class:`DraftModelDrafter` needs a built model and parameters, so it
    has no spec-string form — construct it directly.
    """
    name, _, query = spec.partition("?")
    args: Dict[str, str] = {}
    if query:
        for pair in query.split("&"):
            key, _, val = pair.partition("=")
            if not key or not val:
                raise ValueError(f"bad drafter spec {spec!r}")
            args[key] = val
    if name == "ngram":
        drafter = NgramDrafter(k, max_ngram=int(args.pop("n", 3)))
    elif name == "oracle":
        drafter = OracleDrafter(k, accept_prob=float(args.pop("accept", 1.0)),
                                seed=int(args.pop("seed", 0)))
    else:
        raise ValueError(f"unknown drafter {name!r} (known: ngram, oracle)")
    if args:
        raise ValueError(f"drafter {name!r} got unknown keys "
                         f"{sorted(args)}")
    return drafter
