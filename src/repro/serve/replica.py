"""One serve replica: a :class:`~repro.serve.engine.ServeEngine` plus the
fleet lifecycle state the router steers it through.

State machine (``docs/fault-tolerance.md``)::

    healthy ──kill──▶ dead ──revive──▶ healthy
       │                                  ▲
       └──drain (reload)──▶ draining ─────┘
                               │   (drained: swap params, rejoin)
                               └──kill──▶ dead

* **healthy** — accepts new routes, ticks, heartbeats.
* **draining** — ticks and heartbeats but accepts no new routes; the
  router holds it here until every request it owns completes, then swaps
  its weights between ticks and returns it to *healthy*. Draining before
  the swap is what pins every generation to exactly one weight version.
* **dead** — a crash. The engine object (device caches, slot state) is
  discarded; heartbeats stop, and the router's :class:`HeartbeatMonitor`
  detects the silence and requeues the replica's requests. Revival builds
  a *fresh* engine (the module-level compile cache makes this cheap — no
  recompilation, just cache re-init).

A killed replica's device state is unrecoverable, so crash recovery does
not try to move KV pages or spilled slot snapshots across replicas: the
:class:`~repro.serve.request.Request` is self-contained (prompt, budget,
sampler), and greedy decode is deterministic, so re-prefilling the prompt
on a live replica regenerates the exact token stream the dead replica
would have produced. The engine's spill/revive machinery still runs
*within* a replica (SLO preemption), unchanged.
"""

from __future__ import annotations

from typing import Callable, List, Optional, Set

from repro.serve.engine import ServeEngine
from repro.serve.request import Request, RequestResult

__all__ = ["Replica", "HEALTHY", "DRAINING", "DEAD"]

HEALTHY = "healthy"
DRAINING = "draining"
DEAD = "dead"


class Replica:
    """A router-managed serve engine.

    ``engine_factory`` builds a fresh :class:`ServeEngine` (used at
    construction and again on every revival); ``t_origin`` is the fleet
    clock origin every engine run is pinned to, so all replicas report on
    one timeline.
    """

    def __init__(self, rid: int, engine_factory: Callable[[], ServeEngine],
                 *, t_origin: float = 0.0):
        self.rid = rid
        self._factory = engine_factory
        self._t_origin = t_origin
        self.engine: Optional[ServeEngine] = engine_factory()
        if self.engine.drafter is not None:
            raise ValueError(
                "replica serving drives engines tick-by-tick without a "
                "closing report; speculative decoding's per-run drafter "
                "bookkeeping is not supported here")
        self.engine.start_run(t_origin=t_origin)
        self.state = HEALTHY
        #: uids currently owned by this replica (submitted, not finished)
        self.uids: Set[int] = set()
        self.ticks = 0
        self.completed = 0
        self.param_version = 0
        self.kills = 0
        self.revivals = 0
        self.reloads = 0

    # ---- routing predicates ------------------------------------------------
    @property
    def alive(self) -> bool:
        return self.state != DEAD

    @property
    def accepting(self) -> bool:
        """May the router assign new requests here?"""
        return self.state == HEALTHY

    @property
    def drained(self) -> bool:
        """No queued, prefilling, in-flight, or spilled work left."""
        return self.engine is not None and self.engine.scheduler.done

    # ---- lifecycle ---------------------------------------------------------
    def submit(self, request: Request) -> None:
        if not self.alive:
            raise RuntimeError(f"replica {self.rid} is dead")
        self.engine.submit(request)
        self.uids.add(request.uid)

    def tick(self) -> List[RequestResult]:
        """One engine tick; returns the requests that finished on it."""
        if not self.alive:
            raise RuntimeError(f"replica {self.rid} is dead")
        buf: List[RequestResult] = []
        self.engine.tick(buf)
        self.ticks += 1
        for r in buf:
            self.uids.discard(r.uid)
        self.completed += len(buf)
        return buf

    def kill(self) -> Set[int]:
        """Crash: drop the engine (device state is gone) and stop
        heartbeating. Returns the uids that were lost with it — the router
        requeues them once the heartbeat monitor notices the silence."""
        lost, self.uids = self.uids, set()
        self.engine = None
        self.state = DEAD
        self.kills += 1
        return lost

    def revive(self) -> None:
        """Rejoin after a crash with a fresh engine (same factory, same
        fleet clock origin; the compile cache spares re-jitting)."""
        if self.alive:
            raise RuntimeError(f"replica {self.rid} is not dead")
        self.engine = self._factory()
        self.engine.start_run(t_origin=self._t_origin)
        self.state = HEALTHY
        self.revivals += 1

    def begin_drain(self) -> None:
        if self.state != HEALTHY:
            raise RuntimeError(
                f"replica {self.rid} cannot drain from {self.state!r}")
        self.state = DRAINING

    def reload(self, params, version: int) -> None:
        """Swap weights between ticks and rejoin. The router only calls
        this once the replica is drained, so no request straddles two
        weight versions."""
        if self.state != DRAINING:
            raise RuntimeError(
                f"replica {self.rid} must be draining to reload "
                f"(state {self.state!r})")
        if not self.drained:
            raise RuntimeError(
                f"replica {self.rid} still owns {len(self.uids)} requests; "
                "reload would mix weight versions mid-generation")
        self.engine.reload_params(params)
        self.param_version = version
        self.state = HEALTHY
        self.reloads += 1

    def summary(self) -> dict:
        return {
            "rid": self.rid,
            "state": self.state,
            "ticks": self.ticks,
            "completed": self.completed,
            "param_version": self.param_version,
            "kills": self.kills,
            "revivals": self.revivals,
            "reloads": self.reloads,
        }
