"""Request and result types for the serve engine.

Units: all timestamps are **seconds on the engine clock** (0 = engine
start); all lengths are **tokens**; token ids are vocabulary indices.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Tuple

import numpy as np

from repro.serve.metrics import RequestMetrics
from repro.serve.sampling import GREEDY, Sampler

__all__ = ["FinishReason", "Request", "RequestResult"]


class FinishReason(str, enum.Enum):
    EOS = "eos"          # sampled the request's eos_id
    LENGTH = "length"    # produced max_new_tokens


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request (immutable; prompt stored as a token tuple).

    ``arrival_s`` is the open-loop arrival offset in seconds from engine
    start; the scheduler will not admit the request before the engine clock
    reaches it. ``max_new_tokens`` counts generated tokens including the
    one produced by the prefill logits.

    ``priority`` and ``deadline_s`` only influence admission order under
    the scheduler's ``"slo"`` policy (higher priority first, then earliest
    deadline); FIFO ignores both. ``deadline_s`` is the **absolute** engine
    time by which the first token should be emitted (TTFT SLO) — deadline
    attainment in :mod:`repro.serve.metrics` compares it against
    ``first_token_s`` on the same clock.
    """

    uid: int
    prompt: Tuple[int, ...]
    max_new_tokens: int
    arrival_s: float = 0.0
    sampler: Sampler = GREEDY
    eos_id: Optional[int] = None
    priority: int = 0
    deadline_s: Optional[float] = None

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError(f"request {self.uid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.uid}: max_new_tokens must be "
                             f">= 1, got {self.max_new_tokens}")
        if self.deadline_s is not None and self.deadline_s <= self.arrival_s:
            raise ValueError(
                f"request {self.uid}: deadline_s {self.deadline_s} must be "
                f"after arrival_s {self.arrival_s} (absolute engine time)")

    @property
    def prompt_len(self) -> int:
        """Prompt length in tokens."""
        return len(self.prompt)

    def prompt_array(self) -> np.ndarray:
        """Prompt as a ``(1, prompt_len)`` int32 array (prefill layout)."""
        return np.asarray(self.prompt, np.int32)[None, :]


@dataclasses.dataclass
class RequestResult:
    """Completed request: generated tokens + per-request metrics."""

    uid: int
    tokens: np.ndarray            # (new_tokens,) int32 generated ids
    prompt_len: int               # tokens
    slot: int                     # decode slot the request ran in
    finish_reason: FinishReason
    metrics: RequestMetrics

    def to_json(self) -> dict:
        """JSON-able record (benchmarks/serving.py output schema)."""
        return {
            "uid": self.uid,
            "prompt_tokens": self.prompt_len,
            "new_tokens": int(self.tokens.size),
            "slot": self.slot,
            "finish_reason": self.finish_reason.value,
            **self.metrics.to_json(),
        }
