"""AdamW with decoupled weight decay, global-norm clipping, f32 moments.

Pure-pytree implementation (no optax dependency in this container). The
moment tensors share the parameters' sharding — under FSDP the optimizer
state is ZeRO-style sharded for free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    # decay is skipped for 1-D params (norm scales, biases) per convention
    decay_min_ndim: int = 2


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree.map(
        lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    """sqrt(Σ‖g‖²) — itself a cross-device MOA under data parallelism."""
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt_state: dict, params, *, lr,
                 config: AdamWConfig = AdamWConfig()) -> Tuple[Any, dict, dict]:
    """One AdamW step → (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    if config.clip_norm is not None:
        scale = jnp.minimum(1.0, config.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    b1, b2 = config.b1, config.b2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        step = m_hat / (jnp.sqrt(v_hat) + config.eps)
        if p.ndim >= config.decay_min_ndim:
            step = step + config.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m_new, v_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"m": new_m, "v": new_v, "count": count}, metrics
