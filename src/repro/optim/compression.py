"""Int8 gradient compression with error feedback — the *approximate MOA
that works*.

The paper's §3.2 lesson: approximating an adder whose exact version is
hard-wired (FPGA ALM, TPU MXU/VPU) saves nothing. The cross-device gradient
all-reduce is different — its cost is *wire bytes*, not hard adders — so an
approximate representation genuinely buys 4× on the collective roofline
term. Error feedback (Seide et al. 2014; Karimireddy et al. 2019) keeps the
approximation unbiased-in-the-limit: quantization residue is carried to the
next step, so SGD/Adam trajectories converge to the uncompressed fixed
point.

Usage inside a train step::

    comp, err = compressed_gradients(grads, err)   # quantize + feedback
    # comp is int8 (+ f32 scale per tensor): 4× fewer all-reduce bytes;
    # reduction then happens on the dequantized values.

The benchmark ``benchmarks/moa_strategies.py`` reports the collective-term
delta; the hypothesis log lives in docs/architecture.md §Perf levers.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "init_error_feedback",
           "compressed_gradients"]


def compress_int8(x) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization → (q, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)


def compressed_gradients(grads, error_feedback):
    """Quantize each gradient tensor with error feedback.

    Returns ``(dequantized_grads, new_error_feedback)``. The dequantized
    values are exactly what a compressed all-reduce would deliver (quantize
    → sum in int32/f32 → dequantize); the residue ``g - deq`` feeds forward.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = compress_int8(g32)
        deq = decompress_int8(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_feedback)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
