from repro.runtime.heartbeat import HeartbeatMonitor
from repro.runtime.failures import FailureInjector, SimulatedFailure
from repro.runtime.supervisor import Supervisor, RunResult
from repro.runtime.elastic import plan_mesh_shape, plan_replicas

__all__ = ["HeartbeatMonitor", "FailureInjector", "SimulatedFailure",
           "Supervisor", "RunResult", "plan_mesh_shape", "plan_replicas"]
