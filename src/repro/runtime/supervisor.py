"""Restart supervisor: checkpoint-restore training loop with retry budget.

The control plane a real cluster job runs under, scaled to in-process:

  run → (SimulatedFailure | crash) → restore latest checkpoint →
  re-plan mesh for surviving devices (elastic) → resume at ckpt step.

The training function is handed ``(start_step, restored_state)`` and must
checkpoint through the provided manager; determinism of the data pipeline
by step (see data/pipeline.py) guarantees bit-identical resume, which the
integration tests assert.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional

from repro.checkpoint import CheckpointManager
from repro.runtime.failures import SimulatedFailure

__all__ = ["Supervisor", "RunResult"]


@dataclasses.dataclass
class RunResult:
    final_state: Any
    restarts: int
    failures: List[str]
    completed: bool
    wall_time_s: float


class Supervisor:
    def __init__(self, manager: CheckpointManager, *, max_restarts: int = 3):
        self.manager = manager
        self.max_restarts = max_restarts

    def run(self, train_fn: Callable[[int, Optional[Any]], Any],
            *, restore_fn: Optional[Callable[[int], Any]] = None) -> RunResult:
        """``train_fn(start_step, restored_state) -> final_state``.

        ``restore_fn(step) -> state`` rebuilds state from the checkpoint
        (the supervisor does not assume a state pytree structure).
        """
        restarts = 0
        failures: List[str] = []
        t0 = time.monotonic()
        while True:
            start_step = 0
            restored = None
            latest = self.manager.latest_step()
            if latest is not None and restore_fn is not None:
                restored = restore_fn(latest)
                start_step = latest + 1
            try:
                final_state = train_fn(start_step, restored)
                return RunResult(final_state, restarts, failures, True,
                                 time.monotonic() - t0)
            except SimulatedFailure as e:
                failures.append(str(e))
                restarts += 1
                if restarts > self.max_restarts:
                    return RunResult(None, restarts, failures, False,
                                     time.monotonic() - t0)
