"""Deterministic failure injection for fault-tolerance testing."""

from __future__ import annotations

from typing import Iterable, Optional, Set

__all__ = ["SimulatedFailure", "FailureInjector"]


class SimulatedFailure(RuntimeError):
    """Stands in for a node loss / preemption / ICI link error."""


class FailureInjector:
    """Raise :class:`SimulatedFailure` at scheduled steps (each fires once —
    a restarted run that re-executes the same step number survives it, like
    a replaced node)."""

    def __init__(self, fail_at_steps: Iterable[int] = (),
                 kind: str = "node_loss"):
        self._pending: Set[int] = set(fail_at_steps)
        self.kind = kind
        self.fired = []

    def maybe_fail(self, step: int):
        if step in self._pending:
            self._pending.discard(step)
            self.fired.append(step)
            raise SimulatedFailure(f"{self.kind} at step {step}")
