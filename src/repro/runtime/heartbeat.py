"""Straggler detection: per-step heartbeats + robust outlier flags.

At 1000+ nodes the slowest worker sets the step time (synchronous SPMD), so
stragglers must be *detected* (then evicted/replaced by the supervisor —
elastic re-mesh). Detection here is host-side and framework-agnostic:
rolling median + MAD z-score over reported step durations, per worker.
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Deque, Dict, List, Optional

__all__ = ["HeartbeatMonitor", "StragglerReport"]


@dataclasses.dataclass(frozen=True)
class StragglerReport:
    worker: int
    step: int
    duration: float
    median: float
    threshold: float


class HeartbeatMonitor:
    """Track per-worker step durations; flag stragglers.

    A worker is a straggler at a step if its duration exceeds
    ``max(factor × rolling-median, median + z × 1.4826 × MAD)``.
    Missing heartbeats beyond ``miss_limit`` steps mark the worker dead.
    """

    def __init__(self, n_workers: int, *, window: int = 32,
                 factor: float = 2.0, z: float = 6.0, miss_limit: int = 3):
        self.n_workers = n_workers
        self.window = window
        self.factor = factor
        self.z = z
        self.miss_limit = miss_limit
        self._history: Dict[int, Deque[float]] = {
            w: collections.deque(maxlen=window) for w in range(n_workers)}
        self._last_step: Dict[int, int] = {w: -1 for w in range(n_workers)}
        self.reports: List[StragglerReport] = []

    def beat(self, worker: int, step: int, duration: float) -> Optional[StragglerReport]:
        self._last_step[worker] = step
        hist = self._history[worker]
        all_durations = [d for dq in self._history.values() for d in dq]
        report = None
        if len(all_durations) >= max(8, self.n_workers):
            med = statistics.median(all_durations)
            mad = statistics.median([abs(d - med) for d in all_durations]) \
                or 1e-9
            threshold = max(self.factor * med, med + self.z * 1.4826 * mad)
            if duration > threshold:
                report = StragglerReport(worker, step, duration, med,
                                         threshold)
                self.reports.append(report)
        hist.append(duration)
        return report

    def dead_workers(self, current_step: int) -> List[int]:
        return [w for w, s in self._last_step.items()
                if current_step - s > self.miss_limit]
