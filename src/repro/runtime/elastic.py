"""Elastic re-meshing: pick a mesh for however many devices survived.

Policy: keep the model axis fixed if possible (TP degree is dictated by
memory-per-chip), shrink the data axis; fall back to shrinking the model
axis when too few devices remain. Checkpoint restore onto the new mesh is
``CheckpointManager.restore(shardings=...)`` — parameters re-shard via
``device_put``.
"""

from __future__ import annotations

from typing import Optional, Tuple

__all__ = ["plan_mesh_shape", "plan_replicas"]


def plan_mesh_shape(n_devices: int, *, model_parallel: int = 16,
                    min_model_parallel: int = 1) -> Tuple[int, int]:
    """→ (data, model) using as many of ``n_devices`` as possible."""
    if n_devices < 1:
        raise ValueError("no devices")
    mp = min(model_parallel, n_devices)
    while mp >= min_model_parallel:
        if n_devices % mp == 0:
            return (n_devices // mp, mp)
        mp -= 1
    return (n_devices, 1)


def plan_replicas(n_devices: int, *, devices_per_replica: int = 1,
                  min_replicas: int = 1) -> int:
    """Serve-fleet sizing: how many replicas the surviving devices carry.

    Each replica needs ``devices_per_replica`` chips (its TP degree is a
    memory fact, like ``model_parallel`` above, so the replica *count* is
    the elastic axis — a lost host shrinks the fleet, never a replica's
    mesh). Floors at ``min_replicas`` so a degraded fleet keeps serving
    even when the device budget formally rounds to zero.
    """
    if n_devices < 1:
        raise ValueError("no devices")
    if devices_per_replica < 1:
        raise ValueError("devices_per_replica must be >= 1")
    if min_replicas < 1:
        raise ValueError("min_replicas must be >= 1")
    return max(min_replicas, n_devices // devices_per_replica)
