"""Elastic re-meshing: pick a mesh for however many devices survived.

Policy: keep the model axis fixed if possible (TP degree is dictated by
memory-per-chip), shrink the data axis; fall back to shrinking the model
axis when too few devices remain. Checkpoint restore onto the new mesh is
``CheckpointManager.restore(shardings=...)`` — parameters re-shard via
``device_put``.
"""

from __future__ import annotations

from typing import Optional, Tuple

__all__ = ["plan_mesh_shape"]


def plan_mesh_shape(n_devices: int, *, model_parallel: int = 16,
                    min_model_parallel: int = 1) -> Tuple[int, int]:
    """→ (data, model) using as many of ``n_devices`` as possible."""
    if n_devices < 1:
        raise ValueError("no devices")
    mp = min(model_parallel, n_devices)
    while mp >= min_model_parallel:
        if n_devices % mp == 0:
            return (n_devices // mp, mp)
        mp -= 1
    return (n_devices, 1)
