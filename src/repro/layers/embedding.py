"""Token embedding + (optionally tied) output projection."""

from __future__ import annotations

import jax.numpy as jnp

from repro.layers.common import Params, truncated_normal_init

__all__ = ["init_embedding", "embed", "unembed"]


def init_embedding(rng, vocab: int, d_model: int, *, tie: bool = True,
                   dtype=jnp.float32) -> Params:
    import jax

    ke, ku = jax.random.split(rng)
    p = {"table": truncated_normal_init(ke, (vocab, d_model), 0.02, dtype)}
    if not tie:
        p["unembed"] = truncated_normal_init(ku, (vocab, d_model),
                                             d_model ** -0.5, dtype)
    return p


def embed(params: Params, token_ids, *, compute_dtype=jnp.bfloat16):
    """Lookup: (B, S) int -> (B, S, d). A gather — the one-hot matmul MOA
    degenerate case (all-but-one operand zero; SCM removes them for free)."""
    return params["table"].astype(compute_dtype)[token_ids]


def unembed(params: Params, x, *, compute_dtype=jnp.bfloat16):
    """Logits: (B, S, d) -> (B, S, V). Vocab-dim output — shard over model
    axis and keep the softmax vocab-parallel (see losses.py)."""
    table = params.get("unembed", params["table"]).astype(compute_dtype)
    return jnp.einsum("bsd,vd->bsv", x.astype(compute_dtype), table,
                      preferred_element_type=jnp.float32)
