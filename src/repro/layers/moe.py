"""Mixture-of-Experts layer: top-k router + capacity scatter dispatch.

Dispatch design (EP-friendly, dry-run shardable):

  1. router logits ``(T, E)`` → top-k expert ids + softmax gates;
  2. each (token, choice) claims a slot in its expert's capacity buffer —
     slot rank computed by a cumsum over the one-hot assignment matrix
     (linear in T·E, *not* the quadratic GShard (T, E, C) dispatch einsum);
  3. tokens scatter (``.at[].add`` — differentiable) into ``(E, C, d)``;
     with experts sharded over the ``model`` axis this scatter IS the
     all-to-all (XLA SPMD inserts it);
  4. dense per-expert SwiGLU via batched einsum over the expert axis;
  5. gather back + gate-weighted combine (the token-side MOA: k operands).

Tokens over capacity are dropped (standard capacity-factor semantics); the
auxiliary load-balancing loss (Switch §2.2 style) is returned so trainers
can regularize the router.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.layers.common import Params, dense_init
from repro.layers.numerics import einsum_f32, silu_f32
from repro.moa import active_strategy

__all__ = ["init_moe", "moe_forward"]


def init_moe(rng, *, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> Params:
    kr, kg, ku, kd = jax.random.split(rng, 4)
    return {
        "router": dense_init(kr, (d_model, n_experts), dtype, fan_in=d_model),
        "w_gate": dense_init(kg, (n_experts, d_model, d_ff), dtype, fan_in=d_model),
        "w_up": dense_init(ku, (n_experts, d_model, d_ff), dtype, fan_in=d_model),
        "w_down": dense_init(kd, (n_experts, d_ff, d_model), dtype, fan_in=d_ff),
    }


def moe_forward(params: Params, x, *, n_experts: int, top_k: int,
                capacity_factor: float = 1.25, group_size: int = 4096,
                compute_dtype=jnp.bfloat16,
                strategy=None) -> Tuple[jax.Array, jax.Array]:
    """Apply the MoE to ``x: (B, S, d)``. Returns ``(y, aux_loss)``.

    GShard-style grouping: tokens are split into G groups of ``group_size``
    and capacity applies per group. This keeps the slot-rank cumsum local
    (a (group, E) tensor instead of a (T, E) global sequential cumsum —
    at 1M train tokens the global version is both 0.5 TB and a serial
    dependency chain; grouped, it is embarrassingly parallel over data
    shards).

    ``strategy`` (``cfg.moa_for("moe")``; anything :func:`repro.moa.resolve`
    accepts) schedules the expert d/d_ff contractions — vmapped over the
    expert axis since each expert has its own weights — and the token-side
    top-k combine. ``None`` with no active scope keeps the einsum paths.
    """
    B, S, d = x.shape
    T = B * S
    G = max(T // group_size, 1)
    while T % G:
        G -= 1
    tg = T // G                                                    # tokens/group
    xt = x.reshape(G, tg, d).astype(compute_dtype)
    strat = active_strategy(strategy)

    def expert_dot(spec, operands, weights):
        """Per-expert contraction ``(G, E, C, a) x (E, a, b)`` → (G, E, C, b).

        Each expert owns its weight matrix, so the strategy's 2-D ``dot``
        is vmapped over the expert axis (jnp scan and Pallas kernels both
        batch cleanly under vmap).
        """
        if strat is None:
            return einsum_f32(spec, operands,
                              weights.astype(compute_dtype),
                              out_dtype=compute_dtype)
        return jax.vmap(
            lambda xe, we: strat.dot(xe, we.astype(compute_dtype),
                                     out_dtype=compute_dtype),
            in_axes=(1, 0), out_axes=1)(operands, weights)

    # --- routing -------------------------------------------------------------
    if strat is None:
        logits = jnp.einsum("gtd,de->gte", xt,
                            params["router"].astype(compute_dtype)) \
            .astype(jnp.float32)
    else:
        logits = strat.dot(xt, params["router"].astype(compute_dtype),
                           out_dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                        # (G, tg, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)            # (G, tg, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- per-group slot assignment --------------------------------------------
    capacity = max(int(tg * top_k / n_experts * capacity_factor), 1)
    flat_ids = expert_ids.reshape(G, tg * top_k)                   # (G, tk)
    onehot = jax.nn.one_hot(flat_ids, n_experts, dtype=jnp.int32)  # (G, tk, E)
    ranks = jnp.cumsum(onehot, axis=1) - onehot
    slot = jnp.sum(ranks * onehot, axis=-1)                        # (G, tk)
    keep = slot < capacity

    # --- dispatch (the all-to-all under EP sharding) ---------------------------
    xrep = jnp.repeat(xt, top_k, axis=1)                           # (G, tk, d)
    safe_slot = jnp.where(keep, slot, 0)
    contrib = jnp.where(keep[..., None], xrep, 0).astype(compute_dtype)
    buf = jnp.zeros((G, n_experts, capacity, d), compute_dtype)
    g_idx = jnp.arange(G)[:, None]
    buf = buf.at[g_idx, flat_ids, safe_slot].add(contrib)

    # --- expert compute ----------------------------------------------------------
    gates = expert_dot("gecd,edf->gecf", buf, params["w_gate"])
    ups = expert_dot("gecd,edf->gecf", buf, params["w_up"])
    h = silu_f32(gates, out_dtype=compute_dtype) * ups
    out_buf = expert_dot("gecf,efd->gecd", h, params["w_down"])

    # --- combine (token-side MOA over k expert outputs) -------------------------
    gathered = out_buf[g_idx, flat_ids, safe_slot]                 # (G, tk, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    weighted = gathered * gate_vals.reshape(G, tg * top_k, 1) \
        .astype(compute_dtype)
    weighted = weighted.reshape(G, tg, top_k, d)
    if strat is None:
        y = jnp.sum(weighted, axis=2)
    else:
        y = strat.sum(weighted, axis=2).astype(compute_dtype)

    # --- Switch-style load-balance auxiliary loss --------------------------------
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[..., 0], n_experts, dtype=jnp.float32),
        axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = n_experts * jnp.sum(density * router_prob)

    return y.reshape(B, S, d), aux
