"""Backend-aware numerics helpers.

``einsum_f32``: contraction with f32 accumulation. On TPU this is the
MXU-native ``preferred_element_type=f32`` on bf16 operands; the CPU
runtime's DotThunk does not implement batched BF16×BF16→F32, so on CPU the
operands are explicitly up-cast (same math, slower — correctness path
only).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["einsum_f32"]


def einsum_f32(spec: str, a, b, *, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    if jax.default_backend() == "tpu":
        y = jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
    else:
        y = jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))
    return y.astype(out_dtype)
