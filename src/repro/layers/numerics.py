"""Backend-aware numerics helpers — the repo's f32-accumulation anchors.

``einsum_f32``: contraction with f32 accumulation. On TPU this is the
MXU-native ``preferred_element_type=f32`` on bf16 operands; the CPU
runtime's DotThunk does not implement batched BF16×BF16→F32, so on CPU the
operands are explicitly up-cast (same math, slower — correctness path
only).

The remaining helpers are the *named* upcast sites the static auditor
(:mod:`repro.analysis.jaxpr_audit`) allowlists: any bf16/f16 → f32
``convert_element_type`` on a serve path must originate here or in
``layers/attention.py``. Routing an accumulation through one of these
helpers is how a new site gets allowlisted (docs/static-analysis.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "NEG_INF", "einsum_f32", "f32_upcast", "accum_upcast", "silu_f32",
    "softplus_f32", "sum_f32", "online_softmax_init", "kv_scale_zeros",
]

#: finite masking sentinel: keeps exp() well-defined on all-masked rows
NEG_INF = -1e30


def einsum_f32(spec: str, a, b, *, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    if jax.default_backend() == "tpu":
        y = jnp.einsum(spec, a, b, preferred_element_type=jnp.float32)
    else:
        y = jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))
    return y.astype(out_dtype)


def f32_upcast(x):
    """Upcast to f32 ahead of an accumulation / normalization / softmax."""
    return x.astype(jnp.float32)


def accum_upcast(x, accum_dtype):
    """Upcast an MOA operand to its accumulator dtype (usually f32)."""
    return x.astype(accum_dtype)


def silu_f32(x, *, out_dtype=None):
    """SiLU evaluated in f32 (exp underflows in bf16 for moderate |x|)."""
    y = jax.nn.silu(x.astype(jnp.float32))
    return y if out_dtype is None else y.astype(out_dtype)


def softplus_f32(x, *, bias=None):
    """Softplus evaluated in f32 (the SSM dt parameterization); ``bias``
    (e.g. ``dt_bias``) is added after the upcast so the promotion happens
    here, not at the call site."""
    xf = x.astype(jnp.float32)
    if bias is not None:
        xf = xf + bias.astype(jnp.float32)
    return jax.nn.softplus(xf)


def sum_f32(x, *, axis=None, out_dtype=None):
    """Sum-reduce with an explicit f32 accumulator, storing back narrow.

    ``jnp.sum`` already accumulates half floats in f32 internally; naming
    the site moves the upcast here so the auditor sees it as budgeted.
    """
    out_dtype = x.dtype if out_dtype is None else out_dtype
    return jnp.sum(x.astype(jnp.float32), axis=axis).astype(out_dtype)


def online_softmax_init(stat_shape, head_dim: int):
    """The flash-attention running triple ``(max, denom, accum)`` in f32.

    ``stat_shape`` is the per-query statistics shape (e.g.
    ``(B, Hk, G, q_chunk)``); the accumulator appends ``head_dim``.
    """
    m0 = jnp.full(stat_shape, NEG_INF, jnp.float32)
    l0 = jnp.zeros(stat_shape, jnp.float32)
    a0 = jnp.zeros(tuple(stat_shape) + (head_dim,), jnp.float32)
    return m0, l0, a0


def kv_scale_zeros(shape):
    """Zero-initialized per-(pos, head) f32 scales for an int8 KV cache."""
    return jnp.zeros(shape, jnp.float32)
