"""Rotary position embeddings (RoPE)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.layers.numerics import f32_upcast

__all__ = ["rope_frequencies", "apply_rope"]


def rope_frequencies(head_dim: int, *, theta: float = 10000.0):
    """Inverse frequencies for even ``head_dim``: shape ``(head_dim // 2,)``."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x, positions, *, theta: float = 10000.0):
    """Rotate ``x: (..., seq, heads, head_dim)`` by ``positions: (..., seq)``.

    Computed in f32 (sin/cos precision matters at 500k-token positions),
    result cast back to the input dtype.
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta=theta)
    angles = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., S, D/2)
    sin = jnp.sin(angles)[..., :, None, :]  # broadcast over heads
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(f32_upcast(x), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return rotated.astype(x.dtype)
