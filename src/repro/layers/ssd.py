"""Mamba-2 SSD (state-space duality) block — chunked scan formulation.

The SSD chunked algorithm (Dao & Gu, arXiv:2405.21060 §6) is the clearest
LM-scale instance of the paper's §3.1 strategy *succeeding* on TPU: the
sequence-length reduction (an S-operand MOA per state dimension) is split
into chunks of ``ssd_chunk`` operands — intra-chunk handled by a spatial
(MXU) "adder tree" (the quadratic einsum), inter-chunk handled by a *serial
accumulator* (``lax.scan`` carrying the SSM state). ``ssd_chunk`` is the
cluster size ``n_c``; the roofline benchmarks sweep it.

Layout notes: heads are a leading axis (sharded over ``model``); all decay
arithmetic in f32.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.numerics import (f32_upcast, silu_f32, softplus_f32,
                                   sum_f32)

from repro.layers.common import Params, dense_init, init_rms_norm, rms_norm

__all__ = [
    "init_mamba2_block", "mamba2_forward", "mamba2_decode",
    "init_ssm_state", "ssd_chunked",
]


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(a):
    """Within-chunk pairwise decay sums: out[..., l, s] = sum_{s<i<=l} a_i.

    ``a: (..., L)`` → ``(..., L, L)`` lower-triangular (else -inf).
    """
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, a, b, c, *, chunk: int, h0=None):
    """SSD: y_t = C_t^T h_t,  h_t = exp(a_t) h_{t-1} + B_t x_t^T.

    Args:
      x: (B, S, H, P)   per-head inputs (already dt-scaled).
      a: (B, S, H)      per-step log decay (dt * A, negative).
      b: (B, S, H, N)   input maps  (groups already broadcast to heads).
      c: (B, S, H, N)   output maps.
      chunk: intra/inter split — the serialized-MOA cluster size.
      h0: optional initial state (B, H, P, N).

    Returns: (y, h_last) with y (B, S, H, P), h_last (B, H, P, N).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))  # exp(0)=1 decay, x=0: no-op
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    n_chunks = Sp // chunk

    def to_chunks(t):
        return t.reshape((B, n_chunks, chunk) + t.shape[2:])

    xc, ac, bc, cc = map(to_chunks, (x, f32_upcast(a), b, c))
    a_cs = jnp.cumsum(ac, axis=2)                      # (B, C, L, H)

    # 1. intra-chunk (spatial tree / MXU quadratic term)
    Lmat = jnp.exp(_segsum(jnp.moveaxis(ac, -1, 2)))   # (B, C, H, L, L)
    y_diag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp",
                        f32_upcast(cc), f32_upcast(bc),
                        Lmat, f32_upcast(xc))

    # 2. per-chunk end states
    decay_to_end = jnp.exp(a_cs[:, :, -1:, :] - a_cs)  # (B, C, L, H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn",
                        f32_upcast(bc), decay_to_end,
                        f32_upcast(xc))                # (B, C, H, P, N)

    # 3. inter-chunk recurrence — the serial accumulator (§3.1)
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])           # (B, C, H)
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h_prev, xs):
        st, dec = xs
        h_next = h_prev * dec[..., None, None] + st
        return h_next, h_prev

    (h_last, h_prevs) = lax.scan(
        step, f32_upcast(h0),
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)              # (B, C, H, P, N)

    # 4. state → output within each chunk
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       f32_upcast(cc), h_prevs, jnp.exp(a_cs))
    y = (y_diag + y_off).reshape(B, Sp, H, P)[:, :S]
    return y.astype(x.dtype), h_last


# ---------------------------------------------------------------------------
# Mamba-2 block (in_proj → conv → SSD → gated norm → out_proj)
# ---------------------------------------------------------------------------


def init_mamba2_block(rng, *, d_model: int, d_state: int, headdim: int,
                      n_groups: int = 1, d_conv: int = 4, expand: int = 2,
                      dtype=jnp.float32) -> Params:
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_dim = d_inner + 2 * n_groups * d_state
    k_in, k_conv, k_out, k_dt = jax.random.split(rng, 4)
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
    # dt bias: softplus^-1 of dt in [1e-3, 1e-1] (mamba2 default init)
    u = jax.random.uniform(k_dt, (n_heads,), jnp.float32)
    dt_init = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "in_proj": dense_init(k_in, (d_model, d_in_proj), dtype, fan_in=d_model),
        "conv_w": dense_init(k_conv, (d_conv, conv_dim), dtype, fan_in=d_conv),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias.astype(jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "gate_norm": init_rms_norm(d_inner, dtype),
        "out_proj": dense_init(k_out, (d_inner, d_model), dtype, fan_in=d_inner),
    }


def _split_in_proj(z_xbc_dt, *, d_inner, n_groups, d_state, n_heads):
    zs = d_inner
    xs = d_inner
    bs = n_groups * d_state
    z, xp, b, c, dt = jnp.split(
        z_xbc_dt, [zs, zs + xs, zs + xs + bs, zs + xs + 2 * bs], axis=-1)
    return z, xp, b, c, dt


def _causal_depthwise_conv(x, w, b, hist=None):
    """x (B, S, C), w (K, C): depthwise causal conv (pad left K-1).

    ``hist`` (B, K-1, C), when given, replaces the zero left-pad with the
    last K-1 conv inputs of an earlier segment — the chunked-prefill
    continuation. The summation order is identical either way (a fixed
    K-term sum per position), so a history-padded chunk is bit-identical
    to the same positions inside one long conv.
    """
    K = w.shape[0]
    if hist is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([hist.astype(x.dtype), x], axis=1)
    # sum_k w[k] * x[t - (K-1) + k] — small K, unrolled (K=4)
    y = sum(xp[:, k:k + x.shape[1], :] * w[k] for k in range(K))
    return y + b


def mamba2_forward(params: Params, x, *, d_state: int, headdim: int,
                   n_groups: int = 1, expand: int = 2, ssd_chunk: int = 256,
                   compute_dtype=jnp.bfloat16,
                   initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """Mamba-2 mixer over ``x: (B, S, d_model)`` → ``(y, last_state)``.

    ``initial_state`` is either the legacy SSM state array ``(B, H, P, N)``
    or a dict ``{"h", "conv"}`` (the per-layer slice of
    :func:`init_ssm_state`) — the dict form also seeds the depthwise conv
    with the previous segment's last ``d_conv - 1`` inputs, which is what
    makes chunked prefill a bit-identical continuation.
    """
    B, S, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // headdim

    conv_hist = None
    if isinstance(initial_state, dict):
        conv_hist = initial_state["conv"]
        initial_state = initial_state["h"]

    proj = x.astype(compute_dtype) @ params["in_proj"].astype(compute_dtype)
    z, xp, b, c, dt = _split_in_proj(
        proj, d_inner=d_inner, n_groups=n_groups, d_state=d_state,
        n_heads=n_heads)

    conv_in = jnp.concatenate([xp, b, c], axis=-1)
    conv_out = _causal_depthwise_conv(
        conv_in, params["conv_w"].astype(compute_dtype),
        params["conv_b"].astype(compute_dtype), hist=conv_hist)
    conv_out = silu_f32(conv_out, out_dtype=compute_dtype)
    xp, b, c = jnp.split(conv_out, [d_inner, d_inner + n_groups * d_state],
                         axis=-1)

    dt = softplus_f32(dt, bias=params["dt_bias"])                     # (B,S,H)
    A = -jnp.exp(params["a_log"])                                     # (H,)
    a = dt * A                                                        # (B,S,H)

    xh = xp.reshape(B, S, n_heads, headdim)
    heads_per_group = n_heads // n_groups
    bh = jnp.repeat(b.reshape(B, S, n_groups, d_state), heads_per_group, axis=2)
    ch = jnp.repeat(c.reshape(B, S, n_groups, d_state), heads_per_group, axis=2)

    x_dt = xh * dt[..., None].astype(xh.dtype)
    y, h_last = ssd_chunked(x_dt, a, bh, ch, chunk=ssd_chunk, h0=initial_state)
    y = y + xh * params["d_skip"][None, None, :, None].astype(y.dtype)

    y = y.reshape(B, S, d_inner)
    y = rms_norm(params["gate_norm"],
                 (f32_upcast(y)
                  * silu_f32(z)).astype(compute_dtype))
    return y @ params["out_proj"].astype(compute_dtype), h_last


def init_ssm_state(batch: int, *, d_model: int, d_state: int, headdim: int,
                   n_groups: int = 1, d_conv: int = 4, expand: int = 2):
    d_inner = expand * d_model
    n_heads = d_inner // headdim
    conv_dim = d_inner + 2 * n_groups * d_state
    return {
        "h": jnp.zeros((batch, n_heads, headdim, d_state), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, conv_dim), jnp.bfloat16),
    }


def mamba2_decode(params: Params, x, state, *, d_state: int, headdim: int,
                  n_groups: int = 1, expand: int = 2,
                  compute_dtype=jnp.bfloat16):
    """Single-token step: ``x (B, 1, d_model)``, recurrent state update.

    The decode recurrence *is* the paper's serial accumulator with n_c = 1:
    one MAC per state element per step, zero working set beyond the state.
    """
    B, _, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // headdim

    proj = x[:, 0].astype(compute_dtype) @ params["in_proj"].astype(compute_dtype)
    z, xp, b, c, dt = _split_in_proj(
        proj, d_inner=d_inner, n_groups=n_groups, d_state=d_state,
        n_heads=n_heads)

    conv_in = jnp.concatenate([xp, b, c], axis=-1)      # (B, conv_dim)
    conv_hist = jnp.concatenate(
        [state["conv"].astype(compute_dtype), conv_in[:, None]], axis=1)
    w = params["conv_w"].astype(compute_dtype)          # (K, C)
    conv_out = sum_f32(conv_hist * w[None], axis=1,
                       out_dtype=compute_dtype) + params["conv_b"] \
        .astype(compute_dtype)
    conv_out = silu_f32(conv_out, out_dtype=compute_dtype)
    xp, b, c = jnp.split(conv_out, [d_inner, d_inner + n_groups * d_state],
                         axis=-1)

    dt = softplus_f32(dt, bias=params["dt_bias"])                     # (B,H)
    A = -jnp.exp(params["a_log"])
    dA = jnp.exp(dt * A)                                              # (B,H)

    xh = f32_upcast(xp.reshape(B, n_heads, headdim))
    heads_per_group = n_heads // n_groups
    bh = f32_upcast(
        jnp.repeat(b.reshape(B, n_groups, d_state), heads_per_group, axis=1))
    ch = f32_upcast(
        jnp.repeat(c.reshape(B, n_groups, d_state), heads_per_group, axis=1))

    h = state["h"] * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, bh)
    y = jnp.einsum("bhn,bhpn->bhp", ch, h) + xh * params["d_skip"][None, :, None]

    y = y.reshape(B, d_inner)
    y = rms_norm(params["gate_norm"],
                 (y * silu_f32(z)).astype(compute_dtype))
    out = y @ params["out_proj"].astype(compute_dtype)
    new_state = {"h": h, "conv": conv_hist[:, 1:].astype(state["conv"].dtype)}
    return out[:, None], new_state
