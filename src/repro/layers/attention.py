"""GQA attention: chunked (flash-style) softmax, full-softmax, and decode.

The chunked path is the paper's §3.1 *done right on TPU*: the softmax·V
contraction over the KV axis is a multi-operand reduction with up to 524 288
operands (long_500k). Instead of materializing the (Sq × Skv) score matrix
(the "adder tree" — maximal working set), KV blocks stream through a
``lax.scan`` carrying a running (max, denominator, accumulator) triple in
f32 — a serialized MOA whose "serializer" is the hard-wired HBM→VMEM
pipeline. ``kv_chunk`` is the cluster size ``n_c``.

Layouts: q ``(B, Sq, H, D)``, k/v ``(B, Skv, Hk, D)``; GQA groups
``G = H // Hk`` are kept as a separate axis so the ``model``-axis sharding
of Hk stays even.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.common import Params, dense_init
from repro.layers.numerics import NEG_INF, kv_scale_zeros, online_softmax_init
from repro.layers.rope import apply_rope
from repro.parallel import constrain

__all__ = [
    "init_attention", "attention_forward", "attention_decode",
    "attention_decode_paged", "attention_verify", "attention_verify_paged",
    "flash_attention", "full_attention", "init_kv_cache", "init_kv_pool",
    "gather_paged_kv", "resolve_attn_backend",
]

_NEG_INF = NEG_INF  # canonical sentinel lives in layers/numerics.py

#: valid ``attn_backend`` values (mirrors ``moa/backends.py``'s two
#: substrates: a pure-jnp reference and the Pallas kernels)
ATTN_BACKENDS = ("jnp", "pallas")


def resolve_attn_backend(backend: str = "auto") -> str:
    """Resolve the paged-attention backend knob.

    Mirrors ``MOAStrategy.resolve_backend()``: ``"auto"`` selects the fused
    Pallas block-table kernels on TPU and the gather-based jnp reference
    elsewhere (where the kernels would only run in interpret mode — the
    correctness path, not a fast one). Explicit ``"pallas"`` on CPU still
    works via interpret mode, which is how the parity suite exercises the
    kernel schedule on CI.
    """
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ATTN_BACKENDS:
        raise ValueError(f"unknown attn backend {backend!r}; expected "
                         f"'auto' or one of {ATTN_BACKENDS}")
    return backend


def init_attention(rng, *, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False,
                   dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(kq, (d_model, n_heads * head_dim), dtype, fan_in=d_model),
        "wk": dense_init(kk, (d_model, n_kv_heads * head_dim), dtype, fan_in=d_model),
        "wv": dense_init(kv, (d_model, n_kv_heads * head_dim), dtype, fan_in=d_model),
        "wo": dense_init(ko, (n_heads * head_dim, d_model), dtype,
                         fan_in=n_heads * head_dim),
    }
    if qkv_bias:  # qwen1.5 style
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def full_attention(q, k, v, *, causal: bool, positions_q=None, positions_kv=None,
                   kv_len=None):
    """One-shot attention (the spatial "adder tree"): materializes scores.

    Kept as the ``tree`` MOA strategy baseline and for tiny smoke shapes;
    the memory roofline term it produces is the §Perf before/after foil.

    ``kv_len`` limits which cache positions are attended: a scalar applies
    to the whole batch, a ``(B,)`` vector gives per-sequence valid lengths
    (continuous-batching decode, where slots sit at different positions).
    ``positions_q`` may be ``(Sq,)`` (shared) or ``(B, Sq)`` — per-sequence
    query positions, the speculative-verify case where every slot scores
    its draft window starting at its own cursor.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hk, _ = k.shape
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if positions_q is None:
        positions_q = jnp.arange(Sq)
    if positions_kv is None:
        positions_kv = jnp.arange(Skv)
    pq = positions_q if jnp.ndim(positions_q) == 2 else positions_q[None]
    mask = jnp.ones((pq.shape[0], Sq, Skv), bool)       # (B | 1, Sq, Skv)
    if causal:
        mask &= positions_kv[None, None, :] <= pq[:, :, None]
    if kv_len is not None:
        if jnp.ndim(kv_len) == 0:
            mask &= positions_kv[None, None, :] < kv_len
        else:
            mask &= positions_kv[None, None, :] < kv_len[:, None, None]
    mask = mask[:, None, None]                          # (B|1, 1, 1, Sq, Skv)
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_chunk: int = 256,
                    kv_chunk: int = 512, kv_len=None):
    """Chunked-softmax attention (serialized MOA over the KV axis).

    Works for any (Sq, Skv); sequences are padded up to chunk multiples and
    padded KV positions are masked. f32 running statistics.
    """
    B, Sq, H, D = q.shape
    _, Skv, Hk, _ = k.shape
    G = H // Hk
    scale = D ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    pad_q = -Sq % q_chunk
    pad_k = -Skv % kv_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    nq, nk = Sq_p // q_chunk, Skv_p // kv_chunk
    kv_valid = jnp.asarray(Skv if kv_len is None else kv_len, jnp.int32)

    qg = (q.astype(jnp.float32) * scale).reshape(B, nq, q_chunk, Hk, G, D)
    qg = jnp.moveaxis(qg, 1, 0)                      # (nq, B, qc, Hk, G, D)
    kb = jnp.moveaxis(k.reshape(B, nk, kv_chunk, Hk, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, kv_chunk, Hk, D), 1, 0)

    def outer(_, xs):
        qi, q_blk = xs
        q_pos = qi * q_chunk + jnp.arange(q_chunk)

        def inner(carry, inner_xs):
            m, l, acc = carry
            kj, k_blk, v_blk = inner_xs
            kv_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk,
                           k_blk.astype(jnp.float32))
            mask = kv_pos[None, :] < kv_valid
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = lax.scan(inner,
                                  online_softmax_init((B, Hk, G, q_chunk), D),
                                  (jnp.arange(nk), kb, vb))
        o_blk = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,Hk,G,qc,D)
        return None, jnp.moveaxis(o_blk, 3, 1)           # (B,qc,Hk,G,D)

    _, o_blocks = lax.scan(outer, None, (jnp.arange(nq), qg))
    o = jnp.moveaxis(o_blocks, 0, 1).reshape(B, Sq_p, H, D)
    return o[:, :Sq].astype(q.dtype)


def _moa_dot(x, w, *, strategy, compute_dtype):
    """Dense projection routed through the MOA engine (scope-aware).

    The d_model contraction of every attention projection is itself an MOA;
    delegates to :func:`repro.layers.linear.project` so strategy dispatch
    (and the f32-accumulating fallback) lives in exactly one place.
    """
    from repro.layers.linear import project

    return project({"w": w}, x, strategy=strategy,
                   compute_dtype=compute_dtype)


def _project_qkv(params: Params, x, *, n_heads, n_kv_heads, head_dim,
                 compute_dtype, strategy=None):
    B, S, _ = x.shape
    x = x.astype(compute_dtype)

    def dot(w):
        return _moa_dot(x, w.astype(compute_dtype), strategy=strategy,
                        compute_dtype=compute_dtype)

    q = dot(params["wq"])
    k = dot(params["wk"])
    v = dot(params["wv"])
    if "bq" in params:
        q = q + params["bq"].astype(compute_dtype)
        k = k + params["bk"].astype(compute_dtype)
        v = v + params["bv"].astype(compute_dtype)
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv_heads, head_dim)
    v = v.reshape(B, S, n_kv_heads, head_dim)
    return q, k, v


def attention_forward(params: Params, x, *, positions, n_heads: int,
                      n_kv_heads: int, head_dim: int, causal: bool = True,
                      rope_theta: float = 10000.0, use_rope: bool = True,
                      q_chunk: int = 256, kv_chunk: int = 512,
                      impl: str = "flash", compute_dtype=jnp.bfloat16,
                      context_parallel: bool = False, strategy=None):
    """Self-attention over ``x: (B, S, d_model)``.

    ``context_parallel``: constrain Q to a model-axis-sharded *sequence*
    layout (Ulysses-style). Heads stay unsharded; GSPMD inserts the layout
    all-to-all (each device moves only its activation shard) in place of
    the Megatron attn-out all-reduce (which moves the full activation
    twice) — the §Perf collective lever for attention-heavy cells.
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads=n_heads, n_kv_heads=n_kv_heads,
                           head_dim=head_dim, compute_dtype=compute_dtype,
                           strategy=strategy)
    if use_rope:
        q = apply_rope(q, positions, theta=rope_theta)
        k = apply_rope(k, positions, theta=rope_theta)
    if context_parallel:
        q = constrain(q, "batch", "seq_cp", None, None)
        k = constrain(k, "batch", "seq_cp", None, None)
        v = constrain(v, "batch", "seq_cp", None, None)
    if impl == "flash":
        o = flash_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
    else:
        o = full_attention(q, k, v, causal=causal)
    o = o.reshape(B, S, n_heads * head_dim)
    return _moa_dot(o, params["wo"].astype(compute_dtype),
                    strategy=strategy, compute_dtype=compute_dtype)


def _constrain_cache(cache: Params) -> Params:
    """Pin a dense ``(batch, seq, heads, dim)`` KV cache's layout under an
    active sharding context (no-op otherwise): slots on the data axis, KV
    heads on the model axis. Scatter updates route through this so the
    donated cache buffer's sharding never drifts between decode steps
    (docs/sharded-serving.md)."""
    out = dict(cache)
    for key in ("k", "v"):
        out[key] = constrain(out[key], "batch", "kv_seq",
                             "kv_heads_cache", "head_dim")
    for key in ("k_scale", "v_scale"):
        if key in out:
            out[key] = constrain(out[key], "batch", "scale_seq",
                                 "kv_heads_cache")
    return out


def _constrain_pool(pool: Params) -> Params:
    """Paged twin of :func:`_constrain_cache`: the physical block axis is
    shared across slots (replicated — block tables are logical), only the
    head dimension shards."""
    out = dict(pool)
    for key in ("k", "v"):
        out[key] = constrain(out[key], None, None,
                             "kv_heads_cache", "head_dim")
    for key in ("k_scale", "v_scale"):
        if key in out:
            out[key] = constrain(out[key], None, None, "kv_heads_cache")
    return out


def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> Params:
    """KV cache; ``dtype=int8`` stores quantized K/V with per-(pos, head)
    f32 scales — halves the decode-time HBM stream (the memory-roofline
    lever for decode shapes; see docs/paged-kv.md on cache memory)."""
    cache = {
        "k": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
    }
    if dtype == jnp.int8:
        cache["k_scale"] = kv_scale_zeros((batch, max_len, n_kv_heads))
        cache["v_scale"] = kv_scale_zeros((batch, max_len, n_kv_heads))
    return _constrain_cache(cache)


def init_kv_pool(n_phys_blocks: int, block_size: int, n_kv_heads: int,
                 head_dim: int, dtype=jnp.bfloat16) -> Params:
    """Paged KV pool: one shared set of physical pages instead of a dense
    per-slot region. Same leaf set as :func:`init_kv_cache` with the
    sequence axis factored into ``(n_phys_blocks, block_size)``; physical
    block 0 is the engine's write-trash page (see
    :mod:`repro.serve.kv_pool`). The head dimension is constrained so a
    mesh-backed engine materializes the pool model-axis-sharded from the
    start."""
    pool = {
        "k": jnp.zeros((n_phys_blocks, block_size, n_kv_heads, head_dim),
                       dtype),
        "v": jnp.zeros((n_phys_blocks, block_size, n_kv_heads, head_dim),
                       dtype),
    }
    if dtype == jnp.int8:
        pool["k_scale"] = kv_scale_zeros((n_phys_blocks, block_size,
                                          n_kv_heads))
        pool["v_scale"] = kv_scale_zeros((n_phys_blocks, block_size,
                                          n_kv_heads))
    return _constrain_pool(pool)


def quantize_kv(x):
    """Per-(batch, pos, head) symmetric int8 quantization of K or V."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_decode(params: Params, x, cache: Params, pos, *, n_heads: int,
                     n_kv_heads: int, head_dim: int,
                     rope_theta: float = 10000.0, use_rope: bool = True,
                     compute_dtype=jnp.bfloat16,
                     strategy=None) -> Tuple[jax.Array, Params]:
    """One decode step: ``x (B, 1, d)`` against a KV cache at position ``pos``.

    The softmax over the cache is the *decode-time MOA* — a single-operand
    append followed by a 32k–524k-operand reduction. Under SP the cache's
    sequence axis is sharded and XLA's partial reductions realize the
    split-K (parallel-MOA) combine.
    """
    B = x.shape[0]
    q, k_new, v_new = _project_qkv(
        params, x, n_heads=n_heads, n_kv_heads=n_kv_heads, head_dim=head_dim,
        compute_dtype=compute_dtype, strategy=strategy)
    pos_arr = jnp.full((B, 1), pos) if jnp.ndim(pos) == 0 else pos[:, None]
    if use_rope:
        q = apply_rope(q, pos_arr, theta=rope_theta)
        k_new = apply_rope(k_new, pos_arr, theta=rope_theta)

    quantized = "k_scale" in cache

    def write(buf, new):
        if jnp.ndim(pos) == 0:
            return lax.dynamic_update_slice_in_dim(
                buf, new.astype(buf.dtype), pos, axis=1)
        return _scatter_per_batch(buf, new, pos)

    new_cache = dict(cache)
    if quantized:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache["k"] = write(cache["k"], kq)
        new_cache["v"] = write(cache["v"], vq)
        new_cache["k_scale"] = write(cache["k_scale"], ks)
        new_cache["v_scale"] = write(cache["v_scale"], vs)
        new_cache = _constrain_cache(new_cache)
        k_cache = dequantize_kv(new_cache["k"], new_cache["k_scale"],
                                compute_dtype)
        v_cache = dequantize_kv(new_cache["v"], new_cache["v_scale"],
                                compute_dtype)
    else:
        new_cache["k"] = write(cache["k"], k_new)
        new_cache["v"] = write(cache["v"], v_new)
        new_cache = _constrain_cache(new_cache)
        k_cache, v_cache = new_cache["k"], new_cache["v"]

    kv_len = pos + 1
    o = full_attention(q, k_cache, v_cache, causal=False, kv_len=kv_len)
    o = o.reshape(B, 1, n_heads * head_dim)
    y = _moa_dot(o, params["wo"].astype(compute_dtype),
                 strategy=strategy, compute_dtype=compute_dtype)
    return y, new_cache


def _scatter_per_batch(cache, new, pos):
    """Per-sequence cache write when positions differ across the batch."""
    B = cache.shape[0]
    idx = pos.astype(jnp.int32)
    return cache.at[jnp.arange(B), idx].set(new[:, 0].astype(cache.dtype))


def _verify_positions(pos, batch: int, n_tokens: int):
    """Per-slot query positions ``(B, T)`` for a T-token verify window
    starting at each slot's cursor (scalar ``pos`` broadcasts)."""
    start = jnp.full((batch,), pos) if jnp.ndim(pos) == 0 else pos
    return start.astype(jnp.int32)[:, None] + jnp.arange(n_tokens)[None, :]


def attention_verify(params: Params, x, cache: Params, pos, *, n_heads: int,
                     n_kv_heads: int, head_dim: int,
                     rope_theta: float = 10000.0, use_rope: bool = True,
                     compute_dtype=jnp.bfloat16,
                     strategy=None) -> Tuple[jax.Array, Params]:
    """Speculative verify: score ``T`` tokens per slot in one call.

    ``x (B, T, d)`` holds the pending token followed by the draft window;
    slot ``b``'s tokens sit at positions ``pos[b] .. pos[b]+T-1``. All T
    K/V entries are written (tentatively — the engine's commit/rewind
    decides how many survive via the ``pos`` cursor; rows past the cursor
    are causally masked garbage exactly like freed-slot rows), and each
    query attends the cache causally at its own per-slot position, so the
    per-position math is identical to T sequential
    :func:`attention_decode` calls (tests/test_spec_decode.py parity).
    """
    B, T, _ = x.shape
    q, k_new, v_new = _project_qkv(
        params, x, n_heads=n_heads, n_kv_heads=n_kv_heads, head_dim=head_dim,
        compute_dtype=compute_dtype, strategy=strategy)
    pos_q = _verify_positions(pos, B, T)                 # (B, T)
    if use_rope:
        q = apply_rope(q, pos_q, theta=rope_theta)
        k_new = apply_rope(k_new, pos_q, theta=rope_theta)

    b_idx = jnp.arange(B)[:, None]

    def write(buf, new):
        # out-of-range rows (a slot near max_len) drop, never clamp
        return buf.at[b_idx, pos_q].set(new.astype(buf.dtype),
                                        mode="drop")

    new_cache = dict(cache)
    if "k_scale" in cache:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache["k"] = write(cache["k"], kq)
        new_cache["v"] = write(cache["v"], vq)
        new_cache["k_scale"] = write(cache["k_scale"], ks)
        new_cache["v_scale"] = write(cache["v_scale"], vs)
        new_cache = _constrain_cache(new_cache)
        k_cache = dequantize_kv(new_cache["k"], new_cache["k_scale"],
                                compute_dtype)
        v_cache = dequantize_kv(new_cache["v"], new_cache["v_scale"],
                                compute_dtype)
    else:
        new_cache["k"] = write(cache["k"], k_new)
        new_cache["v"] = write(cache["v"], v_new)
        new_cache = _constrain_cache(new_cache)
        k_cache, v_cache = new_cache["k"], new_cache["v"]

    o = full_attention(q, k_cache, v_cache, causal=True, positions_q=pos_q)
    o = o.reshape(B, T, n_heads * head_dim)
    y = _moa_dot(o, params["wo"].astype(compute_dtype),
                 strategy=strategy, compute_dtype=compute_dtype)
    return y, new_cache


def attention_verify_paged(params: Params, x, pool: Params, block_tables,
                           pos, *, n_heads: int, n_kv_heads: int,
                           head_dim: int, rope_theta: float = 10000.0,
                           use_rope: bool = True,
                           compute_dtype=jnp.bfloat16,
                           strategy=None, backend: str = "jnp",
                           live_blocks: Optional[int] = None,
                           ) -> Tuple[jax.Array, Params]:
    """Paged twin of :func:`attention_verify`.

    The T tentative K/V entries scatter to pages
    ``block_tables[b, (pos+i) // bs]``. The engine's admission reserves a
    ``k``-token margin of private pages past every request's worst-case
    length, so speculative writes only ever land on pages owned by the
    writing slot (or the trash page, for logical blocks past the table) —
    a rejected position is rolled back by rewinding ``pos`` alone and the
    page row is simply overwritten when decode reaches it again.

    ``backend`` / ``live_blocks`` behave as in
    :func:`attention_decode_paged`; the pallas path is the paged
    flash-**prefill** kernel instance (T-token contiguous window per slot),
    which is also what the bucketed suffix-prefill path runs. Callers must
    size ``live_blocks`` to cover ``max(pos) + T`` positions, not just the
    cursors.
    """
    B, T, _ = x.shape
    bs = pool["k"].shape[1]
    q, k_new, v_new = _project_qkv(
        params, x, n_heads=n_heads, n_kv_heads=n_kv_heads, head_dim=head_dim,
        compute_dtype=compute_dtype, strategy=strategy)
    pos_q = _verify_positions(pos, B, T)                 # (B, T)
    if use_rope:
        q = apply_rope(q, pos_q, theta=rope_theta)
        k_new = apply_rope(k_new, pos_q, theta=rope_theta)

    b_idx = jnp.arange(B)[:, None]
    logical = pos_q // bs
    n_logical = block_tables.shape[1]
    blk = block_tables[b_idx, jnp.minimum(logical, n_logical - 1)]
    # positions past the table (idle slots sitting at high cursors) go to
    # physical block 0 — the engine's write-trash page
    blk = jnp.where(logical < n_logical, blk, 0)         # (B, T)
    off = pos_q % bs

    def write(pool_leaf, new):
        return pool_leaf.at[blk, off].set(new.astype(pool_leaf.dtype))

    new_pool = dict(pool)
    if "k_scale" in pool:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_pool["k"] = write(pool["k"], kq)
        new_pool["v"] = write(pool["v"], vq)
        new_pool["k_scale"] = write(pool["k_scale"], ks)
        new_pool["v_scale"] = write(pool["v_scale"], vs)
    else:
        new_pool["k"] = write(pool["k"], k_new)
        new_pool["v"] = write(pool["v"], v_new)
    new_pool = _constrain_pool(new_pool)

    if resolve_attn_backend(backend) == "pallas":
        o = _paged_attention_fused(q, new_pool, block_tables, pos_q[:, 0],
                                   compute_dtype=compute_dtype,
                                   live_blocks=live_blocks)
    else:
        k_cache, v_cache = gather_paged_kv(new_pool, block_tables,
                                           compute_dtype,
                                           live_blocks=live_blocks)
        o = full_attention(q, k_cache, v_cache, causal=True,
                           positions_q=pos_q)
    o = o.reshape(B, T, n_heads * head_dim)
    y = _moa_dot(o, params["wo"].astype(compute_dtype),
                 strategy=strategy, compute_dtype=compute_dtype)
    return y, new_pool


# ---------------------------------------------------------------------------
# paged decode path (gather-based; see docs/paged-kv.md)
# ---------------------------------------------------------------------------


def gather_paged_kv(pool: Params, block_tables, dtype=jnp.bfloat16,
                    *, live_blocks: Optional[int] = None):
    """Materialize each sequence's logical KV view from the shared pool.

    ``pool`` leaves are ``(n_phys_blocks, block_size, ...)``;
    ``block_tables`` is ``(B, max_blocks)`` int32 logical→physical. Returns
    dense ``(B, n_blk·block_size, Hk, D)`` K and V (dequantized for an
    int8 pool). With ``block_size`` dividing ``max_len`` the gathered view
    has *exactly* the dense cache's shape, and every attended position
    holds the same value — the paged read is bit-identical by construction
    (unattended garbage is masked to ``_NEG_INF`` before the softmax either
    way).

    ``live_blocks`` (static) truncates the gather to the batch's high-water
    logical block — pages past *every* slot's cursor were fully masked, so
    not streaming them is float-bit-identical (a masked score contributes
    an exact f32 zero to the softmax and never holds the row max) while
    cutting the gathered HBM traffic from ``max_blocks`` to the live depth.
    """
    if live_blocks is not None:
        block_tables = block_tables[:, :live_blocks]

    def flat(name):
        x = pool[name][block_tables]         # (B, n_blk, bs, ...)
        return x.reshape((x.shape[0], -1) + x.shape[3:])

    k, v = flat("k"), flat("v")
    if "k_scale" in pool:
        k = dequantize_kv(k, flat("k_scale"), dtype)
        v = dequantize_kv(v, flat("v_scale"), dtype)
    # the gathered logical view carries the dense-slot layout: slots over
    # data, heads over model (the score reduction then never reshards)
    k = constrain(k, "batch", "kv_seq", "kv_heads_cache", "head_dim")
    v = constrain(v, "batch", "kv_seq", "kv_heads_cache", "head_dim")
    return k, v


def _paged_attention_fused(q, pool: Params, block_tables, start, *,
                           compute_dtype=jnp.bfloat16,
                           live_blocks: Optional[int] = None):
    """Route the paged score reduction through the fused Pallas kernel.

    ``q: (B, T, H, D)`` queries at positions ``start[b] .. start[b]+T-1``.
    The kernel walks the (optionally high-water-truncated) block tables
    inside the grid and dequantizes int8 pools in-register — the dense
    gathered view of :func:`gather_paged_kv` never exists.
    ``compute_dtype`` is the dtype the gather path would materialize that
    view in; the kernel rounds its dequantized values through it so the
    two backends agree bit-for-bit on every attended KV entry.
    """
    from repro.kernels import ops as kernel_ops

    if live_blocks is not None:
        block_tables = block_tables[:, :live_blocks]
    return kernel_ops.paged_attention(
        q, pool["k"], pool["v"], block_tables, start,
        k_scale=pool.get("k_scale"), v_scale=pool.get("v_scale"),
        dequant_dtype=compute_dtype)


def attention_decode_paged(params: Params, x, pool: Params, block_tables,
                           pos, *, n_heads: int, n_kv_heads: int,
                           head_dim: int, rope_theta: float = 10000.0,
                           use_rope: bool = True,
                           compute_dtype=jnp.bfloat16,
                           strategy=None, backend: str = "jnp",
                           live_blocks: Optional[int] = None,
                           ) -> Tuple[jax.Array, Params]:
    """One decode step against a *paged* KV pool.

    Identical math to :func:`attention_decode` — same projections, same
    rope, same masked full-softmax reduction — with the cache read/write
    factored through per-slot block tables: the new token's K/V scatters to
    physical page ``block_tables[b, pos // bs]`` offset ``pos % bs``, and
    the score reduction runs over the gathered logical view. The engine
    guarantees writes only ever land on unshared pages (copy-on-write
    happens host-side before the first divergent write), so slots at
    heterogeneous depths share physical prefix pages safely.

    ``backend`` picks the score-reduction substrate (resolved via
    :func:`resolve_attn_backend`): ``"jnp"`` gathers the dense logical view
    (reference), ``"pallas"`` runs the fused block-table kernel — greedy
    tokens are bit-identical, floats agree to online-softmax reassociation.
    ``live_blocks`` (static) bounds both paths to the batch's high-water
    logical block.
    """
    B = x.shape[0]
    bs = pool["k"].shape[1]
    q, k_new, v_new = _project_qkv(
        params, x, n_heads=n_heads, n_kv_heads=n_kv_heads, head_dim=head_dim,
        compute_dtype=compute_dtype, strategy=strategy)
    pos = pos[:, None] if jnp.ndim(pos) == 1 else jnp.full((B, 1), pos)
    if use_rope:
        q = apply_rope(q, pos, theta=rope_theta)
        k_new = apply_rope(k_new, pos, theta=rope_theta)

    cur = pos[:, 0]
    blk = block_tables[jnp.arange(B), cur // bs]
    off = cur % bs

    new_pool = dict(pool)
    if "k_scale" in pool:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_pool["k"] = pool["k"].at[blk, off].set(kq[:, 0])
        new_pool["v"] = pool["v"].at[blk, off].set(vq[:, 0])
        new_pool["k_scale"] = pool["k_scale"].at[blk, off].set(ks[:, 0])
        new_pool["v_scale"] = pool["v_scale"].at[blk, off].set(vs[:, 0])
    else:
        new_pool["k"] = pool["k"].at[blk, off].set(
            k_new[:, 0].astype(pool["k"].dtype))
        new_pool["v"] = pool["v"].at[blk, off].set(
            v_new[:, 0].astype(pool["v"].dtype))
    new_pool = _constrain_pool(new_pool)

    if resolve_attn_backend(backend) == "pallas":
        o = _paged_attention_fused(q, new_pool, block_tables, cur,
                                   compute_dtype=compute_dtype,
                                   live_blocks=live_blocks)
    else:
        k_cache, v_cache = gather_paged_kv(new_pool, block_tables,
                                           compute_dtype,
                                           live_blocks=live_blocks)
        o = full_attention(q, k_cache, v_cache, causal=False, kv_len=cur + 1)
    o = o.reshape(B, 1, n_heads * head_dim)
    y = _moa_dot(o, params["wo"].astype(compute_dtype),
                 strategy=strategy, compute_dtype=compute_dtype)
    return y, new_pool
