"""MOA-strategy-aware linear layer.

Every dense contraction in the framework goes through :func:`project`, which
schedules its K-dimension reduction per a :mod:`repro.moa` strategy — the
paper's design knob made a framework-wide config. ``strategy`` accepts a
spec string (``"serial?chunk=512"``), an :class:`repro.moa.MOAStrategy`, or
a legacy :class:`repro.core.moa.ReductionStrategy`; an ambient
:func:`repro.moa.moa_scope` override wins over all of them. With the
default ``serial`` strategy and ``chunk >= K`` the jnp backend lowers to a
single MXU matmul (zero overhead); smaller chunks serialize the contraction
(bounding the live working set of very wide reductions, e.g. d_ff=53248 on
llama3-405b), and ``backend="pallas"`` (or ``auto`` on TPU) executes the
``dot_moa`` kernel.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.layers.common import Params, dense_init
from repro.moa import active_strategy

__all__ = ["init_linear", "project"]


def init_linear(rng, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32) -> Params:
    p = {"w": dense_init(rng, (d_in, d_out), dtype, fan_in=d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def project(params: Params, x, *, strategy=None,
            compute_dtype=jnp.bfloat16):
    """``x @ w (+ b)`` with the contraction scheduled per ``strategy``.

    ``x: (..., d_in)``; weights are cast to ``compute_dtype`` at use
    (master copy stays f32), accumulation is f32 (MXU hard-wired).
    ``strategy=None`` (and no active scope) is the plain one-shot matmul.
    """
    w = params["w"].astype(compute_dtype)
    x = x.astype(compute_dtype)
    strat = active_strategy(strategy)
    if strat is None:
        y = jnp.matmul(x, w, preferred_element_type=jnp.float32) \
            .astype(compute_dtype)
    else:
        y = strat.dot(x, w, out_dtype=compute_dtype)
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y
