"""MOA-strategy-aware linear layer.

Every dense contraction in the framework goes through :func:`project`, which
schedules its K-dimension reduction per the model's
:class:`repro.core.moa.ReductionStrategy` — the paper's design knob made a
framework-wide config. With the default ``serial`` strategy and ``chunk >= K``
this lowers to a single MXU matmul (zero overhead); smaller chunks serialize
the contraction via ``lax.scan`` (useful to bound the live working set of
very wide reductions, e.g. d_ff=53248 on llama3-405b).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.moa import ReductionStrategy, chunked_matmul
from repro.layers.common import Params, dense_init

__all__ = ["init_linear", "project"]


def init_linear(rng, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32) -> Params:
    p = {"w": dense_init(rng, (d_in, d_out), dtype, fan_in=d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def project(params: Params, x, *, strategy: Optional[ReductionStrategy] = None,
            compute_dtype=jnp.bfloat16):
    """``x @ w (+ b)`` with the contraction scheduled per ``strategy``.

    ``x: (..., d_in)``; weights are cast to ``compute_dtype`` at use
    (master copy stays f32), accumulation is f32 (MXU hard-wired).
    """
    w = params["w"].astype(compute_dtype)
    x = x.astype(compute_dtype)
    k = x.shape[-1]
    if strategy is not None and strategy.kind == "serial" and strategy.chunk < k:
        y = chunked_matmul(
            x, w, chunk=strategy.chunk,
            accum_dtype=strategy.accum_dtype, out_dtype=compute_dtype,
        )
    else:
        y = jnp.matmul(x, w, preferred_element_type=jnp.float32) \
            .astype(compute_dtype)
    if "b" in params:
        y = y + params["b"].astype(compute_dtype)
    return y
