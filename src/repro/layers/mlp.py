"""Feed-forward blocks: SwiGLU (llama family) and GELU (encoder family).

The d_ff contraction of ``w_down`` is the widest MOA in most dense archs
(llama3-405b: 53 248 operands) — it routes through the model's MOA
strategy (``cfg.moa_for("mlp")``) via :func:`repro.layers.linear.project`.
``strategy`` accepts anything :func:`repro.moa.resolve` does (spec string,
strategy instance, legacy ReductionStrategy).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.layers.common import Params, dense_init
from repro.layers.linear import project
from repro.layers.numerics import silu_f32

__all__ = ["init_swiglu", "swiglu", "init_gelu_mlp", "gelu_mlp"]


def init_swiglu(rng, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    kg, ku, kd = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(kg, (d_model, d_ff), dtype, fan_in=d_model),
        "w_up": dense_init(ku, (d_model, d_ff), dtype, fan_in=d_model),
        "w_down": dense_init(kd, (d_ff, d_model), dtype, fan_in=d_ff),
    }


def swiglu(params: Params, x, *, strategy=None,
           compute_dtype=jnp.bfloat16):
    g = project({"w": params["w_gate"]}, x, strategy=strategy,
                compute_dtype=compute_dtype)
    u = project({"w": params["w_up"]}, x, strategy=strategy,
                compute_dtype=compute_dtype)
    h = silu_f32(g, out_dtype=compute_dtype) * u
    return project({"w": params["w_down"]}, h, strategy=strategy,
                   compute_dtype=compute_dtype)


def init_gelu_mlp(rng, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ki, ko = jax.random.split(rng)
    return {
        "w_in": dense_init(ki, (d_model, d_ff), dtype, fan_in=d_model),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(ko, (d_ff, d_model), dtype, fan_in=d_ff),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params: Params, x, *, strategy=None,
             compute_dtype=jnp.bfloat16):
    h = project({"w": params["w_in"], "b": params["b_in"]}, x,
                strategy=strategy, compute_dtype=compute_dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(compute_dtype)
    return project({"w": params["w_out"], "b": params["b_out"]}, h,
                   strategy=strategy, compute_dtype=compute_dtype)
