"""Shared layer primitives: norms, initializers, dtype policy.

Parameters are plain pytrees (nested dicts of jnp arrays) — no framework
dependency. Every layer exposes ``init(rng, ...) -> params`` and a pure
``apply``-style function.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.layers.numerics import f32_upcast

Params = Dict[str, Any]

__all__ = ["Params", "DTypePolicy", "rms_norm", "layer_norm", "init_rms_norm",
           "init_layer_norm", "dense_init", "truncated_normal_init", "split_keys"]


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Precision policy: f32 master params, bf16 compute (MXU-native)."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # Accumulation is always f32 — the MXU hard-wires it; see docs/moa-strategies.md.
    accum_dtype: Any = jnp.float32

    def cast(self, x):
        return jax.tree.map(
            lambda a: a.astype(self.compute_dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a,
            x,
        )


def split_keys(rng, n):
    return list(jax.random.split(rng, n))


def truncated_normal_init(rng, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) \
        .astype(dtype)


def dense_init(rng, shape, dtype=jnp.float32, *, fan_in=None):
    """Scaled initializer: stddev = 1/sqrt(fan_in)."""
    fan_in = fan_in or shape[0]
    return truncated_normal_init(rng, shape, stddev=fan_in ** -0.5, dtype=dtype)


def init_rms_norm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: Params, x, *, eps: float = 1e-6):
    """RMSNorm in f32 (mixed_precision_sensitive: the 1/sqrt(mean(x²))
    reduction is itself a multi-operand adder — always exact f32)."""
    xf = f32_upcast(x)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * f32_upcast(params["scale"])).astype(x.dtype)


def init_layer_norm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(params: Params, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)
