"""Checkpoint watcher: edge-triggered "a newer step landed" polling.

The serve-side half of the train → checkpoint → serve-reload loop: a
:class:`~repro.serve.router.ReplicaSet` polls the watcher once per router
step and starts a rolling weight reload when a new checkpoint commits.
Polling keys off :meth:`CheckpointManager.available_steps`, which only
lists steps whose manifest rename committed — a crash mid-save is never
reported.
"""

from __future__ import annotations

from typing import Optional

from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointWatcher"]


class CheckpointWatcher:
    """Report each new latest checkpoint step exactly once.

    ``start_step`` is the step the caller already serves (``None`` =
    nothing loaded yet, so any existing checkpoint is news). ``poll()``
    returns the new latest step the first time it is seen, else ``None``.
    A step is considered news only if it is *newer* than the last seen —
    retention GC shrinking ``available_steps`` never re-reports.
    """

    def __init__(self, manager: CheckpointManager, *,
                 start_step: Optional[int] = None):
        self.manager = manager
        self._seen = start_step

    def poll(self) -> Optional[int]:
        latest = self.manager.latest_step()
        if latest is None:
            return None
        if self._seen is None or latest > self._seen:
            self._seen = latest
            return latest
        return None
