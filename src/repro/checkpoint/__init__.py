from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.watcher import CheckpointWatcher

__all__ = ["CheckpointManager", "CheckpointWatcher"]
