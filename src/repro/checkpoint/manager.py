"""Sharded, atomic, async checkpointing with elastic restore.

Production semantics scaled to this container:

  * **Atomicity** — writes go to ``step_<n>.tmp/`` and are renamed into
    place only after the manifest fsync; a crash mid-save never corrupts
    the latest checkpoint.
  * **Sharding** — each host saves only the leaves (or leaf-slices) it
    owns; here ``shard_id``/``n_shards`` emulate the host grid (leaf-level
    round-robin — shape-agnostic and valid for any pytree).
  * **Async** — ``save_async`` snapshots to host RAM synchronously (so the
    training step can donate its buffers) and writes on a worker thread;
    ``wait()`` joins. A failure during an async save is reported on the
    next call, as a real multi-host checkpointer does.
  * **Elastic restore** — ``restore(..., shardings=...)`` ``device_put``s
    every leaf to the *target* sharding, which may correspond to a
    different mesh shape than the one that saved (elastic re-scaling).
  * **Retention** — keeps the newest ``keep`` checkpoints, never deleting
    an unfinished write.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d+)$")

# dtypes npz handles natively; everything else (bfloat16, fp8, …) is
# stored bit-exactly as a same-width uint + logical name in the manifest
_NATIVE_DTYPES = {
    "float16", "float32", "float64", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64", "bool",
}


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 shard_id: int = 0, n_shards: int = 1):
        self.directory = directory
        self.keep = keep
        self.shard_id = shard_id
        self.n_shards = n_shards
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._async_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, *, metadata: Optional[dict] = None):
        self.wait()
        self._raise_pending()
        self._save_blocking(step, self._snapshot(tree), metadata or {})

    def save_async(self, step: int, tree, *, metadata: Optional[dict] = None):
        """Snapshot now (host RAM), write in the background."""
        self.wait()
        self._raise_pending()
        snap = self._snapshot(tree)
        meta = dict(metadata or {})

        def worker():
            try:
                self._save_blocking(step, snap, meta)
            except BaseException as e:  # surfaced on next wait/save
                self._async_error = e

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _raise_pending(self):
        if self._async_error is not None:
            err, self._async_error = self._async_error, None
            raise RuntimeError("async checkpoint save failed") from err

    def _snapshot(self, tree) -> Dict[str, np.ndarray]:
        flat = _flatten(tree)
        out = {}
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            if i % self.n_shards != self.shard_id:
                continue  # another host owns this leaf
            arr = np.asarray(leaf)
            # npz cannot round-trip ml_dtypes (bfloat16 etc.): store the
            # raw bits as uint + record the logical dtype in the manifest
            if arr.dtype.name not in _NATIVE_DTYPES:
                bits = {1: np.uint8, 2: np.uint16, 4: np.uint32}[
                    arr.dtype.itemsize]
                out[key] = (arr.view(bits), arr.dtype.name)
            else:
                out[key] = (arr, arr.dtype.name)
        return out

    def _save_blocking(self, step: int, snap: Dict[str, np.ndarray],
                       metadata: dict):
        """Per-shard atomic commit into a SHARED step directory.

        Hosts write concurrently into ``step_<n>/``: arrays land under a
        ``.tmp`` name and are ``os.replace``d into place; the manifest
        rename is this shard's commit point (``available_steps`` requires
        the manifest, so a crash mid-save leaves only ignorable ``.tmp``
        litter and the step stays invisible to this shard's restores).
        """
        final = os.path.join(self.directory, f"step_{step}")
        os.makedirs(final, exist_ok=True)
        arrays_path = os.path.join(final, f"shard_{self.shard_id}.npz")
        with open(arrays_path + ".tmp", "wb") as f:
            np.savez(f, **{k.replace("/", "\x1f"): v
                           for k, (v, _) in snap.items()})
            f.flush()
            os.fsync(f.fileno())
        os.replace(arrays_path + ".tmp", arrays_path)
        manifest = {
            "step": step,
            "n_shards": self.n_shards,
            "keys": sorted(snap.keys()),
            "dtypes": {k: d for k, (_, d) in snap.items()},
            "metadata": metadata,
        }
        mpath = os.path.join(final, f"manifest_{self.shard_id}.json")
        with open(mpath + ".tmp", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(mpath + ".tmp", mpath)
        self._gc()

    def _gc(self):
        steps = self.available_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def available_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(
                    self.directory, name, f"manifest_{self.shard_id}.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, template, *, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``template``.

        ``shardings``: optional matching pytree of ``NamedSharding`` — leaves
        are ``device_put`` onto it (elastic re-shard onto a new mesh).
        Returns ``(tree, metadata)``.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        ckpt_dir = os.path.join(self.directory, f"step_{step}")
        arrays: Dict[str, np.ndarray] = {}
        metadata = {}
        for shard in range(self.n_shards):
            shard_path = os.path.join(ckpt_dir, f"shard_{shard}.npz")
            try:
                # eager member reads: a truncated zip member only fails
                # when decompressed, so force it here where the error can
                # name the file instead of surfacing mid-unflatten
                npz = np.load(shard_path)
                npz = {k: npz[k] for k in npz.files}
            except FileNotFoundError:
                raise
            except Exception as e:
                raise RuntimeError(
                    f"checkpoint step_{step} shard {shard} is corrupt or "
                    f"truncated ({shard_path}): {e}") from e
            try:
                with open(os.path.join(ckpt_dir,
                                       f"manifest_{shard}.json")) as f:
                    manifest = json.load(f)
            except FileNotFoundError:
                raise
            except Exception as e:
                raise RuntimeError(
                    f"checkpoint step_{step} shard {shard} manifest is "
                    f"corrupt ({ckpt_dir}): {e}") from e
            metadata = manifest["metadata"] | metadata
            dtypes = manifest.get("dtypes", {})
            for k in npz:
                key = k.replace("\x1f", "/")
                arr = npz[k]
                logical = dtypes.get(key, arr.dtype.name)
                if logical not in _NATIVE_DTYPES:
                    import ml_dtypes

                    arr = arr.view(np.dtype(getattr(ml_dtypes, logical)))
                arrays[key] = arr

        flat_template = _flatten(template)
        missing = set(flat_template) - set(arrays)
        if missing:
            raise KeyError(f"checkpoint step_{step} missing keys: "
                           f"{sorted(missing)[:5]}...")
        flat_shardings = _flatten(shardings) if shardings is not None else {}

        leaves_order, treedef = jax.tree_util.tree_flatten(template)
        keys_order = list(_flatten(template).keys())
        # _flatten sorts nothing: tree_flatten_with_path order == tree_flatten
        restored = []
        for key, tmpl_leaf in zip(keys_order, leaves_order):
            arr = arrays[key]
            if hasattr(tmpl_leaf, "dtype"):
                arr = arr.astype(tmpl_leaf.dtype)
            if key in flat_shardings:
                restored.append(jax.device_put(arr, flat_shardings[key]))
            else:
                restored.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, restored), metadata
