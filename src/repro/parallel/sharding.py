"""Logical-axis sharding rules → NamedSharding (DP / FSDP / TP / EP / SP).

Models annotate activations with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``); a :class:`ShardingRules` table
maps logical names to mesh axes. Swapping the table is a one-line sharding
experiment — the §Perf hillclimb lever.

The mesh context is self-managed (module global set by :func:`activate`);
outside a context every ``constrain`` is a no-op, so all model code runs
unchanged on a single CPU device.

Default mapping (single pod ``(data=16, model=16)``; multi-pod adds ``pod``
as an outer data axis):

  batch   → (pod, data)     DP
  vocab   → model           TP (embedding + logits + vocab-parallel CE)
  heads   → model           TP attention (q heads)
  kv_heads→ model            (replicated automatically when kv < axis — GSPMD)
  ff      → model           TP MLP
  experts → model           EP
  fsdp    → data            parameter/optimizer-state sharding (ZeRO-3)
  seq     → None             (SP variants map seq → data for long-context)
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "DEFAULT_RULES", "activate", "active_context",
           "constrain", "logical_to_spec", "param_shardings"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis name → mesh axis (or tuple of axes, or None)."""

    rules: Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...]

    @staticmethod
    def make(mapping: Dict[str, Optional[Tuple[str, ...] | str]]) -> "ShardingRules":
        norm = []
        for k, v in mapping.items():
            if v is None:
                norm.append((k, None))
            elif isinstance(v, str):
                norm.append((k, (v,)))
            else:
                norm.append((k, tuple(v)))
        return ShardingRules(tuple(norm))

    def lookup(self, name: Optional[str]):
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                if v is None:
                    return None
                return v[0] if len(v) == 1 else v
        return None  # unknown logical names replicate

    def with_overrides(self, **overrides) -> "ShardingRules":
        d = {k: v for k, v in self.rules}
        for k, v in overrides.items():
            d[k] = (v,) if isinstance(v, str) else v
        return ShardingRules(tuple(d.items()))


DEFAULT_RULES = ShardingRules.make({
    "batch": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "expert_capacity": None,
    "fsdp": ("pod", "data"),
    "embed": None,
    "seq": None,
    "seq_cp": "model",   # context-parallel attention (Ulysses-style layout)
    "kv_seq": None,
    "kv_heads_cache": "model",  # cache head axis (≠ the weights' kv_heads)
    "scale_seq": None,   # int8 KV scales' seq dim (kv_dim_shard → "model")
    "head_dim": None,    # kv_dim_shard variant maps this to "model"
    "state": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
})


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[ShardingRules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def activate(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    """Enable sharding constraints inside this context (and `with mesh`)."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_context():
    return _CTX.mesh, _CTX.rules


def logical_to_spec(names, rules: Optional[ShardingRules] = None,
                    mesh: Optional[Mesh] = None) -> P:
    """Logical names tuple → PartitionSpec, dropping axes absent from mesh."""
    rules = rules or _CTX.rules or DEFAULT_RULES
    mesh = mesh or _CTX.mesh
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    out = []
    for n in names:
        ax = rules.lookup(n)
        if ax is not None and mesh_axes is not None:
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if a in mesh_axes) or None
                if ax is not None and len(ax) == 1:
                    ax = ax[0]
            elif ax not in mesh_axes:
                ax = None
        out.append(ax)
    return P(*out)


def _dedupe(spec: P) -> P:
    """A mesh axis may shard at most one dim — first occurrence wins (e.g.
    under SP the residual's seq→model takes priority; a later vocab→model
    on the same tensor replicates instead of erroring)."""
    seen = set()
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if any(a in seen for a in axes):
            out.append(None)
            continue
        seen.update(axes)
        out.append(entry)
    return P(*out)


def constrain(x, *names):
    """with_sharding_constraint by logical names; no-op without a context."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = _dedupe(logical_to_spec(names))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


def param_shardings(logical_tree, mesh: Optional[Mesh] = None,
                    rules: Optional[ShardingRules] = None):
    """Map a pytree of logical-name tuples to NamedShardings."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or DEFAULT_RULES
    if mesh is None:
        raise ValueError("param_shardings requires an active or explicit mesh")
    return jax.tree.map(
        lambda names: NamedSharding(
            mesh, logical_to_spec(names, rules, mesh)),
        logical_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            n is None or isinstance(n, str) for n in t),
    )
