"""Logical-axis sharding rules → NamedSharding (DP / FSDP / TP / EP / SP).

Models annotate activations with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``); a :class:`ShardingRules` table
maps logical names to mesh axes. Swapping the table is a one-line sharding
experiment — the §Perf hillclimb lever.

The mesh context is self-managed (module global set by :func:`activate`);
outside a context every ``constrain`` is a no-op, so all model code runs
unchanged on a single CPU device.

Default mapping (single pod ``(data=16, model=16)``; multi-pod adds ``pod``
as an outer data axis):

  batch   → (pod, data)     DP
  vocab   → model           TP (embedding + logits + vocab-parallel CE)
  heads   → model           TP attention (q heads)
  kv_heads→ model            (replicated automatically when kv < axis — GSPMD)
  ff      → model           TP MLP
  experts → model           EP
  fsdp    → data            parameter/optimizer-state sharding (ZeRO-3)
  seq     → None             (SP variants map seq → data for long-context)
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "DEFAULT_RULES", "activate", "active_context",
           "constrain", "constraint_spec", "logical_to_spec",
           "param_shardings", "replicate_uneven_kv_heads", "serve_rules_for",
           "serve_cache_shardings"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis name → mesh axis (or tuple of axes, or None)."""

    rules: Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...]

    @staticmethod
    def make(mapping: Dict[str, Optional[Tuple[str, ...] | str]]) -> "ShardingRules":
        norm = []
        for k, v in mapping.items():
            if v is None:
                norm.append((k, None))
            elif isinstance(v, str):
                norm.append((k, (v,)))
            else:
                norm.append((k, tuple(v)))
        return ShardingRules(tuple(norm))

    def lookup(self, name: Optional[str]):
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                if v is None:
                    return None
                return v[0] if len(v) == 1 else v
        return None  # unknown logical names replicate

    def with_overrides(self, **overrides) -> "ShardingRules":
        d = {k: v for k, v in self.rules}
        for k, v in overrides.items():
            d[k] = (v,) if isinstance(v, str) else v
        return ShardingRules(tuple(d.items()))


DEFAULT_RULES = ShardingRules.make({
    "batch": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "expert_capacity": None,
    "fsdp": ("pod", "data"),
    "embed": None,
    "seq": None,
    "seq_cp": "model",   # context-parallel attention (Ulysses-style layout)
    "kv_seq": None,
    "kv_heads_cache": "model",  # cache head axis (≠ the weights' kv_heads)
    "scale_seq": None,   # int8 KV scales' seq dim (kv_dim_shard → "model")
    "head_dim": None,    # kv_dim_shard variant maps this to "model"
    "state": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
})


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[ShardingRules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def activate(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
    """Enable sharding constraints inside this context (and `with mesh`)."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_context():
    return _CTX.mesh, _CTX.rules


def logical_to_spec(names, rules: Optional[ShardingRules] = None,
                    mesh: Optional[Mesh] = None) -> P:
    """Logical names tuple → PartitionSpec, dropping axes absent from mesh."""
    rules = rules or _CTX.rules or DEFAULT_RULES
    mesh = mesh or _CTX.mesh
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    out = []
    for n in names:
        ax = rules.lookup(n)
        if ax is not None and mesh_axes is not None:
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if a in mesh_axes) or None
                if ax is not None and len(ax) == 1:
                    ax = ax[0]
            elif ax not in mesh_axes:
                ax = None
        out.append(ax)
    return P(*out)


def _dedupe(spec: P) -> P:
    """A mesh axis may shard at most one dim — first occurrence wins (e.g.
    under SP the residual's seq→model takes priority; a later vocab→model
    on the same tensor replicates instead of erroring)."""
    seen = set()
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if any(a in seen for a in axes):
            out.append(None)
            continue
        seen.update(axes)
        out.append(entry)
    return P(*out)


def constraint_spec(names, rules: Optional[ShardingRules] = None,
                    mesh: Optional[Mesh] = None) -> P:
    """The exact PartitionSpec :func:`constrain` would pin for ``names``:
    logical lookup + one-dim-per-mesh-axis dedupe. Public so tools (e.g.
    the static auditor) can predict constraints without applying them."""
    return _dedupe(logical_to_spec(names, rules, mesh))


def constrain(x, *names):
    """with_sharding_constraint by logical names; no-op without a context."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = _dedupe(logical_to_spec(names))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


def param_shardings(logical_tree, mesh: Optional[Mesh] = None,
                    rules: Optional[ShardingRules] = None):
    """Map a pytree of logical-name tuples to NamedShardings."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or DEFAULT_RULES
    if mesh is None:
        raise ValueError("param_shardings requires an active or explicit mesh")
    return jax.tree.map(
        lambda names: NamedSharding(
            mesh, logical_to_spec(names, rules, mesh)),
        logical_tree,
        is_leaf=lambda t: isinstance(t, tuple) and all(
            n is None or isinstance(n, str) for n in t),
    )


# ---------------------------------------------------------------------------
# Serving (docs/sharded-serving.md)
# ---------------------------------------------------------------------------


def serve_rules_for(family: str,
                    base: ShardingRules = DEFAULT_RULES) -> ShardingRules:
    """Bitwise-reproducible serving rules for a model family.

    Serving parity is verified token-for-token against single-device greedy
    decode (``tests/test_sharded_serving.py``), so the rules must never let
    GSPMD split a reduction whose partial-sum order could round differently
    from the unsharded contraction in a way that compounds:

    * **dense / moe** keep the full TP/EP table — the attention/MLP
      row-parallel all-reduces reproduce the single-device accumulation
      exactly on the shapes we serve, and the MoE combine sums at most
      ``top_k`` non-zero partials (order-invariant in IEEE for two terms);
    * **ssm / hybrid** replicate every model-axis parameter: a split
      contraction's rounding noise feeds the *recurrent* state and
      compounds step over step, so these families serve data-parallel
      (slots over ``data``) with the model axis idle — the paper's lesson
      that a mapping must be validated on the device, not on paper.
    """
    if family in ("ssm", "hybrid"):
        return base.with_overrides(
            heads=None, kv_heads=None, kv_heads_cache=None, ff=None,
            experts=None, vocab=None, ssm_inner=None, ssm_heads=None)
    return base


def replicate_uneven_kv_heads(rules: ShardingRules, n_kv_heads: int,
                              mesh: Mesh) -> ShardingRules:
    """Replicate ``kv_heads_cache`` when its mesh axes do not divide
    ``n_kv_heads`` (GQA kv heads smaller than the model axis).

    The input-side cache shardings already drop the uneven axis
    (:func:`_drop_indivisible` / ``steps._divisible_spec``), but an
    in-flight ``constrain`` would still pin it against GSPMD's padded
    choice and force full-rematerialization copies on every decode step —
    shared by the serve engine and the training/dry-run decode rules.
    """
    entry = rules.lookup("kv_heads_cache")
    if entry is None or not n_kv_heads:
        return rules
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = entry if isinstance(entry, tuple) else (entry,)
    ways = 1
    for a in axes:
        ways *= sizes.get(a, 1)
    if n_kv_heads % ways:
        return rules.with_overrides(kv_heads_cache=None)
    return rules


#: serve-engine batched-cache leaves → logical axes (dense-slot layout).
#: Leaves under a stack key ("layers" / "kv" / "ssm") get a leading None
#: for the layer / application-point axis.
_SERVE_CACHE_AXES = {
    "k": ("batch", "kv_seq", "kv_heads_cache", "head_dim"),
    "v": ("batch", "kv_seq", "kv_heads_cache", "head_dim"),
    "k_scale": ("batch", "scale_seq", "kv_heads_cache"),
    "v_scale": ("batch", "scale_seq", "kv_heads_cache"),
    "h": ("batch", "ssm_heads", None, "state"),
    "conv": ("batch", None, "ssm_inner"),
    "pos": ("batch",),
    "block_tables": ("batch", None),
}

#: paged-pool KV leaves: the physical block axis is shared across slots
#: (block tables are logical, host-side), so only the head dimension
#: shards — pages replicate over ``data`` and split over ``model``.
_SERVE_POOL_AXES = {
    "k": (None, None, "kv_heads_cache", "head_dim"),
    "v": (None, None, "kv_heads_cache", "head_dim"),
    "k_scale": (None, None, "kv_heads_cache"),
    "v_scale": (None, None, "kv_heads_cache"),
}

_STACK_KEYS = ("layers", "kv", "ssm")
_POOL_LEAVES = ("k", "v", "k_scale", "v_scale")


def _drop_indivisible(shape, spec: P, mesh: Mesh) -> P:
    """Replicate any dim its mesh axes do not evenly divide (GQA kv heads
    smaller than the model axis, odd slot counts, ...)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        ways = 1
        for a in axes:
            ways *= sizes[a]
        out.append(entry if dim % ways == 0 else None)
    return P(*out)


def serve_cache_shardings(cache, mesh: Mesh,
                          rules: ShardingRules = DEFAULT_RULES, *,
                          paged: bool = False):
    """NamedShardings for a serve-engine batched cache.

    ``cache`` is the engine's device state (or its ``eval_shape``): KV
    leaves stacked ``(stack, n_slots, max_len, Hk, D)`` in dense-slot mode
    or pooled ``(stack, n_phys_blocks, block_size, Hk, D)`` in paged mode,
    plus per-slot ``pos`` / ``block_tables`` / SSM state. Slots shard over
    the data axis, KV head dims over the model axis (per ``rules``);
    indivisible dims replicate instead of erroring.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = []
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        pooled = paged and name in _POOL_LEAVES \
            and not any(k == "ssm" for k in keys)
        axes = _SERVE_POOL_AXES[name] if pooled \
            else _SERVE_CACHE_AXES.get(name, ())
        if any(k in _STACK_KEYS for k in keys):
            axes = (None,) + tuple(axes)
        axes = tuple(axes)[: leaf.ndim]
        axes = axes + (None,) * (leaf.ndim - len(axes))
        spec = _dedupe(logical_to_spec(axes, rules, mesh))
        spec = _drop_indivisible(leaf.shape, spec, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)
