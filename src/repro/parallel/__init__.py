from repro.parallel.sharding import (
    ShardingRules, DEFAULT_RULES, activate, active_context, constrain,
    logical_to_spec, param_shardings,
)

__all__ = [
    "ShardingRules", "DEFAULT_RULES", "activate", "active_context",
    "constrain", "logical_to_spec", "param_shardings",
]
