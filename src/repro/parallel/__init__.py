from repro.parallel.sharding import (
    ShardingRules, DEFAULT_RULES, activate, active_context, constrain,
    logical_to_spec, param_shardings, replicate_uneven_kv_heads,
    serve_cache_shardings, serve_rules_for,
)

__all__ = [
    "ShardingRules", "DEFAULT_RULES", "activate", "active_context",
    "constrain", "logical_to_spec", "param_shardings",
    "replicate_uneven_kv_heads", "serve_cache_shardings", "serve_rules_for",
]
