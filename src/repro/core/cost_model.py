"""Hardware cost models: FPGA ALMs (paper Figs. 4 & 5) and TPU v5e roofline.

FPGA side — calibrated to an Intel Stratix V 5SGXEA7 (the paper's device,
Quartus 16.0, 8-bit operands):

  * One Stratix-V ALM implements **two bits of a binary adder** (two full
    adders with a hard carry chain). A ``w``-bit two-operand adder therefore
    costs ``ceil(w/2)`` ALMs.
  * A binary adder *tree* over ``n`` operands of width ``b`` has
    ``ceil(log2 n)`` levels; level ``i`` (0-based) holds ~``n/2^(i+1)``
    adders of width ``b+i`` (sums grow one bit per level).
  * The §3.1 *serializer* is a parallel-load shift register: ``n_c·b``
    registers plus a load/shift 2:1 mux per bit. Each ALM packs two such
    mux+FF bit-slices → ``ceil(n_c·b/2)`` ALMs — **linear in n_c**, which is
    exactly the overhead the paper measures (Fig. 4).
  * The accumulator is one adder of width ``b + ceil(log2 n_c)`` plus its
    register (register is free inside the ALM).
  * The §3.2 LOA: an Intel ALM contains a **hard-wired full adder**; whether
    the cell computes XOR/carry (exact) or OR (approximate) it occupies the
    same ALM → cost is *flat* in the number of approximated bits ``l``
    (Fig. 5, bottom). We model exactly that.

TPU side — the reduction-scheduling costs used by benchmarks and §Roofline:
peak 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI (4 links),
128 MiB VMEM (v5e-class constants, fixed for the whole study).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

__all__ = [
    "alm_binary_adder",
    "alm_adder_tree",
    "alm_serializer",
    "alm_accumulator",
    "alm_serial_moa",
    "alm_loa_adder",
    "alm_scm_multiplier",
    "TPUSpec",
    "TPU_V5E",
    "vpu_ops_exact_add",
    "vpu_ops_loa_add",
    "reduction_cost_tpu",
]

# ---------------------------------------------------------------------------
# FPGA (Stratix V) ALM model
# ---------------------------------------------------------------------------

ALM_BITS_PER_ADDER = 2  # hard carry chain: 2 full-adder bits per ALM

# Serializer bit-slices cannot share an ALM with the adder halves: the
# load/shift mux + FF + dual-clock handshake occupy a full ALM per bit.
# Calibrated so the §4.1 result reproduces: the serialized MOA exceeds the
# pipelined tree at *every* cluster size (paper Fig. 4).
ALM_PER_SERIALIZER_BIT = 1.0

# Voronenko–Püschel MCM sharing across the N filters reusing each input
# pixel: average adders per *generic* constant after sharing. Calibrated so
# AlexNet conv1 reproduces the paper's "69 % of logic is MOA" headline
# (tested in tests/test_paper_numbers.py).
MCM_SHARING = 0.43


def alm_binary_adder(width: int) -> int:
    """ALMs for one two-operand ripple adder of ``width`` bits."""
    return math.ceil(width / ALM_BITS_PER_ADDER)


def alm_adder_tree(n_operands: int, width: int) -> int:
    """ALMs for the synthesis-default binary adder tree (Fig. 1 / Fig. 4 dashed).

    ``n-1`` adders arranged in ``ceil(log2 n)`` levels, widths growing one
    bit per level.
    """
    if n_operands <= 1:
        return 0
    total = 0
    remaining = n_operands
    level_width = width
    while remaining > 1:
        pairs = remaining // 2
        total += pairs * alm_binary_adder(level_width + 1)
        remaining = pairs + (remaining % 2)
        level_width += 1
    return total


def alm_serializer(n_inputs: int, width: int) -> int:
    """ALMs for the parallel-to-serial register feeding the accumulator.

    Parallel load of ``n_inputs`` words of ``width`` bits into a shift
    register: one 2:1 (load/shift) mux + FF + clock-domain-crossing logic per
    bit. Linear in ``n_inputs`` — the Fig. 4 overhead.
    """
    return math.ceil(n_inputs * width * ALM_PER_SERIALIZER_BIT)


def alm_accumulator(n_inputs: int, width: int) -> int:
    """ALMs for the serial accumulator (adder sized for n_inputs sums)."""
    acc_width = width + max(1, math.ceil(math.log2(max(n_inputs, 2))))
    return alm_binary_adder(acc_width)


def alm_serial_moa(n_inputs: int, width: int) -> int:
    """Total §3.1 serialized MOA: serializer + accumulator (Fig. 2)."""
    return alm_serializer(n_inputs, width) + alm_accumulator(n_inputs, width)


def alm_loa_adder(width: int, approx_bits: int) -> int:
    """ALMs for one LOA — **flat in approx_bits** (the Fig. 5 negative result).

    Each ALM's hard full adder implements either an exact bit-pair or an OR
    bit-pair; the cell count is identical. (The lone carry-generation AND
    gate folds into the same cell as the first exact bit.)
    """
    del approx_bits  # the entire point: it does not matter
    return alm_binary_adder(width)


def alm_scm_multiplier(bits: int) -> float:
    """Mean ALMs for a *generic* (non-zero, non-pow2) SCM-tiled multiplier.

    Canonical-signed-digit recoding of a b-bit constant needs ~b/3 add/sub
    terms (≈ b/3 − 1 adders of width ~b); Voronenko–Püschel sharing across
    the N filters that reuse each input pixel divides that by ``MCM_SHARING``
    (calibrated to the paper's 69 % headline).
    """
    adders = max(bits / 3.0 - 1.0, 0.5) * MCM_SHARING
    return adders * alm_binary_adder(bits)


# ---------------------------------------------------------------------------
# TPU v5e model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_bf16_flops: float      # FLOP/s per chip
    hbm_bandwidth: float        # bytes/s per chip
    ici_link_bandwidth: float   # bytes/s per link
    ici_links: int              # links per chip
    vmem_bytes: int
    vpu_lanes: int              # 8×128 vector lanes
    mxu_dim: int                # systolic array edge


TPU_V5E = TPUSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=4,
    vmem_bytes=128 * 1024 * 1024,
    vpu_lanes=8 * 128,
    mxu_dim=128,
)


def vpu_ops_exact_add() -> int:
    """Vector ops per element-wise exact add on the VPU: one hard add."""
    return 1


def vpu_ops_loa_add() -> int:
    """Vector ops per element-wise LOA add on the VPU.

    mask_lo(x), mask_lo(y), or, shift(x), shift(y), and-carry, add, shift-combine,
    or-combine → with fused masking this lowers to ~6 integer VPU ops. The
    TPU analogue of the flat-ALM result, with the sign flipped: approximate
    addition costs **6×** the hard-wired exact add. How not to solve it.
    """
    return 6


def reduction_cost_tpu(n_operands: int, elem_bytes: int, spec: TPUSpec = TPU_V5E,
                       *, strategy: str = "serial") -> Dict[str, float]:
    """First-order cost of an n-operand reduction per output element.

    Returns seconds spent in {vpu, hbm} assuming the operands stream from
    HBM once (serial accumulation) or are materialized per tree level
    (tree → log2(n) extra VMEM traffic, charged at HBM rate when the working
    set exceeds VMEM).
    """
    adds = n_operands - 1
    vpu_s = adds / (spec.vpu_lanes * 0.94e9)  # ~940 MHz vector clock
    bytes_moved = n_operands * elem_bytes
    if strategy == "tree":
        bytes_moved += elem_bytes * n_operands  # level intermediates
    hbm_s = bytes_moved / spec.hbm_bandwidth
    return {"vpu_s": vpu_s, "hbm_s": hbm_s, "bound": "vpu" if vpu_s > hbm_s else "hbm"}
