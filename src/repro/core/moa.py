"""DEPRECATED shim — the MOA API moved to :mod:`repro.moa`.

The string-kind :class:`ReductionStrategy` and its ``if/elif`` dispatch were
replaced by the registry-backed strategy classes in :mod:`repro.moa`
(``resolve("serial?chunk=512")``, ``TreeStrategy``, ``SerialStrategy``,
``LOAStrategy``) with jnp/pallas backend dispatch. This module keeps the old
surface importable and working:

  * ``ReductionStrategy`` still constructs and validates exactly as before;
    ``.to_strategy()`` converts it to the new API (and every new-API entry
    point accepts legacy instances directly).
  * ``moa_sum`` / ``moa_dot`` / ``chunked_matmul`` delegate to the new
    engine; ``TREE`` / ``SERIAL`` remain the old defaults.

Importing this module emits a :class:`DeprecationWarning`. Migrate::

    from repro.core.moa import ReductionStrategy, moa_dot      # old
    y = moa_dot(a, b, strategy=ReductionStrategy(kind="serial", chunk=512))

    from repro.moa import resolve                               # new
    y = resolve("serial?chunk=512").dot(a, b)
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro import moa as _moa
from repro.moa.backends import chunked_matmul  # noqa: F401  (re-export)

__all__ = ["ReductionStrategy", "moa_sum", "moa_dot", "chunked_matmul", "TREE", "SERIAL"]

warnings.warn(
    "repro.core.moa is deprecated; use repro.moa (e.g. "
    "`repro.moa.resolve('serial?chunk=512')`) instead",
    DeprecationWarning, stacklevel=2)


@dataclasses.dataclass(frozen=True)
class ReductionStrategy:
    """Legacy string-kind strategy description (see :mod:`repro.moa`).

    Attributes:
      kind: ``"tree"`` | ``"serial"`` | ``"loa"``.
      chunk: serialization cluster size ``n_c`` (``serial`` only).
      accum_dtype: accumulator precision (float kinds).
      approx_bits: LOA ``l`` (``loa`` only).
      width: LOA operand bit-width ``b`` (``loa`` only).
    """

    kind: str = "serial"
    chunk: int = 512
    accum_dtype: jnp.dtype = jnp.float32
    approx_bits: int = 0
    width: int = 8

    def __post_init__(self):
        if self.kind not in ("tree", "serial", "loa"):
            raise ValueError(f"unknown MOA strategy kind: {self.kind!r}")
        if self.chunk < 1:
            raise ValueError("chunk must be >= 1")

    def to_strategy(self) -> "_moa.MOAStrategy":
        """Convert to the new registry-backed API."""
        return _moa.resolve(self)


TREE = ReductionStrategy(kind="tree")
SERIAL = ReductionStrategy(kind="serial")


def moa_sum(operands: jax.Array, *, axis: int = -1,
            strategy: ReductionStrategy = SERIAL) -> jax.Array:
    """Reduce ``operands`` over ``axis`` with the configured MOA strategy."""
    return _moa.resolve(strategy).sum(operands, axis=axis)


def moa_dot(a: jax.Array, b: jax.Array, *,
            strategy: ReductionStrategy = SERIAL,
            out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """Matrix product whose contraction is scheduled per ``strategy``."""
    out_dtype = out_dtype or a.dtype
    return _moa.resolve(strategy).dot(a, b, out_dtype=out_dtype)
