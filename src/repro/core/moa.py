"""Multi-Operand Adder (MOA) reduction strategies — the paper's core object.

The paper (§2) identifies the MOA — a reduction node with hundreds to
thousands of operands — as the dominant resource sink of a direct-mapped CNN,
and evaluates two scheduling strategies for it:

  * ``tree``   — the synthesis-tool default: a spatial binary adder tree
                 (n-1 two-operand adders). On TPU this corresponds to a
                 one-shot reduction that materializes all partial products
                 (maximal working set, minimal sequentialization).
  * ``serial`` — §3.1: time-multiplex a *cluster* of ``n_c`` operands into a
                 single accumulator. On FPGA this failed (the serializer costs
                 more fabric than it saves). On TPU the serializer is the
                 hard-wired DMA/address path, so serial accumulation — a
                 ``lax.scan`` carrying an f32 accumulator, or a Pallas grid
                 loop — is the *native* idiom. ``chunk`` plays the paper's
                 ``n_c`` role (the clock-domain ratio f_c = n_c · f_0 has no
                 TPU analogue; grid sequentialization replaces it).
  * ``loa``    — §3.2: approximate the adders (Lower-part-OR). Integer paths
                 only; faithful bitwise semantics from :mod:`repro.core.loa`.

Every dot-product-bearing layer in the framework takes a
:class:`ReductionStrategy`, making the paper's design space a first-class
config knob (``model.moa.kind``, ``model.moa.chunk``).

All float variants are exact up to reassociation; tests assert
``serial == tree == jnp.sum`` within dtype tolerance and exact equality for
integer dtypes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import loa as loa_lib

__all__ = ["ReductionStrategy", "moa_sum", "moa_dot", "chunked_matmul", "TREE", "SERIAL"]


@dataclasses.dataclass(frozen=True)
class ReductionStrategy:
    """How a large-fan-in reduction is scheduled.

    Attributes:
      kind: ``"tree"`` | ``"serial"`` | ``"loa"``.
      chunk: serialization cluster size ``n_c`` (contraction-dim block). Only
        meaningful for ``serial``; the reduction processes ``chunk`` operands
        per sequential step, accumulating in ``accum_dtype``.
      accum_dtype: accumulator precision. The MXU hard-wires f32 accumulation
        — setting bf16 here models the paper's "approximate adder" at the
        precision level and is surfaced in benchmarks as *costing nothing
        less* (same op count), the TPU analogue of the flat-ALM result.
      approx_bits: LOA ``l`` (low bits OR-approximated); ``loa`` kind only.
      width: LOA operand bit-width ``b``; ``loa`` kind only.
    """

    kind: str = "serial"
    chunk: int = 512
    accum_dtype: jnp.dtype = jnp.float32
    approx_bits: int = 0
    width: int = 8

    def __post_init__(self):
        if self.kind not in ("tree", "serial", "loa"):
            raise ValueError(f"unknown MOA strategy kind: {self.kind!r}")
        if self.chunk < 1:
            raise ValueError("chunk must be >= 1")


TREE = ReductionStrategy(kind="tree")
SERIAL = ReductionStrategy(kind="serial")


def _tree_sum(x: jax.Array, accum_dtype) -> jax.Array:
    """Explicit balanced binary adder tree over axis 0.

    Structurally mirrors Fig. 1's adder tree: ``ceil(log2 n)`` levels of
    pairwise adds, odd leftovers passing through. For floats this fixes the
    reassociation order to the hardware tree's order.
    """
    x = x.astype(accum_dtype)
    while x.shape[0] > 1:
        m = x.shape[0]
        half = m // 2
        paired = x[: 2 * half : 2] + x[1 : 2 * half : 2]
        if m % 2:
            paired = jnp.concatenate([paired, x[2 * half :]], axis=0)
        x = paired
    return x[0]


def _serial_sum(x: jax.Array, chunk: int, accum_dtype) -> jax.Array:
    """§3.1 serialized MOA: scan over clusters of ``chunk`` operands.

    The carried accumulator lives in ``accum_dtype`` — the TPU analogue of
    the single accumulator in the fast clock domain. Ragged tails are
    zero-padded (padding is exact for addition).
    """
    n = x.shape[0]
    chunk = min(chunk, n)
    n_chunks = -(-n // chunk)
    pad = n_chunks * chunk - n
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    x = x.reshape((n_chunks, chunk) + x.shape[1:]).astype(accum_dtype)

    def body(acc, block):
        # In-cluster reduction is a tree (the paper's serializer feeds the
        # accumulator one *cluster* at a time); across clusters we serialize.
        return acc + jnp.sum(block, axis=0), None

    init = jnp.zeros(x.shape[2:], accum_dtype)
    acc, _ = lax.scan(body, init, x)
    return acc


def moa_sum(operands: jax.Array, *, axis: int = -1,
            strategy: ReductionStrategy = SERIAL) -> jax.Array:
    """Reduce ``operands`` over ``axis`` with the configured MOA strategy."""
    x = jnp.moveaxis(jnp.asarray(operands), axis, 0)
    if strategy.kind == "tree":
        return _tree_sum(x, strategy.accum_dtype)
    if strategy.kind == "serial":
        return _serial_sum(x, strategy.chunk, strategy.accum_dtype)
    if strategy.kind == "loa":
        if not jnp.issubdtype(x.dtype, jnp.integer):
            raise TypeError("LOA strategy requires integer operands")
        return loa_lib.loa_sum(
            x, approx_bits=strategy.approx_bits, width=strategy.width, axis=0
        )
    raise AssertionError(strategy.kind)


def chunked_matmul(a: jax.Array, b: jax.Array, *, chunk: int,
                   accum_dtype=jnp.float32,
                   out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """K-blocked matmul: ``a @ b`` with a serialized-MOA contraction.

    ``a: (..., M, K)``, ``b: (K, N)``. The contraction dimension is processed
    ``chunk`` operands at a time by a ``lax.scan`` carrying an f32
    accumulator — §3.1 realized on hardware whose "serializer" (DMA) and
    "accumulator" (MXU) are hard-wired. Differentiable (scan has a transpose
    rule), so it is usable in training.
    """
    k = a.shape[-1]
    if b.shape[0] != k:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    out_dtype = out_dtype or a.dtype
    chunk = min(chunk, k)
    n_chunks = -(-k // chunk)
    pad = n_chunks * chunk - k
    if pad:
        a = jnp.concatenate([a, jnp.zeros(a.shape[:-1] + (pad,), a.dtype)], axis=-1)
        b = jnp.concatenate([b, jnp.zeros((pad,) + b.shape[1:], b.dtype)], axis=0)
    a_blocks = jnp.moveaxis(
        a.reshape(a.shape[:-1] + (n_chunks, chunk)), -2, 0
    )  # (n_chunks, ..., M, chunk)
    b_blocks = b.reshape((n_chunks, chunk) + b.shape[1:])

    def body(acc, blocks):
        a_blk, b_blk = blocks
        acc = acc + jnp.matmul(
            a_blk, b_blk, preferred_element_type=accum_dtype
        ).astype(accum_dtype)
        return acc, None

    init = jnp.zeros(a_blocks.shape[1:-1] + (b.shape[-1],), accum_dtype)
    acc, _ = lax.scan(body, init, (a_blocks, b_blocks))
    return acc.astype(out_dtype)


def moa_dot(a: jax.Array, b: jax.Array, *,
            strategy: ReductionStrategy = SERIAL,
            out_dtype: Optional[jnp.dtype] = None) -> jax.Array:
    """Matrix product whose contraction is scheduled per ``strategy``.

    ``tree``   → one-shot ``jnp.matmul`` with f32 accumulation (XLA emits the
                 spatial reduction; on the MXU this is the hard adder tree).
    ``serial`` → :func:`chunked_matmul` with ``strategy.chunk``.
    ``loa``    → integer partial products reduced through LOA adders
                 (int8 × int8 → int32 with approximate accumulation). Used by
                 the quantized path and the Fig.-5 end-to-end experiments.
    """
    out_dtype = out_dtype or a.dtype
    if strategy.kind == "tree":
        return jnp.matmul(
            a, b, preferred_element_type=strategy.accum_dtype
        ).astype(out_dtype)
    if strategy.kind == "serial":
        if a.shape[-1] <= strategy.chunk:
            return jnp.matmul(
                a, b, preferred_element_type=strategy.accum_dtype
            ).astype(out_dtype)
        return chunked_matmul(
            a, b, chunk=strategy.chunk, accum_dtype=strategy.accum_dtype,
            out_dtype=out_dtype,
        )
    if strategy.kind == "loa":
        if not (jnp.issubdtype(a.dtype, jnp.integer)
                and jnp.issubdtype(b.dtype, jnp.integer)):
            raise TypeError("LOA moa_dot requires integer operands")
        # Partial products (…, M, K, N) reduced over K through the LOA tree.
        partials = a[..., None].astype(jnp.int32) * b.astype(jnp.int32)
        return loa_lib.loa_sum(
            partials,
            approx_bits=strategy.approx_bits,
            width=strategy.width,
            axis=-2,
        ).astype(out_dtype)
    raise AssertionError(strategy.kind)
