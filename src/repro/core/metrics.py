"""Error metrics for approximate arithmetic (paper eq. 2 and relatives)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["mred", "nmed", "max_red", "error_rate"]


def mred(s_hat, s, *, eps: float = 0.0):
    """Mean Relative Error Distance —  mean(|ŝ − s| / s), paper eq. (2).

    Zero exact sums are excluded from the mean (the paper draws positive
    uniform operands, so s > 0 almost surely; we guard anyway).
    """
    s_hat = jnp.asarray(s_hat, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    valid = jnp.abs(s) > eps
    rel = jnp.where(valid, jnp.abs(s_hat - s) / jnp.where(valid, jnp.abs(s), 1.0), 0.0)
    return jnp.sum(rel) / jnp.maximum(jnp.sum(valid), 1)


def nmed(s_hat, s, *, max_abs: float):
    """Normalized Mean Error Distance: mean(|ŝ − s|) / max_abs."""
    s_hat = jnp.asarray(s_hat, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    return jnp.mean(jnp.abs(s_hat - s)) / max_abs


def max_red(s_hat, s):
    """Worst-case relative error distance."""
    s_hat = jnp.asarray(s_hat, jnp.float32)
    s = jnp.asarray(s, jnp.float32)
    valid = jnp.abs(s) > 0
    rel = jnp.where(valid, jnp.abs(s_hat - s) / jnp.where(valid, jnp.abs(s), 1.0), 0.0)
    return jnp.max(rel)


def error_rate(s_hat, s):
    """Fraction of results that differ at all (ER metric)."""
    return jnp.mean((jnp.asarray(s_hat) != jnp.asarray(s)).astype(jnp.float32))
