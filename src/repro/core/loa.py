"""Lower-part-OR approximate adder (LOA) — faithful bitwise port.

Reference: Mahdiani et al., "Bio-Inspired Imprecise Computational Blocks for
Efficient VLSI Implementation of Soft-Computing Applications", TCAS-I 2010 —
the adder evaluated in §3.2 / Fig. 3 / Fig. 5 of the reproduced paper.

Semantics for a ``b``-bit adder with ``l`` approximated low bits
(0 <= l <= b), operands interpreted as unsigned ``b``-bit integers:

    low  = (x & mask_l) | (y & mask_l)                 # bit-wise OR "sum"
    cin  = (x >> (l-1)) & (y >> (l-1)) & 1  if l > 0   # AND of lower MSBs
    high = (x >> l) + (y >> l) + cin                    # exact sub-adder
    s̃   = (high << l) | low

``l == 0`` degenerates to the exact adder. The exact sub-adder keeps its
natural carry-out, so the result may occupy ``b+1`` bits — matching a
hardware adder with carry-out.

Everything here is pure jnp on integer dtypes and is the oracle for
``repro.kernels.loa_add``.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "loa_add",
    "loa_sum",
    "loa_error_bound",
    "exact_bits_required",
]


def _as_int32(x):
    """Promote to int32 container; LOA operates on unsigned b-bit values."""
    return jnp.asarray(x).astype(jnp.int32)


def loa_add(x, y, *, approx_bits: int, width: int = 8):
    """Approximate LOA addition of unsigned ``width``-bit operands.

    Args:
      x, y: integer arrays holding values in ``[0, 2**width)``.
      approx_bits: ``l`` — number of low bits processed with a bit-wise OR.
      width: ``b`` — operand bit-width.

    Returns:
      int32 array with the (possibly ``width+1``-bit) approximate sum.
    """
    if not 0 <= approx_bits <= width:
        raise ValueError(f"approx_bits={approx_bits} outside [0, width={width}]")
    x = _as_int32(x)
    y = _as_int32(y)
    if approx_bits == 0:
        return x + y
    l = approx_bits
    mask_l = jnp.int32((1 << l) - 1)
    low = (x & mask_l) | (y & mask_l)
    # AND gate on the most-significant *approximate* bit generates carry-in.
    cin = ((x >> (l - 1)) & (y >> (l - 1))) & jnp.int32(1)
    high = (x >> l) + (y >> l) + cin
    return (high << l) | low


def loa_sum(operands, *, approx_bits: int, width: int = 8, axis: int = -1):
    """Multi-operand reduction through a *tree* of LOA adders.

    Mirrors §3.2: every binary adder in the MOA tree of Fig. 1 is replaced by
    an LOA. The reduction is a balanced binary tree (odd remainders pass
    through), so the error profile matches the hardware structure rather than
    a serial chain.

    The intermediate width grows by one bit per tree level; ``approx_bits``
    stays fixed per the paper (the approximate *lower* part is a property of
    the adder instance, not of the operand magnitude).
    """
    x = _as_int32(operands)
    x = jnp.moveaxis(x, axis, 0)
    n = x.shape[0]
    if n == 0:
        raise ValueError("loa_sum needs at least one operand")
    level_width = width
    while x.shape[0] > 1:
        m = x.shape[0]
        half = m // 2
        paired = loa_add(
            x[: 2 * half : 2],
            x[1 : 2 * half : 2],
            approx_bits=approx_bits,
            width=level_width,
        )
        if m % 2:  # odd leftover passes through to the next tree level
            paired = jnp.concatenate([paired, x[2 * half :]], axis=0)
        x = paired
        level_width += 1  # sums occupy one more bit per level
    return x[0]


def loa_error_bound(approx_bits: int) -> int:
    """Worst-case absolute error of a single LOA addition.

    The OR of the low parts under-approximates their sum by at most
    ``2**l - 1`` and the AND-carry can over-compensate by at most ``2**l - 1``
    relative to the true carry; the combined deviation is ``< 2**l``.
    """
    if approx_bits == 0:
        return 0
    return (1 << approx_bits) - 1


def exact_bits_required(n_operands: int, width: int) -> int:
    """Bit-width of the exact sum of ``n`` unsigned ``width``-bit operands."""
    import math

    return width + max(0, math.ceil(math.log2(max(n_operands, 1))))


def loa_add_reference_python(x: int, y: int, approx_bits: int) -> int:
    """Scalar pure-python model (used by hypothesis tests as a third oracle)."""
    l = approx_bits
    if l == 0:
        return x + y
    mask = (1 << l) - 1
    low = (x & mask) | (y & mask)
    cin = (x >> (l - 1)) & (y >> (l - 1)) & 1
    high = (x >> l) + (y >> l) + cin
    return (high << l) | low
