"""Single-Constant-Multiplication (SCM) weight census.

In the paper's Direct Hardware Mapping, every weight gets its own multiplier
whose circuitry is *tiled to the constant's value* (Voronenko & Püschel
multiplierless MCM): multiplications by zero vanish, multiplications by ±2^k
become wiring (shifts), and only "generic" constants need adder-based
multipliers. This census is what produces Table 1's "mean non-null operands
per MOA" — zero weights remove operands from the adder tree.

On TPU none of this tiles hardware (a dense MXU MAC costs the same for any
multiplicand) — kept as *analysis*: it drives the Table-1 reproduction, the
DHM cost model, and the sparsity statistics of the quantized int8 path.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SCMCensus", "classify_weights", "quantize_symmetric"]


@dataclasses.dataclass(frozen=True)
class SCMCensus:
    """Per-filter multiplier census after SCM optimization."""

    total: int            # C*J*K operands per filter × N filters
    zeros: int            # multiplications removed entirely
    pow2: int             # ±2^k → shift (wiring, ~free on FPGA fabric)
    generic: int          # require a real (adder-based) multiplier
    n_filters: int        # N — number of MOAs in the layer
    mean_nonnull_per_moa: float  # Table 1's n_opd

    @property
    def density(self) -> float:
        return 1.0 - self.zeros / max(self.total, 1)


def quantize_symmetric(w: np.ndarray, bits: int = 8) -> np.ndarray:
    """Symmetric per-tensor quantization to signed ``bits`` integers.

    The paper's DHM operates on 8-bit weights; quantization is what creates
    exact zeros (and power-of-two values) in otherwise-dense float filters.
    """
    w = np.asarray(w, dtype=np.float64)
    qmax = 2 ** (bits - 1) - 1
    scale = np.max(np.abs(w)) / qmax if np.max(np.abs(w)) > 0 else 1.0
    return np.clip(np.round(w / scale), -qmax - 1, qmax).astype(np.int32)


def _is_pow2(q: np.ndarray) -> np.ndarray:
    a = np.abs(q)
    return (a > 0) & ((a & (a - 1)) == 0)


def classify_weights(weights: np.ndarray, *, already_quantized: bool = False,
                     bits: int = 8) -> SCMCensus:
    """Census of a conv/linear weight tensor.

    Args:
      weights: ``(N, C, J, K)`` conv filters or ``(N, K)`` linear weights —
        leading axis is the output/filter axis (one MOA per output).
      already_quantized: skip the int8 quantization step.
    """
    w = np.asarray(weights)
    n_filters = w.shape[0]
    q = w.astype(np.int64) if already_quantized else quantize_symmetric(w, bits)
    q = q.reshape(n_filters, -1)
    zeros = int(np.sum(q == 0))
    pow2 = int(np.sum(_is_pow2(q)))
    total = int(q.size)
    nonnull_per_filter = np.sum(q != 0, axis=1)
    return SCMCensus(
        total=total,
        zeros=zeros,
        pow2=pow2,
        generic=total - zeros - pow2,
        n_filters=n_filters,
        mean_nonnull_per_moa=float(np.mean(nonnull_per_filter)),
    )
