"""Direct-Hardware-Mapping (DHM) analyzer — reproduces Table 1.

Given a CNN layer spec ``(N, C, J, K)`` and its (quantized) weights, compute
what a DHM synthesis would instantiate:

  * ``N`` Multi-Operand Adders (one per output filter),
  * ``C·J·K`` structural operands per MOA,
  * the *mean non-null* operand count ``n_opd`` after SCM zero-removal
    (Table 1 of the paper),
  * the fraction of layer logic spent on MOAs (the 69 % headline number),
    via :mod:`repro.core.cost_model`.

Offline note: the paper uses trained AlexNet weights; trained checkpoints are
not available in this container, so the Table-1 benchmark calibrates a
Bernoulli zero-mask to the paper's reported per-layer densities and verifies
the *pipeline* reproduces the published ``n_opd`` within sampling error
(documented in docs/moa-strategies.md). The structural counts (N, C·J·K) are
exact.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import cost_model, scm

__all__ = [
    "ConvLayerSpec",
    "MOAReport",
    "analyze_layer",
    "analyze_network",
    "ALEXNET_CONV_SPECS",
    "ALEXNET_PAPER_NOPD",
    "LENET5_CONV_SPECS",
]


@dataclasses.dataclass(frozen=True)
class ConvLayerSpec:
    name: str
    n_filters: int   # N  (== number of MOAs)
    in_channels: int  # C (per group)
    kernel_h: int    # J
    kernel_w: int    # K

    @property
    def operands(self) -> int:
        """Structural MOA fan-in C·J·K."""
        return self.in_channels * self.kernel_h * self.kernel_w


# AlexNet conv geometry (grouped convs use per-group C, as the paper does:
# conv2/conv4/conv5 run with groups=2 → C = channels/2).
ALEXNET_CONV_SPECS: List[ConvLayerSpec] = [
    ConvLayerSpec("conv1", 96, 3, 11, 11),     # 363 operands
    ConvLayerSpec("conv2", 256, 48, 5, 5),     # 1200
    ConvLayerSpec("conv3", 384, 256, 3, 3),    # 2304
    ConvLayerSpec("conv4", 384, 192, 3, 3),    # 1728
    ConvLayerSpec("conv5", 256, 192, 3, 3),    # 1728
]

# Paper Table 1 — mean non-null operands per MOA with trained 8-bit weights.
ALEXNET_PAPER_NOPD: Dict[str, int] = {
    "conv1": 325, "conv2": 957, "conv3": 1774, "conv4": 1398, "conv5": 1420,
}

LENET5_CONV_SPECS: List[ConvLayerSpec] = [
    ConvLayerSpec("conv1", 6, 1, 5, 5),
    ConvLayerSpec("conv2", 16, 6, 5, 5),
]


@dataclasses.dataclass(frozen=True)
class MOAReport:
    spec: ConvLayerSpec
    census: scm.SCMCensus
    moa_alms: float          # ALMs spent on the N adder trees
    multiplier_alms: float   # ALMs spent on SCM-tiled multipliers
    moa_fraction: float      # the paper's "69 %" metric

    @property
    def n_opd(self) -> float:
        return self.census.mean_nonnull_per_moa


def analyze_layer(spec: ConvLayerSpec, weights: Optional[np.ndarray] = None,
                  *, bits: int = 8,
                  rng: Optional[np.random.Generator] = None,
                  target_density: Optional[float] = None) -> MOAReport:
    """Analyze one conv layer's DHM resource split.

    If ``weights`` is None, synthesize int8 weights; when ``target_density``
    is given, zeros are planted i.i.d. at rate ``1 - density`` (the
    documented Table-1 calibration), otherwise Gaussian weights are
    quantized and whatever zeros fall out are used.
    """
    rng = rng or np.random.default_rng(0)
    shape = (spec.n_filters, spec.in_channels, spec.kernel_h, spec.kernel_w)
    if weights is None:
        w = rng.standard_normal(shape)
        q = scm.quantize_symmetric(w, bits)
        if target_density is not None:
            keep = rng.random(shape) < target_density
            q = np.where(keep, np.where(q == 0, 1, q), 0)
        census = scm.classify_weights(q, already_quantized=True)
    else:
        census = scm.classify_weights(weights, bits=bits)

    moa_alms = spec.n_filters * cost_model.alm_adder_tree(
        int(round(census.mean_nonnull_per_moa)), bits
    )
    # SCM multipliers: zeros cost 0, pow2 cost ~0 (wiring), generic constants
    # cost a shift-add multiplier ≈ bits/2 adders of width `bits`.
    mult_alms = census.generic * cost_model.alm_scm_multiplier(bits)
    return MOAReport(
        spec=spec,
        census=census,
        moa_alms=moa_alms,
        multiplier_alms=mult_alms,
        moa_fraction=moa_alms / max(moa_alms + mult_alms, 1e-9),
    )


def analyze_network(specs: Sequence[ConvLayerSpec], *, bits: int = 8,
                    densities: Optional[Dict[str, float]] = None,
                    seed: int = 0) -> List[MOAReport]:
    rng = np.random.default_rng(seed)
    out = []
    for spec in specs:
        density = None
        if densities and spec.name in densities:
            density = densities[spec.name]
        out.append(analyze_layer(spec, bits=bits, rng=rng, target_density=density))
    return out


def paper_calibrated_densities() -> Dict[str, float]:
    """Per-layer non-null densities implied by Table 1 (n_opd / C·J·K)."""
    return {
        s.name: ALEXNET_PAPER_NOPD[s.name] / s.operands for s in ALEXNET_CONV_SPECS
    }
