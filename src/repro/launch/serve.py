"""Serving CLI: continuous-batching engine (default) or static batch.

Thin front-end over :mod:`repro.serve`. The default mode drives the
:class:`~repro.serve.engine.ServeEngine` with a synthetic Poisson workload
(open-loop arrivals, mixed prompt/generation lengths) and prints per-request
and aggregate latency/throughput metrics; ``--static`` keeps the original
lockstep path (:func:`serve_batch`: one joint prefill, then batched greedy
or sampled decode — the fast path when all requests start together).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --requests 8 --rate 50 --slots 4
  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --static --batch 4 --prompt-len 64 --gen-len 32 --temperature 0.8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, smoke_config
from repro.launch.mesh import ensure_host_devices, make_mesh, parse_mesh
from repro.models.api import build_model
from repro.serve import (GREEDY, ReplicaSet, Sampler, ServeEngine, StepClock,
                         bursty_workload, poisson_workload, resolve_drafter)

__all__ = ["serve_batch", "main"]


def serve_batch(model, params, prompts: dict, *, gen_len: int,
                max_len: int, sampler: Sampler = GREEDY, rng=None):
    """Static-batch serving: joint prefill + ``gen_len`` lockstep decode
    steps with donated cache buffers.

    ``sampler`` is the single next-token policy for the whole batch
    (``rng`` required when it is not greedy). Returns ``(tokens, timings)``
    where ``tokens`` is ``(B, gen_len)`` int32 and timings are in seconds
    (``per_token_ms`` in milliseconds).
    """
    if not sampler.greedy and rng is None:
        raise ValueError("non-greedy sampler needs an rng key")
    prefill_fn = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=max_len))
    decode_fn = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.monotonic()
    logits, cache = prefill_fn(params, prompts)
    logits.block_until_ready()
    t_prefill = time.monotonic() - t0

    B = logits.shape[0]
    out_tokens = []

    def next_tok(lg):
        nonlocal rng
        if sampler.greedy:
            return sampler(lg[:, -1])[:, None]
        rng, k = jax.random.split(rng)
        return sampler(lg[:, -1], k)[:, None]

    tok = next_tok(logits)
    t0 = time.monotonic()
    for _ in range(gen_len):
        out_tokens.append(tok)
        logits, cache = decode_fn(params, cache, tok)
        tok = next_tok(logits)
    tok.block_until_ready()
    t_decode = time.monotonic() - t0
    tokens = jnp.concatenate(out_tokens, axis=1)
    return tokens, {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": B * gen_len / max(t_decode, 1e-9),
        "per_token_ms": 1e3 * t_decode / max(gen_len, 1),
    }


def _build(args):
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step "
                         "(assignment skip rule)")
    return cfg, build_model(cfg)


def _sampler(args) -> Sampler:
    return GREEDY if args.greedy else Sampler(args.temperature)


def _run_static(args):
    cfg, model = _build(args)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    shape = ShapeSpec("serve", args.prompt_len, args.batch, "prefill")
    prompts = model.make_batch(rng, shape)
    max_len = args.prompt_len + args.gen_len + 1
    tokens, stats = serve_batch(model, params, prompts,
                                gen_len=args.gen_len, max_len=max_len,
                                sampler=_sampler(args), rng=rng)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen_len}")
    print(f"[serve] prefill={stats['prefill_s']*1e3:.0f}ms "
          f"decode={stats['per_token_ms']:.1f}ms/tok "
          f"throughput={stats['decode_tok_per_s']:.1f} tok/s")
    print(f"[serve] sample: {np.asarray(tokens[0, :16]).tolist()}")


def _run_engine(args):
    cfg, model = _build(args)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    spec_margin = args.spec_k if args.spec_decode else 0
    max_len = args.max_len \
        or (args.prompt_len + args.gen_len + spec_margin + 1) * 2
    if args.paged and max_len % args.block_size:
        max_len += args.block_size - max_len % args.block_size
    drafter = resolve_drafter(args.drafter, args.spec_k) \
        if args.spec_decode else None
    mesh = make_mesh(parse_mesh(args.mesh)) if args.mesh else None
    chunk = args.prefill_chunk or None
    if chunk is not None and args.paged and chunk % args.block_size:
        raise SystemExit(f"--prefill-chunk {chunk} must be a multiple of "
                         f"--block-size {args.block_size}")
    if args.attn_backend and not args.paged:
        raise SystemExit("--attn-backend selects the paged attention "
                         "backend; it requires --paged")
    engine = ServeEngine(model, params, n_slots=args.slots, max_len=max_len,
                         paged=args.paged, block_size=args.block_size,
                         n_blocks=args.blocks or None, rng=rng,
                         drafter=drafter, mesh=mesh,
                         prefill_chunk_tokens=chunk,
                         scheduling=args.scheduling,
                         attn_backend=args.attn_backend or None)
    if args.scheduling == "slo":
        requests = bursty_workload(
            vocab=cfg.vocab, n_long=args.slots,
            n_burst=max(args.requests - args.slots, 1),
            long_prompt_len=args.prompt_len, long_gen_len=args.gen_len,
            burst_prompt_len=max(args.prompt_len // 4, 1),
            burst_gen_len=max(args.gen_len // 4, 1),
            burst_deadline_s=args.deadline, sampler=_sampler(args),
            seed=args.seed)
    else:
        requests = poisson_workload(
            n_requests=args.requests, vocab=cfg.vocab, rate_rps=args.rate,
            prompt_len_range=(min(4, args.prompt_len), args.prompt_len),
            gen_len_range=(min(2, args.gen_len), args.gen_len),
            sampler=_sampler(args), seed=args.seed)
    results, report = engine.run(requests, warmup=not args.no_warmup)
    print(f"[serve] arch={cfg.name} slots={args.slots} max_len={max_len} "
          f"requests={args.requests} rate={args.rate}/s")
    if mesh is not None:
        axes = ", ".join(f"{a}={s}" for a, s in
                         zip(mesh.axis_names, mesh.devices.shape))
        print(f"[serve] mesh: ({axes}) over {mesh.devices.size} devices, "
              f"family rules for {cfg.family!r} (docs/sharded-serving.md)")
    for r in results:
        m = r.metrics
        print(f"[serve]   req {r.uid}: slot={r.slot} prompt={r.prompt_len} "
              f"gen={r.tokens.size} ttft={m.ttft_s*1e3:.0f}ms "
              f"{m.per_token_ms:.1f}ms/tok ({r.finish_reason.value})")
    print(f"[serve] aggregate: {report['tok_per_s']:.1f} tok/s, "
          f"ttft p50={report['ttft_ms']['p50']:.0f}ms "
          f"p95={report['ttft_ms']['p95']:.0f}ms, "
          f"occupancy={report['slot_occupancy']:.2f}, "
          f"slot_reuse={report['slot_reuse']}, "
          f"warmup compile={report['compile_s']*1e3:.0f}ms (kept out of "
          f"wall_s)")
    if args.spec_decode:
        sp = report["spec"]
        print(f"[serve] spec: drafter={args.drafter} k={sp['k']}, "
              f"{sp['tokens_per_step']:.2f} tokens/step "
              f"(plain decode = 1.00), accept rate "
              f"{sp['accept_rate']:.2f}, accepted hist "
              f"{sp['accepted_hist']}, draft steps {sp['draft_steps']}")
    if args.paged:
        pg = report["paged"]
        print(f"[serve] paged: {pg['n_blocks']}x{pg['block_size']}-token "
              f"blocks, backend={pg['attn_backend']}, "
              f"occupancy={pg['block_occupancy']:.2f}, "
              f"prefix hits={pg['prefix_hits']}/{pg['admissions']}, "
              f"cow={pg['cow_count']}, "
              f"resident={pg['resident_kv_bytes']:,}B "
              f"(dense equiv {pg['dense_equiv_kv_bytes']:,}B), "
              f"kv read/step gathered={pg['gathered_kv_bytes_per_step']:,.0f}B "
              f"fused={pg['fused_kv_bytes_per_step']:,.0f}B")
    if "slo" in report:
        sl = report["slo"]
        print(f"[serve] slo ({report['scheduling']}): attainment "
              f"{sl['deadline_met']}/{sl['deadline_requests']} "
              f"({sl['attainment']:.2f}), goodput "
              f"{sl['goodput_tok_per_s']:.1f} tok/s, deadline ttft "
              f"p99={sl['deadline_ttft_ms']['p99']:.0f}ms, "
              f"preemptions={sl['preemptions']} "
              f"(spills={sl['spills']}, revivals={sl['revivals']}), "
              f"chunked ticks={sl['prefill_chunk_count']}")


def _parse_kill_schedule(spec: str):
    """``"step:replica,step:replica"`` → {replica: [steps]}."""
    schedule = {}
    for item in filter(None, (s.strip() for s in spec.split(","))):
        try:
            step_s, rid_s = item.split(":")
            step, rid = int(step_s), int(rid_s)
        except ValueError:
            raise SystemExit(f"--kill: bad entry {item!r}; expected "
                             "STEP:REPLICA, e.g. 6:1")
        schedule.setdefault(rid, []).append(step)
    return schedule


def _run_replicas(args):
    """Replica-set serving on a deterministic StepClock: the chaos smoke.

    Kills from ``--kill`` are injected through per-replica
    FailureInjectors at the scheduled router steps; ``--reload-at`` saves
    the serving weights as a checkpoint mid-run so the watcher triggers a
    rolling drain → swap → rejoin. Exits non-zero if any request is lost,
    any reload drops an in-flight request, or (greedy) any token stream
    diverges from the failure-free fleet baseline.
    """
    import tempfile

    from repro.checkpoint import CheckpointManager, CheckpointWatcher
    from repro.runtime import FailureInjector

    if args.spec_decode or args.scheduling != "fifo" or args.mesh \
            or args.static:
        raise SystemExit("--replicas drives plain fifo engines tick-by-"
                         "tick; --spec-decode/--scheduling slo/--mesh/"
                         "--static are single-engine modes")
    cfg, model = _build(args)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    max_len = args.max_len or (args.prompt_len + args.gen_len + 1) * 2
    if args.paged and max_len % args.block_size:
        max_len += args.block_size - max_len % args.block_size
    sampler = _sampler(args)
    make_workload = lambda: poisson_workload(  # noqa: E731
        n_requests=args.requests, vocab=cfg.vocab, rate_rps=args.rate,
        prompt_len_range=(min(4, args.prompt_len), args.prompt_len),
        gen_len_range=(min(2, args.gen_len), args.gen_len),
        sampler=sampler, seed=args.seed)
    kills = _parse_kill_schedule(args.kill)
    for rid in kills:
        if not 0 <= rid < args.replicas:
            raise SystemExit(f"--kill: replica {rid} out of range "
                             f"(0..{args.replicas - 1})")

    def fleet(chaos: bool, tmpdir):
        clock = StepClock(dt=args.dt)
        factory = lambda: ServeEngine(  # noqa: E731
            model, params, n_slots=args.slots, max_len=max_len,
            paged=args.paged, block_size=args.block_size,
            n_blocks=args.blocks or None, rng=rng, clock=clock)
        manager = watcher = None
        actions = {}
        if chaos and args.reload_at:
            manager = CheckpointManager(tmpdir)
            watcher = CheckpointWatcher(manager)
            actions[args.reload_at] = \
                lambda _rs: manager.save(1, params)
        rs = ReplicaSet(
            factory, n_replicas=args.replicas, clock=clock,
            failure_injectors={rid: FailureInjector(steps)
                               for rid, steps in kills.items()}
            if chaos else None,
            watcher=watcher,
            load_params=(lambda step: manager.restore(params)[0])
            if watcher else None)
        results, report = rs.run(make_workload(), actions=actions)
        rs.check()
        return results, report

    with tempfile.TemporaryDirectory() as tmpdir:
        base_results, base_report = fleet(False, tmpdir)
        results, report = fleet(True, tmpdir)
    print(f"[serve] arch={cfg.name} replicas={args.replicas} "
          f"slots={args.slots}/replica max_len={max_len} "
          f"requests={args.requests} rate={args.rate}/s dt={args.dt}")
    print(f"[serve] chaos: kills={report['kills']} (schedule "
          f"{args.kill or 'none'}), deaths detected="
          f"{report['deaths_detected']}, requeues={report['requeues']}, "
          f"requeue latency p95="
          f"{report['requeue_latency_ms']['p95']:.0f}ms")
    print(f"[serve] reload: completed={report['reloads_completed']} "
          f"dropped={report['reload_dropped']} versions="
          f"{[r['param_version'] for r in report['replicas']]}")
    print(f"[serve] fleet: {report['completed']}/{report['requests']} "
          f"requests, {report['tok_per_s']:.1f} tok/s "
          f"(baseline {base_report['tok_per_s']:.1f}), router steps="
          f"{report['router_steps']}")
    failures = []
    if report["lost_requests"]:
        failures.append(f"{report['lost_requests']} requests lost")
    if report["reload_dropped"]:
        failures.append(f"reload dropped {report['reload_dropped']} "
                        "in-flight requests")
    if args.reload_at and not report["reloads_completed"]:
        failures.append("scheduled reload never completed")
    if sampler.greedy:
        diverged = [r.uid for r, b in zip(results, base_results)
                    if not np.array_equal(r.tokens, b.tokens)]
        if diverged:
            failures.append(f"greedy tokens diverged from failure-free "
                            f"baseline for uids {diverged}")
        else:
            print("[serve] greedy tokens bit-identical to failure-free "
                  "baseline")
    if failures:
        raise SystemExit("[serve] FAIL: " + "; ".join(failures))


def main():
    ap = argparse.ArgumentParser(
        description="Serve a registry arch: continuous batching (default) "
                    "or --static lockstep batch")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CPU-runnable config")
    ap.add_argument("--static", action="store_true",
                    help="static-batch serve_batch path")
    ap.add_argument("--batch", type=int, default=4,
                    help="[static] batch size")
    ap.add_argument("--prompt-len", type=int, default=64,
                    help="prompt tokens ([engine] upper bound of the range)")
    ap.add_argument("--gen-len", type=int, default=32,
                    help="generated tokens ([engine] upper bound)")
    ap.add_argument("--requests", type=int, default=8,
                    help="[engine] number of workload requests")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="[engine] Poisson arrival rate, requests/s")
    ap.add_argument("--slots", type=int, default=4,
                    help="[engine] decode slots (in-flight requests)")
    ap.add_argument("--max-len", type=int, default=0,
                    help="[engine] per-slot context capacity, tokens")
    ap.add_argument("--paged", action="store_true",
                    help="[engine] paged KV-cache: shared block pool with "
                         "ref-counted prefix caching (docs/paged-kv.md)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="[engine --paged] tokens per physical KV page")
    ap.add_argument("--blocks", type=int, default=0,
                    help="[engine --paged] pool size in pages (0 = dense "
                         "equivalent slots*max_len/block_size)")
    ap.add_argument("--attn-backend", default="",
                    choices=("", "auto", "jnp", "pallas"),
                    help="[engine --paged] paged attention backend: jnp "
                         "(gathered KV view, reference), pallas (fused "
                         "block-table flash kernels, docs/kernels.md), or "
                         "auto (pallas on TPU). Default: the model "
                         "config's (auto)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="[engine] speculative decoding: draft k tokens "
                         "per tick, verify in one pass "
                         "(docs/spec-decode.md)")
    ap.add_argument("--drafter", default="ngram?n=3",
                    help="[engine --spec-decode] drafter spec: "
                         "ngram[?n=N] (prompt lookup) or "
                         "oracle[?accept=P] (target-as-drafter, forced "
                         "accept rate)")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="[engine --spec-decode] draft tokens per verify "
                         "window")
    ap.add_argument("--mesh", default="",
                    help="[engine] run sharded on a DxM device mesh (e.g. "
                         "2x4): params tensor-parallel, KV cache sharded "
                         "over slots/heads (docs/sharded-serving.md). On "
                         "CPU the devices are XLA host-platform devices")
    ap.add_argument("--scheduling", choices=["fifo", "slo"], default="fifo",
                    help="[engine] admission policy: fifo (arrival order) "
                         "or slo (priority + earliest TTFT deadline, with "
                         "preemption; docs/slo-scheduling.md). slo swaps "
                         "the workload for a deadline-carrying bursty one")
    ap.add_argument("--deadline", type=float, default=0.25,
                    help="[engine --scheduling slo] burst requests' TTFT "
                         "deadline, seconds after arrival")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="[engine] split prompts longer than this into "
                         "fixed-budget prefill chunks interleaved with "
                         "decode ticks (0 = one-shot; see "
                         "repro.launch.costing.prefill_chunk_guidance)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="[engine] serve through a fault-tolerant "
                         "replica set of N engines on a deterministic "
                         "StepClock (docs/fault-tolerance.md); 0 = single "
                         "engine, -1 = plan from the visible device count "
                         "(repro.runtime.elastic.plan_replicas)")
    ap.add_argument("--kill", default="",
                    help="[--replicas] chaos schedule STEP:REPLICA[,...] — "
                         "each entry crashes that replica at that router "
                         "step via a FailureInjector; its requests requeue "
                         "after heartbeat detection")
    ap.add_argument("--reload-at", type=int, default=0,
                    help="[--replicas] router step at which to save the "
                         "weights as a checkpoint, triggering a rolling "
                         "watcher-driven hot reload (0 = no reload)")
    ap.add_argument("--dt", type=float, default=1e-3,
                    help="[--replicas] StepClock virtual seconds per "
                         "clock read")
    ap.add_argument("--no-warmup", action="store_true",
                    help="[engine] skip the unmeasured warmup tick "
                         "(first-call XLA compile time then lands in "
                         "wall_s instead of compile_s)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--greedy", action="store_true",
                    help="force greedy decode regardless of --temperature")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.mesh:
        # before any backend touch: XLA locks device count at first init
        ensure_host_devices(parse_mesh(args.mesh))
    if args.replicas == -1:
        from repro.runtime import plan_replicas
        args.replicas = plan_replicas(jax.device_count())
    if args.replicas:
        _run_replicas(args)
    elif args.static:
        _run_static(args)
    else:
        _run_engine(args)


if __name__ == "__main__":
    main()
