"""Batched serving driver: continuous prefill → greedy decode.

Serves any registry arch (``--smoke`` for CPU-runnable sizes): builds the
model, prefills a batch of prompts, then runs batched single-token decode
steps with donated cache buffers. Reports per-phase latency and
tokens/sec. The decode loop is the paper's serial accumulator running at
the system level: one operand (token) per step into a constant-size state.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --batch 4 --prompt-len 64 --gen-len 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, smoke_config
from repro.models.api import build_model

__all__ = ["serve_batch", "main"]


def serve_batch(model, params, prompts: dict, *, gen_len: int,
                max_len: int, greedy: bool = True, rng=None):
    """Prefill + decode ``gen_len`` tokens. Returns (tokens, timings)."""
    prefill_fn = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=max_len))
    decode_fn = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.monotonic()
    logits, cache = prefill_fn(params, prompts)
    logits.block_until_ready()
    t_prefill = time.monotonic() - t0

    B = logits.shape[0]
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t0 = time.monotonic()
    for i in range(gen_len):
        out_tokens.append(tok)
        logits, cache = decode_fn(params, cache, tok)
        if greedy or rng is None:
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        else:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits[:, -1])[:, None] \
                .astype(jnp.int32)
    tok.block_until_ready()
    t_decode = time.monotonic() - t0
    tokens = jnp.concatenate(out_tokens, axis=1)
    return tokens, {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": B * gen_len / max(t_decode, 1e-9),
        "per_token_ms": 1e3 * t_decode / max(gen_len, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "encoder":
        raise SystemExit("encoder-only arch has no decode step "
                         "(assignment skip rule)")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    shape = ShapeSpec("serve", args.prompt_len, args.batch, "prefill")
    prompts = model.make_batch(rng, shape)
    max_len = args.prompt_len + args.gen_len + 1
    tokens, stats = serve_batch(model, params, prompts,
                                gen_len=args.gen_len, max_len=max_len)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen_len}")
    print(f"[serve] prefill={stats['prefill_s']*1e3:.0f}ms "
          f"decode={stats['per_token_ms']:.1f}ms/tok "
          f"throughput={stats['decode_tok_per_s']:.1f} tok/s")
    print(f"[serve] sample: {np.asarray(tokens[0, :16]).tolist()}")


if __name__ == "__main__":
    main()
