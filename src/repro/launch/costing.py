"""Analytic per-cell cost model: FLOPs (exact to our einsums), HBM bytes
(first-order), collective wire bytes (structured ring model).

WHY ANALYTIC: XLA's ``compiled.cost_analysis()`` counts every ``while``
body ONCE, not × trip-count (verified: a length-8 scan reports exactly 1/8
the FLOPs of its unrolled twin). Our models are scan-over-layers with
scan-inside-layer (flash attention, SSD chunks), so raw HLO numbers are
under by 1–3 orders of magnitude. The dry-run therefore records BOTH: the
raw ``cost_analysis`` (labeled loop-undercounted) and this model, which is
exact-by-construction for FLOPs (we wrote every contraction) and validated
against ``cost_analysis`` on fully-unrolled single-layer variants in
``tests/test_costing.py`` (±2 % — see docs/architecture.md §costing).

Conventions: 1 MAC = 2 FLOPs; all values are **per device per step** given
the mesh meta; ring collectives move ``2·B·(k−1)/k`` (all-reduce) or
``B·(k−1)/k`` (all-gather / reduce-scatter) bytes per device for a
per-device-visible buffer of ``B`` bytes over a group of ``k``.

MOA scheduling: the dense-contraction FLOPs are **not** assumed to be a
one-shot matmul — each site queries its configured
:meth:`repro.moa.MOAStrategy.cost` and scales by the strategy's hardware
ops per add. Exact strategies (tree, serial — the paper's TPU result:
scheduling is free) multiply by exactly 1.0; approximate strategies pay
(LOA: ~6 VPU ops per fold where the hard add is one — the §3.2 inversion),
surfaced as a per-component FLOPs increase.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeSpec

__all__ = ["CellCost", "estimate_cell", "request_decode_cost",
           "kv_bytes_per_token", "kv_resident_bytes",
           "expected_accepted_len", "prefill_chunk_guidance",
           "serve_target_cost", "NONCONTRACTION_COMPONENTS",
           "spec_decode_cost", "spec_request_decode_cost",
           "spec_break_even_accept"]

BF16 = 2
F32 = 4


@dataclasses.dataclass(frozen=True)
class MeshMeta:
    pod: int
    data: int
    model: int
    fsdp: bool = True
    # hillclimb levers (docs/architecture.md §Perf levers)
    compress_grads: bool = False    # int8 gradient all-reduce (+err state)
    attn_cp: bool = False           # context-parallel attention: a2a layout
                                    # swap replaces the attn-out all-reduce
    kv_dim_shard: bool = False      # shard cache head_dim over model when
                                    # kv_heads doesn't divide it

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data

    def kv_shard_ways(self, cfg: "ModelConfig") -> int:
        """How many ways the KV cache actually shards (divisibility!)."""
        ways = self.dp if cfg.n_kv_heads else self.chips
        if not cfg.n_kv_heads:
            return ways
        if cfg.n_kv_heads % self.model == 0:
            return self.dp * self.model
        if self.kv_dim_shard and cfg.head_dim % self.model == 0:
            return self.dp * self.model
        return self.dp  # kv heads replicated over the model axis


@dataclasses.dataclass
class CellCost:
    flops: float                  # per device
    hbm_bytes: float              # per device
    collective_bytes: float       # per device (wire)
    components: Dict[str, float]  # named breakdown (global FLOPs)
    bytes_components: Dict[str, float]
    collective_components: Dict[str, float]


# ---------------------------------------------------------------------------
# ring-collective wire models (bytes per device)
# ---------------------------------------------------------------------------

def ring_all_reduce(buf_bytes: float, k: int) -> float:
    return 0.0 if k <= 1 else 2.0 * buf_bytes * (k - 1) / k


def ring_all_gather(full_bytes: float, k: int) -> float:
    """Gathering shards into ``full_bytes`` per device."""
    return 0.0 if k <= 1 else full_bytes * (k - 1) / k


ring_reduce_scatter = ring_all_gather


def all_to_all(buf_bytes: float, k: int) -> float:
    return 0.0 if k <= 1 else buf_bytes * (k - 1) / k


# ---------------------------------------------------------------------------
# forward FLOPs (global, per pass) — mirrors the model code exactly
# ---------------------------------------------------------------------------

def _attn_layer_flops(cfg: ModelConfig, T: float, S_attn: float) -> Dict[str, float]:
    """One attention layer over T tokens attending to S_attn positions.

    Our flash path computes *all* (q-chunk × kv-chunk) blocks — causal
    blocks are masked, not skipped — so the score/PV term is the full
    ``T × S_attn`` rectangle (the useful-compute ratio exposes this; chunk
    skipping is a §Perf lever).
    """
    d, H, Kv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "attn_qkv": 2 * T * d * (H * Dh + 2 * Kv * Dh),
        "attn_scores_pv": 4 * T * S_attn * H * Dh,
        "attn_out": 2 * T * d * H * Dh,
    }


def _mlp_layer_flops(cfg: ModelConfig, T: float) -> float:
    if cfg.family == "encoder":
        return 4 * T * cfg.d_model * cfg.d_ff       # in + out
    return 6 * T * cfg.d_model * cfg.d_ff           # swiglu: gate, up, down


def _moe_layer_flops(cfg: ModelConfig, T: float) -> Dict[str, float]:
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    slots = T * k * cf                               # E·C buffer rows
    return {
        "moe_router": 2 * T * cfg.d_model * E,
        "moe_experts": 6 * slots * cfg.d_model * cfg.d_ff,
    }


def _ssd_layer_flops(cfg: ModelConfig, T: float, decode: bool) -> Dict[str, float]:
    d, di = cfg.d_model, cfg.d_inner
    H, P, N = cfg.n_ssm_heads, cfg.headdim, cfg.d_state
    d_in_proj = 2 * di + 2 * cfg.n_groups * cfg.d_state + H
    conv_dim = di + 2 * cfg.n_groups * cfg.d_state
    out = {
        "ssm_proj": 2 * T * d * d_in_proj + 2 * T * di * d,
        "ssm_conv": 2 * T * cfg.d_conv * conv_dim,
    }
    if decode:
        # outer product dB·x (2THPN) + readout h·C (2THPN) + the dt
        # broadcast einsum (K=1 dot over (T,H,N) — ssd.py step path)
        out["ssm_core"] = 4 * T * H * P * N + 2 * T * H * N
    else:
        # chunked SSD (layers/ssd.py): y_diag = CBᵀ over n (2TLHN) +
        # decay mask (K=1, 2TLH) + ·X over s (2TLHP); states/y_off each
        # pay a 2THPN contraction + a K=1 decay dot (2THP)
        L = cfg.ssd_chunk
        out["ssm_core"] = (2 * T * L * H * (N + P + 1)
                           + 4 * T * H * P * (N + 1))
    return out


def _moa_flops_multiplier(cfg: ModelConfig, site: str,
                          n_operands: int) -> float:
    """Strategy-scheduled FLOPs over exact one-shot FLOPs for one MOA.

    Queries ``cfg.moa_for(site).cost(...)``: an ``n``-operand dot-product
    output costs ``n`` mults + ``n-1`` adds exactly; the strategy reports
    what its adds actually cost on the substrate (LOA: ~6 ops each).
    """
    if n_operands < 2:
        return 1.0
    cost = cfg.moa_for(site).cost(n_operands, cfg.compute_dtype)
    exact = 2.0 * n_operands - 1.0
    return float(cost["flops"]) / exact


def forward_flops(cfg: ModelConfig, *, tokens: float, s_attn: float,
                  decode: bool = False) -> Dict[str, float]:
    """Global FLOPs of one forward pass over ``tokens`` total tokens.

    Per-site MOA strategies scale their components (see
    :func:`_moa_flops_multiplier`); with the default exact strategies the
    multipliers are identically 1.0.
    """
    comp: Dict[str, float] = {}
    L = cfg.n_layers

    def add(d: Dict[str, float], mult: float = 1.0):
        for k, v in d.items():
            comp[k] = comp.get(k, 0.0) + v * mult

    if cfg.family in ("dense", "encoder", "vlm"):
        add(_attn_layer_flops(cfg, tokens, s_attn), L)
        comp["mlp"] = L * _mlp_layer_flops(cfg, tokens)
    elif cfg.family == "moe":
        add(_attn_layer_flops(cfg, tokens, s_attn), L)
        add(_moe_layer_flops(cfg, tokens), L)
    elif cfg.family == "ssm":
        add(_ssd_layer_flops(cfg, tokens, decode), L)
    elif cfg.family == "hybrid":
        add(_ssd_layer_flops(cfg, tokens, decode), L)
        n_apps = cfg.n_layers // cfg.attn_every
        add(_attn_layer_flops(cfg, tokens, s_attn), n_apps)
        comp["mlp"] = n_apps * _mlp_layer_flops(cfg, tokens)
    # logits (VLM: text positions only — approximate with all tokens is
    # wrong, so scale)
    logits_tokens = tokens
    if cfg.family == "vlm":
        logits_tokens = tokens * max(
            1 - cfg.n_patches / max(s_attn, 1), 0.05)
    comp["logits"] = 2 * logits_tokens * cfg.d_model * cfg.vocab

    # ---- MOA strategy scheduling costs (per-site cfg.moa_for query) --------
    m_attn = _moa_flops_multiplier(cfg, "attention", cfg.d_model)
    for key in ("attn_qkv", "attn_out"):
        if key in comp:
            comp[key] *= m_attn
    m_mlp = _moa_flops_multiplier(cfg, "mlp", max(cfg.d_ff, cfg.d_model))
    if "mlp" in comp:
        comp["mlp"] *= m_mlp
    if "moe_experts" in comp:
        # moe_forward routes the router contraction (d_model operands) and
        # the expert matmuls (d_ff) through the same "moe" site strategy
        comp["moe_experts"] *= _moa_flops_multiplier(cfg, "moe", cfg.d_ff)
        comp["moe_router"] *= _moa_flops_multiplier(cfg, "moe", cfg.d_model)
    return comp


#: components of :func:`forward_flops` implemented WITHOUT MXU
#: contractions (the depthwise conv is an elementwise shift-multiply-sum,
#: not a ``conv_general_dilated``), so the static contraction-FLOP audit
#: cannot see them. ``serve_target_cost`` excludes them; they are real
#: compute and stay in :func:`forward_flops` for wall-clock estimates.
NONCONTRACTION_COMPONENTS = ("ssm_conv",)

#: serve-path phases ``analysis/targets.py`` builds per family; the keying
#: below must track ``build_family_targets`` exactly — the cost audit
#: (analysis/cost_audit.py) reconciles each against its traced jaxpr.
SERVE_PHASES = (
    "prefill", "decode", "verify", "prefill_chunk",
    "paged_decode", "paged_decode_hw", "paged_decode_fused",
    "paged_verify", "paged_verify_fused", "paged_suffix_prefill",
)


def _ssd_conv_hist_flops(cfg: ModelConfig, batch: float) -> float:
    """Per-layer FLOPs of the conv-history seed recompute in serve prefill.

    ``prefill`` re-projects the last ``d_conv - 1`` input positions per
    sequence to rebuild the rolling conv window it hands the decode cache
    (models/mamba2.py, models/zamba2.py) — cache-building work the plain
    training forward does not do, which is why it lives here and not in
    :func:`_ssd_layer_flops`."""
    d_in_proj = (2 * cfg.d_inner + 2 * cfg.n_groups * cfg.d_state
                 + cfg.n_ssm_heads)
    return 2.0 * batch * (cfg.d_conv - 1) * cfg.d_model * d_in_proj


def serve_target_cost(cfg: ModelConfig, phase: str, *, slots: int,
                      max_len: int, window: int, block_size: int,
                      prefill_len: int) -> Dict[str, float]:
    """Analytic cost of one serve-path audit target, keyed exactly the way
    ``analysis/targets.py`` shapes its traced callables (``AUDIT_SHAPE``).

    Returns ``{"flops", "components", and for paged phases
    "kv_gather_bytes"}``. ``flops`` is **contraction FLOPs only**
    (:data:`NONCONTRACTION_COMPONENTS` excluded) so it is directly
    comparable to the jaxpr walker's ``dot_general``/conv counts; the
    serve-prefill conv-history recompute (``ssm_conv_hist``) is added for
    prefill-like phases. ``kv_gather_bytes`` prices the paged-KV gather
    stream: the full resident window per decode/verify pass
    (``slots × s_kv × kv_bytes_per_token``), once per pass — except the
    hybrid's sequential verify, which re-gathers per verify step — and 0
    for fused kernels, which walk the pool in place (their traffic is the
    audit's ``pallas_stream_bytes``, recorded, not reconciled).
    """
    if phase not in SERVE_PHASES:
        raise ValueError(f"unknown serve phase {phase!r}; "
                         f"expected one of {SERVE_PHASES}")
    hw = max((max_len // block_size) // 2, 1)   # targets.py half-window
    batch = None                                # conv-hist rebuild batch
    if phase == "prefill":
        tokens, s_attn, decode = slots * prefill_len, prefill_len, False
        logits_tokens, batch = slots, slots     # last-position logits
    elif phase in ("decode", "paged_decode", "paged_decode_fused"):
        tokens, s_attn, decode = slots, max_len, True
        logits_tokens = slots
    elif phase == "paged_decode_hw":
        tokens, s_attn, decode = slots, hw * block_size, True
        logits_tokens = slots
    elif phase in ("verify", "paged_verify", "paged_verify_fused"):
        tokens, s_attn, decode = slots * window, max_len, True
        logits_tokens = slots * window
    else:  # prefill_chunk / paged_suffix_prefill: one sequence, a chunk
        #    attending its own tokens plus an equal-length prior context
        tokens, s_attn, decode = prefill_len, 2 * prefill_len, False
        logits_tokens, batch = 1, 1
    comp = forward_flops(cfg, tokens=float(tokens), s_attn=float(s_attn),
                         decode=decode)
    comp["logits"] = 2.0 * logits_tokens * cfg.d_model * cfg.vocab
    for key in NONCONTRACTION_COMPONENTS:
        comp.pop(key, None)
    if batch is not None and cfg.family in ("ssm", "hybrid"):
        comp["ssm_conv_hist"] = cfg.n_layers * _ssd_conv_hist_flops(
            cfg, float(batch))
    out: Dict[str, float] = {"flops": float(sum(comp.values()))}
    if phase.startswith("paged_"):
        kvbpt = kv_bytes_per_token(cfg)
        if phase == "paged_decode":
            kv = slots * max_len * kvbpt
        elif phase == "paged_decode_hw":
            kv = slots * hw * block_size * kvbpt
        elif phase == "paged_verify":
            steps = window if cfg.family == "hybrid" else 1
            kv = slots * max_len * kvbpt * steps
        elif phase == "paged_suffix_prefill":
            # the suffix callable receives the prefix KV as a dense
            # operand (materialized by the engine before the call), so
            # the traced jaxpr has no in-attention KV gather
            kv = 0.0
        else:                                   # *_fused
            kv = 0.0
        out["kv_gather_bytes"] = float(kv)
    out["components"] = comp  # type: ignore[assignment]
    return out


def kv_bytes_per_token(cfg: ModelConfig) -> float:
    """KV-cache bytes one token occupies across all KV-bearing stacks
    (layers, or application points for the hybrid; 0 for pure SSM and the
    cacheless encoder).

    Delegates to :meth:`repro.models.api.Model.cache_spec` — one source
    of truth, derived from the real cache leaves via ``eval_shape`` (so
    int8 quantization scales are counted; the serve report's
    ``resident_kv_bytes`` and this cost model agree by construction).
    """
    from repro.models.api import build_model  # lazy: models sit above us

    return float(build_model(cfg).cache_spec().kv_bytes_per_token)


def kv_resident_bytes(cfg: ModelConfig, *, n_blocks_in_use: int,
                      block_size: int) -> float:
    """HBM bytes the paged KV cache actually holds resident: blocks in
    use, not ``n_slots · max_len`` — the dense layout's reservation. The
    serve report's ``resident_kv_bytes`` vs ``dense_equiv_kv_bytes``
    columns are this quantity against the dense equivalent."""
    return n_blocks_in_use * block_size * kv_bytes_per_token(cfg)


def request_decode_cost(cfg: ModelConfig, *, prompt_tokens: int,
                        new_tokens: int) -> float:
    """Strategy-priced FLOPs of one serve request's decode steps.

    The first generated token comes from the prefill logits, so this sums
    :func:`forward_flops` over the remaining ``new_tokens - 1`` single-token
    decode steps, with the attended context growing by one token per step
    (``prompt_tokens + t + 1``). Each step inherits the per-site MOA
    multipliers, so exact strategies (tree/serial) price at 1.0× while
    approximate ones (LOA: ~6 VPU ops per fold) inflate the total — the
    serving-level view of the §3.2 inversion. O(new_tokens) Python loop;
    units: FLOPs (global, this request only).
    """
    total = 0.0
    for t in range(max(new_tokens - 1, 0)):
        s_attn = float(prompt_tokens + t + 1)
        total += sum(forward_flops(cfg, tokens=1.0, s_attn=s_attn,
                                   decode=True).values())
    return total


def spec_request_decode_cost(cfg: ModelConfig, *, k: int,
                             tick_contexts) -> float:
    """Strategy-priced FLOPs one speculatively-served request actually
    spent on target-side verify passes.

    ``tick_contexts`` lists the request's committed context length
    (tokens whose K/V was in its slot) at each verify tick it was active;
    each tick scores ``k + 1`` tokens attending on average the mid-window
    context. This is the *measured* counterpart of
    :func:`spec_decode_cost`'s ``flops_per_token_spec × emitted`` —
    unlike :func:`request_decode_cost`, rejected draft positions are
    compute spent, so a low accept rate shows up as more FLOPs per
    emitted token, not fewer. Draft-model work is not attributed per
    request (it is batched across slots); the engine reports it in
    ``report["spec"]["draft_steps"]``. Units: FLOPs (global, this
    request's verify share only).
    """
    total = 0.0
    for ctx in tick_contexts:
        s_attn = float(ctx) + (k + 2) / 2.0
        total += sum(forward_flops(cfg, tokens=float(k + 1), s_attn=s_attn,
                                   decode=True).values())
    return total


def prefill_chunk_guidance(cfg: ModelConfig, *, n_slots: int,
                           max_len: int, mean_context: float,
                           stall_budget_ticks: float = 4.0,
                           block_size: int = 0) -> dict:
    """Size ``ServeEngine(prefill_chunk_tokens=...)`` from the cost model.

    Chunked prefill bounds head-of-line blocking: every prefill tick of a
    long prompt steals one engine tick from the decoding slots, so the
    right chunk is the *largest* one whose prefill FLOPs stay within
    ``stall_budget_ticks`` batched decode ticks — big enough to amortize
    per-chunk overhead (and, for recurrent families, to cover whole
    ``ssd_chunk`` blocks), small enough that a decode token is never
    delayed by more than the budget. Candidates are multiples of the
    family's chunk alignment (``cfg.ssd_chunk`` for ssm/hybrid) and, when
    ``block_size`` is given (paged engine), of the page size; the floor is
    one alignment unit even when it busts the budget (chunks cannot be
    split below it). ``mean_context`` is the expected attended context of
    a decode tick (tokens); units throughout: tokens and FLOPs.

    Returns a dict: ``prefill_chunk_tokens`` (the suggestion),
    ``decode_tick_flops``, ``chunk_prefill_flops``, ``stall_ticks`` (the
    achieved ratio), and ``alignment``.
    """
    if n_slots < 1 or max_len < 1:
        raise ValueError("n_slots and max_len must be >= 1")
    if stall_budget_ticks <= 0:
        raise ValueError("stall_budget_ticks must be > 0")
    align = cfg.ssd_chunk if cfg.family in ("ssm", "hybrid") else 1
    if block_size:
        align = align * block_size // math.gcd(align, block_size)
    tick_flops = _decode_step_flops(cfg, tokens=float(n_slots),
                                    s_attn=mean_context)

    def chunk_flops(c: float) -> float:
        # a mid-prompt chunk of c tokens attends on average ~max_len/2
        # prior positions (worst-case-ish context for the suffix chunks)
        return sum(forward_flops(cfg, tokens=c, s_attn=max_len / 2.0,
                                 decode=False).values())

    best = align
    c = align
    while c + align <= max_len \
            and chunk_flops(float(c + align)) \
            <= stall_budget_ticks * tick_flops:
        c += align
        best = c
    return {
        "prefill_chunk_tokens": best,
        "alignment": align,
        "decode_tick_flops": tick_flops,
        "chunk_prefill_flops": chunk_flops(float(best)),
        "stall_ticks": chunk_flops(float(best)) / max(tick_flops, 1e-9),
    }


def _train_multiplier(cfg: ModelConfig) -> float:
    """fwd=1, bwd=2, remat recompute: full≈+1, dots≈+0.5, none=+0."""
    return {"full": 4.0, "dots": 3.5, "none": 3.0}[cfg.remat]


# ---------------------------------------------------------------------------
# speculative decoding: the acceptance-aware "does the gamble pay" model
# ---------------------------------------------------------------------------
# The paper's §4 lesson in serving clothes: §3.1 serialization looked
# great in arithmetic-count terms and lost after synthesis. Speculative
# decoding spends (k+1)·target + k·draft scoring work per tick to collapse
# serial decode steps, and only the *accept rate* — an emergent workload
# property, like the synthesizer's routing — decides whether the bet pays.
# These estimators price the bet both ways (steps saved vs FLOPs burned)
# so the serving stack can be sized before a benchmark run; the measured
# counterpart is the engine's ``report["spec"]``
# (docs/cost-model.md §speculative).


def expected_accepted_len(k: int, accept_prob: float) -> float:
    """Expected accepted draft tokens per verify with i.i.d. per-position
    accept probability ``a``: the draft survives position ``i`` only if
    all earlier positions survived, so ``E[N] = Σ_{i=1..k} a^i``."""
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    a = min(max(accept_prob, 0.0), 1.0)
    return float(sum(a ** i for i in range(1, k + 1)))


def _decode_step_flops(cfg: ModelConfig, *, tokens: float,
                       s_attn: float) -> float:
    return sum(forward_flops(cfg, tokens=tokens, s_attn=s_attn,
                             decode=True).values())


def spec_decode_cost(cfg: ModelConfig, *, k: int, accept_prob: float,
                     s_attn: float,
                     draft_cfg: Optional[ModelConfig] = None) -> Dict[str, float]:
    """Acceptance-aware speculative-decoding estimate at context ``s_attn``.

    Per verify tick the target scores ``k + 1`` tokens in one pass and the
    drafter spends ``k`` draft-model steps (0 for lookup drafters —
    ``draft_cfg=None``); the tick emits ``E = expected_accepted_len + 1``
    tokens. Two currencies, mirroring the paper's ALM-vs-latency split:

    * ``step_speedup`` — emitted tokens per *serial target pass*, assuming
      a (k+1)-token verify costs one decode step's latency (decode is
      weight-stream-bound, so the verify amortizes the same HBM traffic —
      the TPU analogue of the serializer's free clocking) and a draft step
      costs its FLOPs-ratio fraction of a target step;
    * ``flops_overhead`` — strategy-priced FLOPs per *emitted* token over
      plain decode, which is always ≥ 1: speculation burns compute to buy
      latency, exactly the multiplexing trade the paper warns must be
      measured, not assumed.

    All FLOPs inherit the per-site MOA strategy multipliers (LOA ~6×).
    Returns a dict with both, plus the raw per-tick terms.
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    emitted = expected_accepted_len(k, accept_prob) + 1.0
    target_step = _decode_step_flops(cfg, tokens=1.0, s_attn=s_attn)
    verify = _decode_step_flops(cfg, tokens=float(k + 1), s_attn=s_attn)
    if draft_cfg is None:
        draft_step, draft_total = 0.0, 0.0
    else:
        draft_step = _decode_step_flops(draft_cfg, tokens=1.0,
                                        s_attn=s_attn)
        draft_total = k * draft_step
    draft_ratio = draft_step / max(target_step, 1e-30)
    # one verify ≈ one target-step latency; each draft step ≈ its relative
    # FLOPs share of a target step
    tick_latency_steps = 1.0 + k * draft_ratio
    return {
        "k": float(k),
        "accept_prob": float(accept_prob),
        "expected_tokens_per_step": emitted,
        "target_step_flops": target_step,
        "verify_flops": verify,
        "draft_flops": draft_total,
        "flops_per_token_plain": target_step,
        "flops_per_token_spec": (verify + draft_total) / emitted,
        "flops_overhead": (verify + draft_total) / (emitted * target_step),
        "step_speedup": emitted / tick_latency_steps,
    }


def spec_break_even_accept(cfg: ModelConfig, *, k: int, s_attn: float,
                           draft_cfg: Optional[ModelConfig] = None,
                           tol: float = 1e-3) -> float:
    """Smallest per-position accept probability at which speculation wins
    (``step_speedup > 1``), by bisection; 1.0 means it never pays at this
    ``k`` / draft-cost point (the benchmark's negative-result column)."""
    def speedup(a: float) -> float:
        return spec_decode_cost(cfg, k=k, accept_prob=a, s_attn=s_attn,
                                draft_cfg=draft_cfg)["step_speedup"]

    if speedup(1.0) <= 1.0:
        return 1.0
    lo, hi = 0.0, 1.0
    if speedup(lo) > 1.0:
        return 0.0
    while hi - lo > tol:
        mid = 0.5 * (lo + hi)
        if speedup(mid) > 1.0:
            hi = mid
        else:
            lo = mid
    return hi


# ---------------------------------------------------------------------------
# cell-level estimate
# ---------------------------------------------------------------------------


def estimate_cell(cfg: ModelConfig, shape: ShapeSpec, mesh: MeshMeta, *,
                  resident_kv_tokens: Optional[float] = None) -> CellCost:
    """Per-cell cost estimate.

    ``resident_kv_tokens``: decode-phase override for the KV tokens the
    cache actually holds (paged serving: blocks in use × block size).
    Default prices the dense layout's full ``B × S`` reservation.
    """
    B, S = shape.global_batch, shape.seq_len
    phase = shape.phase
    decode = phase == "decode"
    tokens = float(B) if decode else float(B * S)
    s_attn = float(S)

    comp = forward_flops(cfg, tokens=tokens, s_attn=s_attn, decode=decode)
    fwd = sum(comp.values())
    if phase == "train":
        mult = _train_multiplier(cfg)
        total_flops = (fwd - comp["logits"]) * mult + comp["logits"] * 3.0
    else:
        total_flops = fwd

    # ---- HBM bytes (first-order) -------------------------------------------
    pbytes_f32 = cfg.param_count() * F32
    pbytes_bf16 = cfg.param_count() * BF16
    chips = mesh.chips
    bcomp: Dict[str, float] = {}
    T_dev = tokens / max(mesh.dp, 1)
    d = cfg.d_model
    if phase == "train":
        # weights ×2 (fwd+bwd reads), grad write, adam m/v r+w, param r+w
        bcomp["params_opt"] = (2 * pbytes_bf16 + 8 * pbytes_f32) / chips
        if mesh.compress_grads:
            bcomp["error_feedback"] = 2 * pbytes_f32 / chips
        # residual + ~8 intermediates per layer, fwd write + bwd read, ×2 remat
        act_mult = {"full": 1.0, "dots": 1.5, "none": 2.0}[cfg.remat]
        bcomp["activations"] = (cfg.n_layers * T_dev * d * BF16
                                * 8 * 2 * act_mult) / mesh.model
        # flash KV re-read: KV streamed once per q-chunk
        if cfg.family in ("dense", "vlm", "moe", "encoder"):
            nq = max(S // cfg.q_chunk, 1)
            kv_b = tokens * cfg.n_kv_heads * cfg.head_dim * 2 * BF16
            bcomp["kv_stream"] = (cfg.n_layers * nq * kv_b) / chips
        bcomp["logits"] = 3 * T_dev * cfg.vocab * F32 / mesh.model
    elif phase == "prefill":
        bcomp["params"] = pbytes_bf16 / chips
        bcomp["activations"] = (cfg.n_layers * T_dev * d * BF16 * 8) \
            / mesh.model
        if cfg.family in ("dense", "vlm", "moe"):
            nq = max(S // cfg.q_chunk, 1)
            kv_b = tokens * cfg.n_kv_heads * cfg.head_dim * 2 * BF16
            bcomp["kv_stream"] = (cfg.n_layers * nq * kv_b) / chips
            bcomp["kv_cache_write"] = (cfg.n_layers * tokens * cfg.n_kv_heads
                                       * cfg.head_dim * 2 * BF16) / chips
    else:  # decode
        bcomp["params"] = pbytes_bf16 / chips
        kv_ways = mesh.kv_shard_ways(cfg)
        kv_tokens = float(B * S) if resident_kv_tokens is None \
            else float(resident_kv_tokens)
        if cfg.family in ("dense", "vlm", "moe", "hybrid"):
            bcomp["kv_cache_read"] = \
                kv_bytes_per_token(cfg) * kv_tokens / kv_ways
        if cfg.family in ("ssm", "hybrid"):
            ssm_state = (cfg.n_layers * B * cfg.n_ssm_heads * cfg.headdim
                         * cfg.d_state * F32)
            bcomp["ssm_state"] = 2 * ssm_state / chips

    # ---- collective wire bytes ----------------------------------------------
    ccomp: Dict[str, float] = {}
    tp = mesh.model
    n_attn = cfg.n_layers if cfg.family not in ("ssm", "hybrid") else \
        (cfg.n_layers // cfg.attn_every if cfg.attn_every else 0)

    def block_ar_count() -> float:
        """Activation all-reduces per forward pass.

        TP inserts one AR per sharded-output block: attention (attn-out)
        and dense MLP (down-proj). MoE layers have NO mlp AR — the combine
        is the all-to-all, charged separately. Context-parallel attention
        (attn_cp) replaces the attn AR with a layout a2a, charged below.
        """
        attn_ar = 0 if mesh.attn_cp else n_attn
        if cfg.family == "moe":
            return attn_ar
        if cfg.family == "ssm":
            return cfg.n_layers  # ssm out_proj AR
        if cfg.family == "hybrid":
            return cfg.n_layers + attn_ar + n_attn  # mamba + shared mlp
        return attn_ar + cfg.n_layers  # attn + mlp per layer

    if phase == "train":
        grad_shard = pbytes_f32 / tp          # per model-shard gradient bytes
        grad_elem = 1.0 if mesh.compress_grads else 1.0 * F32
        ccomp["grad_reduce"] = ring_all_reduce(
            grad_shard * (grad_elem / F32), mesh.dp)
        if mesh.fsdp:
            # weights gathered over data axis fwd+bwd (bf16 compute copies)
            ccomp["fsdp_allgather"] = 2 * ring_all_gather(
                pbytes_bf16 / tp, mesh.data)
        act = T_dev * d * BF16
        ccomp["tp_activations"] = 2 * block_ar_count() * ring_all_reduce(
            act, tp)
        if mesh.attn_cp:
            # layout swap: each device exchanges only its activation shard
            ccomp["attn_cp_a2a"] = 2 * 2 * n_attn * all_to_all(act / tp, tp)
        if cfg.loss_impl == "gather":
            ccomp["logits_gather"] = ring_all_gather(
                T_dev * cfg.vocab * F32, tp) * 3  # fwd + bwd scatter
        else:
            ccomp["vocab_parallel_ce"] = ring_all_reduce(T_dev * F32 * 2, tp)
        if cfg.family == "moe":
            ccomp["moe_all_to_all"] = 2 * 2 * cfg.n_layers * all_to_all(
                T_dev * cfg.top_k * d * BF16, tp)
    else:
        act = (tokens / max(mesh.dp, 1)) * d * BF16
        ccomp["tp_activations"] = block_ar_count() * ring_all_reduce(act, tp)
        if mesh.attn_cp:
            ccomp["attn_cp_a2a"] = 2 * n_attn * all_to_all(act / tp, tp)
        if cfg.family == "moe":
            ccomp["moe_all_to_all"] = 2 * cfg.n_layers * all_to_all(
                (tokens / max(mesh.dp, 1)) * cfg.top_k * d * BF16, tp)
        if decode and shape.global_batch < mesh.dp:
            # SP decode: split-K softmax combine over the data axis
            stats = cfg.n_heads * 2 * F32 * B
            ccomp["sp_softmax_combine"] = n_attn * ring_all_reduce(
                stats, mesh.data)

    return CellCost(
        flops=total_flops / chips,
        hbm_bytes=sum(bcomp.values()),
        collective_bytes=sum(ccomp.values()),
        components=comp,
        bytes_components=bcomp,
        collective_components=ccomp,
    )
