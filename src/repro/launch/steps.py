"""Jitted step builders: train / prefill / decode, with sharding inference.

``infer_param_axes`` maps every parameter leaf to logical axis names by
path + rank (the tables below); ``build_shardings`` turns logical names
into ``NamedSharding``s under the active rules, **dropping any axis that
does not divide the dimension** (GQA kv=8 on a model=16 axis replicates
rather than erroring) and optionally upgrading unsharded major dims to
FSDP over the ``data`` axis (ZeRO-3).
"""

from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.api import Model
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compressed_gradients, cosine_schedule,
                         init_error_feedback)
from repro.parallel import (ShardingRules, logical_to_spec,
                            replicate_uneven_kv_heads)

__all__ = [
    "infer_param_axes", "build_shardings", "batch_specs", "cache_specs",
    "TrainState", "init_train_state", "build_train_step",
    "build_prefill_step", "build_decode_step", "rules_for",
]


# ---------------------------------------------------------------------------
# Logical axes by parameter path
# ---------------------------------------------------------------------------

_NAME_TABLE = {
    # attention
    "wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"), "wo": ("heads", "embed"),
    "bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",),
    # dense mlp
    "w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
    "w_down": ("ff", "embed"),
    "w_in": ("embed", "ff"), "b_in": ("ff",),
    "w_out": ("ff", "embed"), "b_out": ("embed",),
    # embedding
    "table": ("vocab", "embed"), "unembed": ("vocab", "embed"),
    "pos_embed": (None, "embed"), "mask_embed": ("embed",),
    # moe
    "router": ("embed", "experts"),
    # mamba2
    "in_proj": ("embed", "ssm_inner"), "out_proj": ("ssm_inner", "embed"),
    "conv_w": (None, "ssm_inner"), "conv_b": ("ssm_inner",),
    "a_log": ("ssm_heads",), "dt_bias": ("ssm_heads",),
    "d_skip": ("ssm_heads",),
    # norms / misc
    "scale": ("norm",), "bias": ("norm",), "w": ("embed", "embed_out"),
    "b": ("embed_out",),
}

_MOE_TABLE = {
    "w_gate": ("experts", "embed", "ff"), "w_up": ("experts", "embed", "ff"),
    "w_down": ("experts", "ff", "embed"),
}

_STACKED_KEYS = ("layers", "app_norms")


def infer_param_axes(params) -> Any:
    """Pytree of logical-axis tuples matching ``params``' structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        table = _MOE_TABLE if ("moe" in keys and name in _MOE_TABLE) \
            else _NAME_TABLE
        axes = table.get(name)
        if axes is None:
            axes = (None,) * leaf.ndim
        stacked = any(k in _STACKED_KEYS for k in keys)
        if stacked:
            axes = (None,) + tuple(axes)
        axes = tuple(axes)[: leaf.ndim]
        axes = axes + (None,) * (leaf.ndim - len(axes))
        out.append(axes)
    return jax.tree_util.tree_unflatten(treedef, out)


def _dedupe_spec(spec: P) -> P:
    """A mesh axis may shard at most one dim: first occurrence wins (e.g.
    MoE expert weights map both 'experts' and 'ff' to 'model' — EP takes
    priority, the ff dim replicates)."""
    seen = set()
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        if any(a in seen for a in axes):
            out.append(None)
            continue
        seen.update(axes)
        out.append(entry)
    return P(*out)


def _divisible_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop axes that don't evenly divide their dim (replicate instead)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes[a]
        out.append(entry if dim % total == 0 else None)
    return P(*out)


def build_shardings(tree, axes_tree, mesh: Mesh, rules: ShardingRules,
                    *, fsdp: bool = False) -> Any:
    """Logical axes + rules → NamedSharding pytree (divisibility-safe).

    FSDP shards over ALL data-parallel mesh axes (the rules' ``fsdp``
    entry, default ``(pod, data)`` — absent axes dropped), so optimizer
    state halves again on the multi-pod mesh.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fsdp_entry = rules.lookup("fsdp")
    if fsdp_entry is None:
        fsdp_axes: tuple = ()
    elif isinstance(fsdp_entry, str):
        fsdp_axes = (fsdp_entry,)
    else:
        fsdp_axes = tuple(fsdp_entry)
    fsdp_axes = tuple(a for a in fsdp_axes if a in sizes)
    fsdp_size = 1
    for a in fsdp_axes:
        fsdp_size *= sizes[a]
    fsdp_spec_entry = (fsdp_axes[0] if len(fsdp_axes) == 1 else fsdp_axes) \
        if fsdp_axes else None

    def one(leaf, axes):
        spec = _dedupe_spec(logical_to_spec(axes, rules, mesh))
        spec = _divisible_spec(leaf.shape, spec, mesh)
        if fsdp and leaf.ndim >= 2 and fsdp_axes:
            entries = list(tuple(spec) + (None,) * (leaf.ndim - len(spec)))
            flat_axes = [a for e in entries if e is not None
                         for a in (e if isinstance(e, tuple) else (e,))]
            if any(a in flat_axes for a in fsdp_axes):
                return NamedSharding(mesh, P(*entries))
            # never FSDP the scan (stacked-layer) axis: dim 0 of stacked
            # leaves (axes was prepended with None and rank is >= 3)
            start = 1 if (len(axes) and axes[0] is None and leaf.ndim >= 3) else 0
            for i in range(start, leaf.ndim):
                if entries[i] is None and leaf.shape[i] % fsdp_size == 0 \
                        and leaf.shape[i] >= fsdp_size:
                    entries[i] = fsdp_spec_entry
                    break
            spec = P(*entries)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, tree, axes_tree)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

_BATCH_TABLE = {
    "tokens": ("batch", "seq"), "labels": ("batch", "seq"),
    "loss_mask": ("batch", "seq"),
    "frames": ("batch", "seq", "embed"), "mask": ("batch", "seq"),
    "targets": ("batch", "seq"), "patches": ("batch", "seq", "embed"),
}

_CACHE_TABLE = {
    # 'kv_heads_cache' is distinct from the weights' 'kv_heads' so the
    # kv_dim_shard variant can re-layout the cache without un-sharding the
    # (flattened, divisible) K/V projection weights
    "k": (None, "batch", "kv_seq", "kv_heads_cache", "head_dim"),
    "v": (None, "batch", "kv_seq", "kv_heads_cache", "head_dim"),
    # scales have no head_dim — shard their seq dim instead (scale_seq),
    # orthogonal to the cache's head_dim sharding (kv_dim_shard variant)
    "k_scale": (None, "batch", "scale_seq", "kv_heads"),
    "v_scale": (None, "batch", "scale_seq", "kv_heads"),
    "h": (None, "batch", "ssm_heads", None, "state"),
    "conv": (None, "batch", None, "ssm_inner"),
    "pos": (),
}


def batch_specs(specs_tree, mesh: Mesh, rules: ShardingRules):
    """ShapeDtypeStruct batch pytree → NamedSharding pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs_tree)
    out = []
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        if "cache" in keys and name in _CACHE_TABLE:
            axes = _CACHE_TABLE[name]
        elif name in _CACHE_TABLE and name in ("k", "v", "h", "conv", "pos"):
            axes = _CACHE_TABLE[name]
        else:
            axes = _BATCH_TABLE.get(name, (None,) * leaf.ndim)
        axes = tuple(axes)[: leaf.ndim]
        axes = axes + (None,) * (leaf.ndim - len(axes))
        spec = _dedupe_spec(logical_to_spec(axes, rules, mesh))
        spec = _divisible_spec(leaf.shape, spec, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


cache_specs = batch_specs  # same table handles cache entries


def rules_for(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
              base: ShardingRules) -> ShardingRules:
    """Per-(arch, shape) rule adjustments.

    long-context decode with batch 1 cannot shard the batch axis — shard
    the KV cache / sequence dimension over ``data`` instead (SP / split-K
    decode).
    """
    rules = base
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_ways = 1
    for a in ("pod", "data"):
        batch_ways *= axis_sizes.get(a, 1)
    if shape.phase == "decode" and shape.global_batch < batch_ways:
        rules = rules.with_overrides(batch=None, kv_seq="data", seq=None)
    # the decode path's in-flight cache constraints
    # (attention._constrain_cache) would pin an uneven kv-head sharding
    # (GQA kv < model axis) against GSPMD's padded choice and trigger full
    # rematerialization copies — replicate the cache head axis instead
    # (the input-side _CACHE_TABLE sharding is divisibility-dropped too)
    return replicate_uneven_kv_heads(rules, cfg.n_kv_heads, mesh)


# ---------------------------------------------------------------------------
# Train state / steps
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    adamw: AdamWConfig = AdamWConfig()
    compress_grads: bool = False
    # gradient accumulation: the global batch is split into this many
    # microbatches processed sequentially (lax.scan) — divides the live
    # activation footprint by the same factor at identical math
    # (loss/grads averaged); collective volume per step is unchanged
    # except the gradient reduction, which still happens once.
    microbatches: int = 1


def init_train_state(model: Model, rng, *, hyper: TrainHyper) -> dict:
    params = model.init(rng)
    state = {
        "params": params,
        "opt": adamw_init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if hyper.compress_grads:
        state["err"] = init_error_feedback(params)
    return state


def state_axes(state: dict) -> dict:
    """Logical axes for the full train state (opt moments mirror params)."""
    p_axes = infer_param_axes(state["params"])
    out = {
        "params": p_axes,
        "opt": {"m": p_axes, "v": p_axes, "count": ()},
        "step": (),
    }
    if "err" in state:
        out["err"] = p_axes
    return out


def _accumulate_grads(model: Model, params, batch: dict, n_micro: int):
    """lax.scan over microbatches; returns mean grads + last metrics."""
    def split(a):
        b = a.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return a.reshape((n_micro, b // n_micro) + a.shape[1:])

    micro = jax.tree.map(split, batch)

    def body(carry, mbatch):
        gsum = carry

        def loss_fn(p):
            return model.loss(p, mbatch)

        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                            gsum, grads)
        return gsum, metrics

    gzero = jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), params)
    gsum, metrics_stacked = jax.lax.scan(body, gzero, micro)
    grads = jax.tree.map(lambda a: a / n_micro, gsum)
    metrics = jax.tree.map(lambda a: a[-1], metrics_stacked)
    return grads, metrics


def build_train_step(model: Model, *, hyper: TrainHyper) -> Callable:
    def train_step(state: dict, batch: dict) -> Tuple[dict, dict]:
        if hyper.microbatches > 1:
            grads, metrics = _accumulate_grads(
                model, state["params"], batch, hyper.microbatches)
        else:
            def loss_fn(p):
                return model.loss(p, batch)

            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"])
        new_err = None
        if hyper.compress_grads:
            grads, new_err = compressed_gradients(grads, state["err"])
        lr = cosine_schedule(state["step"], peak_lr=hyper.peak_lr,
                             warmup_steps=hyper.warmup_steps,
                             total_steps=hyper.total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], state["params"], lr=lr, config=hyper.adamw)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_err is not None:
            new_state["err"] = new_err
        return new_state, {**metrics, **opt_metrics}

    return train_step


def build_prefill_step(model: Model, *, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=max_len)

    return prefill_step


def build_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return decode_step
