"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init; everything
else sees the real single-device CPU).

Topology model (TPU v5e): one pod = 16×16 = 256 chips; ``multi_pod`` adds a
leading ``pod`` axis across 2 pods (512 chips) connected by DCI. Axis use:

  pod    — outer data parallelism (gradient reduction crosses pods once)
  data   — data parallelism + FSDP parameter sharding (intra-pod ICI)
  model  — tensor/expert parallelism (highest-bandwidth dimension)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...],
              axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary (test-sized) mesh: shape (d, m) or (p, d, m)."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):] if len(shape) == 3 \
            else ("data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)
