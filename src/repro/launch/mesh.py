"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init; everything
else sees the real single-device CPU).

Topology model (TPU v5e): one pod = 16×16 = 256 chips; ``multi_pod`` adds a
leading ``pod`` axis across 2 pods (512 chips) connected by DCI. Axis use:

  pod    — outer data parallelism (gradient reduction crosses pods once)
  data   — data parallelism + FSDP parameter sharding (intra-pod ICI)
  model  — tensor/expert parallelism (highest-bandwidth dimension)
"""

from __future__ import annotations

import math
import os
from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "parse_mesh",
           "ensure_host_devices", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...],
              axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary (test-sized) mesh: shape (d, m) or (p, d, m)."""
    if axes is None:
        axes = ("pod", "data", "model")[-len(shape):] if len(shape) == 3 \
            else ("data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)


def parse_mesh(spec: str) -> Tuple[int, ...]:
    """CLI mesh spec ``"DxM"`` (or ``"PxDxM"``) → shape tuple.

    ``"2x4"`` → ``(data=2, model=4)``; ``"2x2x2"`` adds a leading ``pod``
    axis. Every factor must be a positive integer.
    """
    try:
        shape = tuple(int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"bad mesh spec {spec!r}: expected DxM like '2x4'")
    if len(shape) not in (2, 3) or any(s < 1 for s in shape):
        raise ValueError(f"bad mesh spec {spec!r}: expected 2 or 3 positive "
                         "factors (data x model, optionally pod-leading)")
    return shape


def ensure_host_devices(shape) -> None:
    """Request enough XLA host-platform devices for a CPU run.

    ``shape`` is a mesh shape tuple (``parse_mesh`` output) or a bare
    device count. Must be called before jax initializes its backends
    (first device or array op) — XLA locks the device count at first
    init. Appends ``--xla_force_host_platform_device_count`` to
    ``XLA_FLAGS`` unless the flag is already set, so an explicit
    environment always wins; on real accelerator platforms the flag is
    inert.
    """
    n = shape if isinstance(shape, int) else math.prod(shape)
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
