import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-lower + compile every (arch × shape × mesh) cell.

MUST keep the two lines above as the very first statements — jax locks the
device count at first init, and the production meshes need 512 placeholder
host devices. Everything else (tests, benches) sees the real single CPU.

Per cell this produces a JSON artifact with:
  * memory_analysis()  — per-device argument/output/temp/peak bytes,
  * cost_analysis()    — HLO FLOPs + bytes accessed (per device),
  * collective census  — per-op-type per-device buffer bytes parsed from
    the post-SPMD compiled HLO,
  * the three roofline terms (seconds) + dominant term,
  * MODEL_FLOPS (6·N·D train / 2·N·D inference) and the useful-compute
    ratio MODEL_FLOPS / HLO_FLOPs.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
  ... --variant fsdp_off|gather_ce|full_attn|remat_none (hillclimb levers)
"""

import argparse
import dataclasses
import json
import re
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, shape_applicable
from repro.configs.registry import ARCHS, get_config
from repro.core.cost_model import TPU_V5E
from repro.launch import costing
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.models.api import build_model
from repro.parallel import DEFAULT_RULES, activate

__all__ = ["run_cell", "collective_census", "roofline_terms"]

_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s32|s16|s8|u32|u16|u8|pred)"
                       r"\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES.get(dtype, 4)


def collective_census(hlo_text: str) -> Dict[str, int]:
    """Per-device collective buffer bytes by op type, from post-SPMD HLO.

    Counts the *result* buffer of every collective instruction (for
    all-gather the result is the gathered buffer — the bytes that move;
    for reduce-scatter the operand is bigger, but ring bytes-on-wire scale
    with the large buffer either way, so we take max(result, operands)).
    """
    census = {op: 0 for op in _COLLECTIVE_OPS}
    census["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in _COLLECTIVE_OPS:
            # match the instruction itself (" op(" / " op-start("), not the
            # result name (%all-reduce.5) or metadata mentions
            marker = None
            for cand in (f" {op}(", f" {op}-start("):
                if cand in stripped:
                    marker = cand
                    break
            if marker is None:
                continue
            head, tail = stripped.split(marker, 1)
            result_b = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(head))
            operand_b = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(tail.split(
                                ", replica_groups")[0]))
            census[op] += max(result_b, operand_b)
            census["count"] += 1
            break
    census["total_bytes"] = sum(census[o] for o in _COLLECTIVE_OPS)
    return census


def roofline_terms(*, hlo_flops: float, hlo_bytes: float,
                   collective_bytes_per_device: float,
                   spec=TPU_V5E) -> Dict[str, float]:
    compute_s = hlo_flops / spec.peak_bf16_flops
    memory_s = hlo_bytes / spec.hbm_bandwidth
    collective_s = collective_bytes_per_device / spec.ici_link_bandwidth
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    terms["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                            key=lambda k: terms[k])
    terms["bound_s"] = terms[terms["dominant"]]
    return terms


def _model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    n = cfg.active_param_count()
    if shape.phase == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.phase == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def apply_variant(cfg: ModelConfig, variant: str) -> ModelConfig:
    """Hillclimb levers, selectable from the CLI (see
    docs/architecture.md §Perf levers)."""
    if variant == "baseline" or not variant:
        return cfg
    updates: dict = {}
    for item in variant.split("+"):
        if item == "gather_ce":
            updates["loss_impl"] = "gather"
        elif item == "full_attn":
            updates["attn_impl"] = "full"
        elif item == "remat_none":
            updates["remat"] = "none"
        elif item == "remat_dots":
            updates["remat"] = "dots"
        elif item == "kv_int8":
            updates["kv_cache_dtype"] = "int8"
        elif item == "attn_cp":
            updates["attn_cp"] = True
        elif item.startswith("moa="):
            # full repro.moa spec string, e.g. moa=serial?chunk=512
            updates["moa"] = item.split("=", 1)[1]
        elif item.startswith("moa_chunk="):
            # legacy alias for the serialization cluster size
            updates["moa"] = f"serial?chunk={int(item.split('=')[1])}"
        elif item.startswith("kv_chunk="):
            updates["kv_chunk"] = int(item.split("=")[1])
        elif item.startswith("q_chunk="):
            updates["q_chunk"] = int(item.split("=")[1])
        elif item.startswith("ssd_chunk="):
            updates["ssd_chunk"] = int(item.split("=")[1])
        elif item.startswith("capacity="):
            updates["capacity_factor"] = float(item.split("=")[1])
        elif item in ("fsdp_off", "compress_grads", "kv_dim_shard",
                      "seq_shard") or item.startswith("micro="):
            pass  # handled at sharding/step level via run_cell
        else:
            raise ValueError(f"unknown variant item {item!r}")
    return dataclasses.replace(cfg, **updates)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             variant: str = "baseline", fsdp: bool = True,
             compress_grads: bool = False,
             save_hlo: Optional[str] = None) -> dict:
    cfg = apply_variant(get_config(arch), variant)
    shape = SHAPES[shape_name]
    if shape.phase != "train":
        # serving runs on bf16 weights (f32 masters are a training artifact)
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": why}
    if "fsdp_off" in variant:
        fsdp = False
    if "compress_grads" in variant:
        compress_grads = True
    kv_dim_shard = "kv_dim_shard" in variant

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    rules = steps_lib.rules_for(cfg, shape, mesh, DEFAULT_RULES)
    if kv_dim_shard:
        rules = rules.with_overrides(head_dim="model", kv_heads_cache=None,
                                     scale_seq="model")
    if "seq_shard" in variant:
        # Megatron-SP: the residual stream (and saved remat activations)
        # shard their sequence dim over the model axis between blocks
        rules = rules.with_overrides(seq="model")
    t0 = time.monotonic()

    with activate(mesh, rules):
        specs = model.input_specs(shape)
        batch_shardings = steps_lib.batch_specs(specs, mesh, rules)

        if shape.phase == "train":
            micro = 1
            for item in variant.split("+"):
                if item.startswith("micro="):
                    micro = int(item.split("=")[1])
            hyper = steps_lib.TrainHyper(compress_grads=compress_grads,
                                         microbatches=micro)
            state_spec = jax.eval_shape(
                lambda: steps_lib.init_train_state(
                    model, jax.random.PRNGKey(0), hyper=hyper))
            axes = steps_lib.state_axes(state_spec)
            state_shardings = steps_lib.build_shardings(
                state_spec, axes, mesh, rules, fsdp=fsdp)
            step_fn = steps_lib.build_train_step(model, hyper=hyper)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_shardings, batch_shardings),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_spec, specs)
        elif shape.phase == "prefill":
            params_spec = model.abstract_params()
            p_axes = steps_lib.infer_param_axes(params_spec)
            param_shardings = steps_lib.build_shardings(
                params_spec, p_axes, mesh, rules, fsdp=False)
            step_fn = steps_lib.build_prefill_step(model,
                                                   max_len=shape.seq_len)
            jitted = jax.jit(step_fn,
                             in_shardings=(param_shardings, batch_shardings))
            lowered = jitted.lower(params_spec, specs)
        else:  # decode
            params_spec = model.abstract_params()
            p_axes = steps_lib.infer_param_axes(params_spec)
            param_shardings = steps_lib.build_shardings(
                params_spec, p_axes, mesh, rules, fsdp=False)
            cache_spec = specs["cache"]
            cache_shardings = steps_lib.cache_specs(
                {"cache": cache_spec}, mesh, rules)["cache"]
            token_sharding = steps_lib.batch_specs(
                {"tokens": specs["tokens"]}, mesh, rules)["tokens"]
            step_fn = steps_lib.build_decode_step(model)
            jitted = jax.jit(
                step_fn,
                in_shardings=(param_shardings, cache_shardings,
                              token_sharding),
                donate_argnums=(1,))
            lowered = jitted.lower(params_spec, cache_spec, specs["tokens"])

        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    census = collective_census(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    n_chips = mesh.devices.size
    hlo_flops = float(cost.get("flops", 0.0))
    hlo_bytes = float(cost.get("bytes accessed", 0.0))

    # Analytic cost model (primary): XLA cost_analysis counts while bodies
    # once, so scan-over-layers models are loop-undercounted there — see
    # costing.py docstring + tests/test_costing.py for the validation.
    mesh_meta = costing.MeshMeta(
        pod=2 if multi_pod else 1, data=16, model=16, fsdp=fsdp,
        compress_grads=compress_grads, attn_cp=cfg.attn_cp,
        kv_dim_shard=kv_dim_shard)
    cell = costing.estimate_cell(cfg, shape, mesh_meta)
    terms = roofline_terms(hlo_flops=cell.flops, hlo_bytes=cell.hbm_bytes,
                           collective_bytes_per_device=cell.collective_bytes)
    mflops = _model_flops(cfg, shape)
    mflops_per_chip = mflops / n_chips
    result = {
        "arch": arch,
        "shape": shape_name,
        "phase": shape.phase,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "n_chips": n_chips,
        "variant": variant,
        "fsdp": fsdp,
        "skipped": False,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost_raw_hlo": {
            # loop-undercounted: while bodies counted once by XLA
            "flops_per_device": hlo_flops,
            "bytes_per_device": hlo_bytes,
        },
        "cost_analytic": {
            "flops_per_device": cell.flops,
            "hbm_bytes_per_device": cell.hbm_bytes,
            "collective_bytes_per_device": cell.collective_bytes,
            "flops_components_global": cell.components,
            "bytes_components": cell.bytes_components,
            "collective_components": cell.collective_components,
        },
        "collectives_hlo_census": census,
        "roofline": terms,
        "model_flops_total": mflops,
        "model_flops_per_chip": mflops_per_chip,
        "useful_compute_ratio": (mflops_per_chip / cell.flops
                                 if cell.flops else None),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="every valid (arch, shape) cell")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in sorted(ARCHS):
            for shape_name in SHAPES:
                cells.append((arch, shape_name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape_name in cells:
        for multi_pod in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
            if args.variant != "baseline":
                tag += f"__{args.variant.replace('=', '-').replace('+', '_')}"
            try:
                res = run_cell(arch, shape_name, multi_pod=multi_pod,
                               variant=args.variant,
                               save_hlo=args.save_hlo)
            except Exception as e:  # a failed cell is a bug — surface it
                res = {"arch": arch, "shape": shape_name,
                       "mesh": "multi" if multi_pod else "single",
                       "error": f"{type(e).__name__}: {e}", "skipped": False}
                failures += 1
            path = os.path.join(args.out, tag + ".json")
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            status = ("SKIP: " + res["reason"]) if res.get("skipped") else \
                ("ERROR: " + res["error"][:120]) if "error" in res else \
                (f"ok compile={res['compile_s']}s "
                 f"dominant={res['roofline']['dominant']} "
                 f"bound={res['roofline']['bound_s']:.4f}s")
            print(f"[dryrun] {tag}: {status}", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
