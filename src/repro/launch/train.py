"""Fault-tolerant training driver.

Wires together: config registry → model → sharded train step → synthetic
data pipeline → AdamW (+ optional int8 gradient compression) → atomic
async checkpoints → failure injection → restart supervisor → straggler
heartbeats. Runs end-to-end on one CPU device with ``--smoke`` configs and
scales to the production mesh unchanged (the mesh is built from whatever
devices exist).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --batch 8 --seq 128
  ... --fail-at 20 --fail-at 35     # survives two injected node losses
  ... --compress-grads              # int8 all-reduce with error feedback
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, smoke_config
from repro.data import SyntheticLMData
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_mesh
from repro.models.api import build_model
from repro.parallel import DEFAULT_RULES, activate
from repro.runtime import (FailureInjector, HeartbeatMonitor, Supervisor,
                           plan_mesh_shape)

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    """Reusable in-process trainer (the integration tests drive this)."""

    def __init__(self, cfg, *, steps: int, global_batch: int, seq_len: int,
                 ckpt_dir: Optional[str] = None, save_every: int = 10,
                 hyper: Optional[steps_lib.TrainHyper] = None,
                 injector: Optional[FailureInjector] = None,
                 mesh_shape=None, seed: int = 0, log_every: int = 10,
                 async_save: bool = True):
        self.cfg = cfg
        self.steps = steps
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.save_every = save_every
        self.log_every = log_every
        self.async_save = async_save
        self.hyper = hyper or steps_lib.TrainHyper(
            warmup_steps=max(steps // 10, 1), total_steps=steps)
        self.injector = injector or FailureInjector()
        self.monitor = HeartbeatMonitor(n_workers=1)
        self.manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.seed = seed
        self.model = build_model(cfg)
        self.data = SyntheticLMData(
            vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
            seed=seed, family="encoder" if cfg.family == "encoder" else "lm",
            d_model=cfg.d_model, n_patches=cfg.n_patches)
        n_dev = len(jax.devices())
        self.mesh = make_mesh(mesh_shape or plan_mesh_shape(
            n_dev, model_parallel=min(4, n_dev)))
        self.rules = DEFAULT_RULES
        self.metrics_history: list = []

        shape = ShapeSpec("train", seq_len, global_batch, "train")
        with activate(self.mesh, self.rules):
            # one Trainer per process (not per request, unlike serve
            # engines), so a per-instance jit is deliberate here
            # audit: allow(lint-jit-in-init)
            self._step_fn = jax.jit(
                steps_lib.build_train_step(self.model, hyper=self.hyper),
                donate_argnums=(0,))

    # -- state management ----------------------------------------------------
    def fresh_state(self):
        with activate(self.mesh, self.rules):
            return steps_lib.init_train_state(
                self.model, jax.random.PRNGKey(self.seed), hyper=self.hyper)

    def restore_state(self, step: int):
        template = jax.eval_shape(self.fresh_state)
        state, _ = self.manager.restore(template, step=step)
        return state

    # -- loop ------------------------------------------------------------------
    def run_segment(self, start_step: int, state):
        """Run from ``start_step`` to completion (may raise SimulatedFailure)."""
        if state is None:
            state = self.fresh_state()
        with activate(self.mesh, self.rules):
            for step in range(start_step, self.steps):
                t0 = time.monotonic()
                batch = self.data.batch_for_step(step)
                state, metrics = self._step_fn(state, batch)
                # failure window: after compute, before checkpoint — the
                # hardest point to get restart-exactness right
                self.injector.maybe_fail(step)
                dt = time.monotonic() - t0
                self.monitor.beat(0, step, dt)
                if step % self.log_every == 0 or step == self.steps - 1:
                    loss = float(metrics["loss"])
                    self.metrics_history.append(
                        {"step": step, "loss": loss, "dt": dt})
                    print(f"[train] step={step} loss={loss:.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f} "
                          f"dt={dt*1e3:.0f}ms", flush=True)
                if self.manager and (step + 1) % self.save_every == 0:
                    save = (self.manager.save_async if self.async_save
                            else self.manager.save)
                    save(step, state, metadata={"loss": float(
                        metrics["loss"])})
        if self.manager:
            self.manager.wait()
            self.manager.save(self.steps - 1, state)
        return state

    def run(self, *, max_restarts: int = 3):
        if self.manager is None:
            return self.run_segment(0, None), None
        sup = Supervisor(self.manager, max_restarts=max_restarts)
        result = sup.run(self.run_segment, restore_fn=self.restore_state)
        return result.final_state, result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, action="append", default=[])
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    hyper = steps_lib.TrainHyper(
        peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps, compress_grads=args.compress_grads)
    loop = TrainLoop(cfg, steps=args.steps, global_batch=args.batch,
                     seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                     save_every=args.save_every, hyper=hyper,
                     injector=FailureInjector(args.fail_at), seed=args.seed)
    state, result = loop.run()
    if result is not None:
        print(f"[train] done: restarts={result.restarts} "
              f"completed={result.completed} wall={result.wall_time_s:.1f}s")
    losses = [m["loss"] for m in loop.metrics_history]
    if len(losses) >= 2:
        print(f"[train] loss {losses[0]:.4f} → {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
