"""Pallas TPU kernel: paged flash-attention over block-table KV pools.

The serving hot path's gather-based reference (`layers/attention.py:
gather_paged_kv`) materializes a dense ``(B, max_blocks·block_size, Hk, D)``
KV view on every decode step — the full padded cache streams through HBM
regardless of how deep each sequence actually is. This kernel walks each
sequence's **block table inside the grid** instead: the table row and the
per-slot first-query position are scalar-prefetched (SMEM), and the K/V
BlockSpec index maps translate logical KV block ``j`` to its physical page
``tables[b, j]`` on the fly. Pages past a slot's cursor are redirected to
physical page 0 (the engine's write-trash page); Pallas elides the re-fetch
when consecutive grid steps map to the same block, so dead pages cost
neither bandwidth nor compute (the compute body is ``pl.when``-guarded).

Softmax·V is scheduled exactly like ``flash_attention.py`` — the paper's
serialized MOA with a renormalizable (m, l, acc) carry in the output refs
across the sequential trailing grid dimension — so per-slot depth masking
falls out of the causal mask: a fully-dead page contributes an exact f32
zero and never perturbs the running max.

For **int8 pools** the per-(pos, head) ``k_scale``/``v_scale`` leaves ride
along as two more paged inputs and dequantization happens in-register on
the VMEM tile — the bf16/f32 KV view the jnp path materializes in HBM never
exists here (the reconfigurable-MOA move: pick the accumulation path per
operand width at the kernel boundary).

Grid: ``(B, Hk, n_blocks)`` with the page walk sequential; per-step VMEM
working set is one ``(T·G + 2·block_size) × head_dim`` tile plus the
``T·G × block_size`` score tile (both f32) — independent of table width.
Query layout inside the kernel is ``(B, Hk, T, G, D)`` so the GQA group
axis stays packed next to the head it shares KV with.

One kernel covers both serve phases: decode is the ``T = 1`` instance
(``start`` = each slot's cursor) and the bucketed/chunked suffix-prefill /
speculative-verify path is the ``T = window`` instance (queries are always
a contiguous window starting at the cursor, so positions never need to be
shipped — only the ``(B,)`` start vector).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention_pallas", "paged_flash_decode",
           "paged_flash_prefill"]

_NEG_INF = -1e30


def _paged_kernel(tables_ref, start_ref, q_ref, k_ref, v_ref, *rest,
                  block_size, n_tokens, sm_scale, quantized, dequant_dtype):
    if quantized:
        k_scale_ref, v_scale_ref, o_ref, m_ref, l_ref = rest
    else:
        o_ref, m_ref, l_ref = rest
    del tables_ref  # consumed by the BlockSpec index maps
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    start = start_ref[b]
    # logical block j holds KV positions [j·bs, j·bs + bs); the deepest
    # query sits at start + T - 1, so later blocks are fully causal-masked
    live = j * block_size <= start + n_tokens - 1

    @pl.when(live)
    def _fold():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # (T, G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quantized:
            # round through the gather path's materialization dtype so the
            # in-register dequant sees the exact values gather_paged_kv
            # would have written to HBM — greedy parity needs the logits to
            # differ only by online-softmax reassociation
            k = (k * k_scale_ref[0, :, 0][:, None]) \
                .astype(dequant_dtype).astype(jnp.float32)
            v = (v * v_scale_ref[0, :, 0][:, None]) \
                .astype(dequant_dtype).astype(jnp.float32)
        T, G, D = q.shape
        bs = block_size
        s = (q.reshape(T * G, D) @ k.T).reshape(T, G, bs)

        q_pos = start + jax.lax.broadcasted_iota(jnp.int32, (T, bs), 0)
        kv_pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (T, bs), 1)
        mask = kv_pos <= q_pos          # causal = per-slot kv_len cutoff
        s = jnp.where(mask[:, None, :], s, _NEG_INF)

        m_prev = m_ref[0, 0]                                 # (T, G)
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = o_ref[0, 0] * corr[..., None] \
            + (p.reshape(T * G, bs) @ v).reshape(T, G, D)
        m_ref[0, 0] = m_new
        l_ref[0, 0] = l_new
        o_ref[0, 0] = acc

    # the last page may be dead for shallow slots, so normalization reads
    # the carried (acc, l) from the refs rather than registers
    @pl.when(j == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0, 0] = o_ref[0, 0] \
            / jnp.maximum(l_ref[0, 0], 1e-30)[..., None]


def paged_attention_pallas(q, k_pool, v_pool, block_tables, start, *,
                           k_scale=None, v_scale=None,
                           dequant_dtype=jnp.bfloat16,
                           interpret: bool = False):
    """q: (B, T, H, D); pools: (n_phys, bs, Hk, D); tables: (B, n_blocks)
    int32; start: (B,) first query position per slot → (B, T, H, D).

    ``T`` is static (1 for decode, the window for suffix-prefill/verify);
    slot ``b``'s queries sit at positions ``start[b] .. start[b]+T-1`` and
    attend the pool causally at those positions. ``k_scale``/``v_scale``
    (``(n_phys, bs, Hk)`` f32) switch on the fused int8 dequant path;
    ``dequant_dtype`` is the dtype the gather reference materializes its
    dequantized view in (the in-register values round through it so both
    paths see bit-equal KV). Callers bound the page walk by slicing
    ``block_tables`` to the live high-water width before the call.
    """
    B, T, H, D = q.shape
    n_phys, bs, Hk, _ = k_pool.shape
    G = H // Hk
    n_blocks = block_tables.shape[1]
    sm_scale = D ** -0.5
    quantized = k_scale is not None

    qg = jnp.moveaxis(q.reshape(B, T, Hk, G, D), 1, 2)   # (B, Hk, T, G, D)
    tables = block_tables.astype(jnp.int32)
    start = start.astype(jnp.int32)

    def phys(b, j, tables_ref, start_ref):
        # dead pages all redirect to the trash page so the pipeline fetches
        # it once and elides every repeat
        live = j * bs <= start_ref[b] + T - 1
        return jnp.where(live, tables_ref[b, j], 0)

    def q_map(b, h, j, tables_ref, start_ref):
        return (b, h, 0, 0, 0)

    def kv_map(b, h, j, tables_ref, start_ref):
        return (phys(b, j, tables_ref, start_ref), 0, h, 0)

    def scale_map(b, h, j, tables_ref, start_ref):
        return (phys(b, j, tables_ref, start_ref), 0, h)

    def ml_map(b, h, j, tables_ref, start_ref):
        return (b, h, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, T, G, D), q_map),
        pl.BlockSpec((1, bs, 1, D), kv_map),
        pl.BlockSpec((1, bs, 1, D), kv_map),
    ]
    inputs = [qg, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, bs, 1), scale_map),
                     pl.BlockSpec((1, bs, 1), scale_map)]
        inputs += [k_scale, v_scale]

    out, _, _ = pl.pallas_call(
        functools.partial(_paged_kernel, block_size=bs, n_tokens=T,
                          sm_scale=sm_scale, quantized=quantized,
                          dequant_dtype=dequant_dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hk, n_blocks),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, 1, T, G, D), q_map),
                pl.BlockSpec((1, 1, T, G), ml_map),
                pl.BlockSpec((1, 1, T, G), ml_map),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, Hk, T, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hk, T, G), jnp.float32),
            jax.ShapeDtypeStruct((B, Hk, T, G), jnp.float32),
        ],
        interpret=interpret,
    )(tables, start, *inputs)
    return jnp.moveaxis(out, 2, 1).reshape(B, T, H, D).astype(q.dtype)


def paged_flash_decode(q, k_pool, v_pool, block_tables, pos, *,
                       k_scale=None, v_scale=None,
                       dequant_dtype=jnp.bfloat16, interpret: bool = False):
    """Decode instance: one query per slot at its cursor ``pos (B,)``."""
    if q.shape[1] != 1:
        raise ValueError(f"decode expects T=1 queries, got {q.shape}")
    return paged_attention_pallas(q, k_pool, v_pool, block_tables, pos,
                                  k_scale=k_scale, v_scale=v_scale,
                                  dequant_dtype=dequant_dtype,
                                  interpret=interpret)


def paged_flash_prefill(q, k_pool, v_pool, block_tables, start, *,
                        k_scale=None, v_scale=None,
                        dequant_dtype=jnp.bfloat16, interpret: bool = False):
    """Suffix-prefill / verify instance: a T-token contiguous window per
    slot starting at ``start (B,)`` (the slot's cursor)."""
    return paged_attention_pallas(q, k_pool, v_pool, block_tables, start,
                                  k_scale=k_scale, v_scale=v_scale,
                                  dequant_dtype=dequant_dtype,
                                  interpret=interpret)
