"""Pallas TPU kernel: flash-attention forward — §3.1 applied to softmax·V.

The attention output for one query is a softmax-weighted MOA over up to
524 288 value operands. This kernel schedules it exactly like the paper's
serialized MOA, with the extra subtlety that softmax needs *renormalizable*
partial sums: the running (max m, denominator l, accumulator acc) triple is
carried across KV blocks in the output refs (the trailing grid dimension is
sequential on TPU), and the accumulator is rescaled by ``exp(m_old−m_new)``
at each fold — an MOA whose "carry" is a scaling factor instead of a bit.

Grid: ``(B·H, q_blocks, kv_blocks)``; per-step VMEM working set is
``(block_q + 2·block_k) × head_dim + block_q × block_k`` floats — the
paper's cluster size ``n_c`` is ``block_k``. Layout: q/k/v arrive as
``(BH, S, D)`` (GQA broadcast done by the wrapper). Under the causal mask,
KV blocks strictly above the diagonal are skipped via ``pl.when`` rather
than computed-and-masked — halving score FLOPs at long prefill
(the ``benchmarks/roofline.py`` prefill compute lever), bit-identically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  block_q, block_k, sm_scale, causal, kv_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _fold():
        q = q_ref[0].astype(jnp.float32) * sm_scale      # (bq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0].astype(jnp.float32)                 # (bk, D)
        s = q @ k.T                                      # (bq, bk)

        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kv_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kv_pos < kv_len
        if causal:
            mask &= kv_pos <= q_pos
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[0]                                # (bq,)
        l_prev = l_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        m_ref[0] = m_new
        l_ref[0] = l_new
        o_ref[0] = o_ref[0] * corr[:, None] + p @ v

    if causal:
        # Skip KV blocks strictly above the causal diagonal
        # (ki·block_k > qi·block_q + block_q − 1): every position in such a
        # block is masked, so it would contribute an exact f32 zero without
        # moving the running max — eliding it is bit-identical and saves
        # the score matmul (the roofline's prefill compute lever).
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(_fold)
    else:
        _fold()

    # the last KV block may sit above the diagonal for early q blocks, so
    # normalization reads the carried (acc, l) from the refs
    @pl.when(ki == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-30)[:, None]


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (BH, Sq, D); k, v: (BH, Skv, D) → (BH, Sq, D).

    Carries the accumulator in f32 through the output ref (the MXU-style
    hard accumulation the paper's conclusion asks for); m/l side outputs
    are discarded after the final normalization step.
    """
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    sm_scale = D ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = -Sq % block_q
    pad_k = -Skv % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    grid = (BH, Sq_p // block_q, Skv_p // block_k)

    out, _, _ = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          sm_scale=sm_scale, causal=causal, kv_len=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq_p, D), jnp.float32),
            jax.ShapeDtypeStruct((BH, Sq_p), jnp.float32),
            jax.ShapeDtypeStruct((BH, Sq_p), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq].astype(q.dtype)
