"""Pallas TPU kernels for the paper's compute hot-spots (MOA scheduling).

Layout (per the framework convention):
  * ``moa_reduce.py`` / ``loa_add.py`` / ``dot_moa.py`` — ``pl.pallas_call``
    bodies with explicit BlockSpec VMEM tiling (TPU target);
  * ``ops.py``  — jitted public wrappers (auto-interpret on CPU);
  * ``ref.py``  — pure-jnp oracles used by the test sweeps.
"""

from repro.kernels.ops import (moa_reduce, loa_add, loa_reduce, dot_moa,
                               flash_attention)

__all__ = ["moa_reduce", "loa_add", "loa_reduce", "dot_moa",
           "flash_attention"]
