"""Pallas TPU kernels: Lower-part-OR approximate addition (§3.2, Fig. 3).

Two kernels:

  * ``loa_add_pallas`` — element-wise LOA over int32 containers. The kernel
    body is the *gate-level* structure of Fig. 3 expressed in VPU ops:
    mask/OR for the low part, AND for the carry, hard add for the high part.
    Counting the ops in this body is itself the TPU negative result: ~6
    integer VPU ops replace the single hard-wired add — approximation costs
    6×, the exact analogue of the flat-ALM finding (the hard adder is free;
    you cannot undercut silicon with logic).

  * ``loa_reduce_pallas`` — the approximate *serialized* MOA: operand blocks
    stream through the grid (§3.1), each block is tree-reduced exactly, and
    the running accumulator is folded through an LOA addition (§3.2). This
    is the faithful composition of both of the paper's strategies on TPU.

Integer only (the paper's operands are 8-bit); containers are int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["loa_add_pallas", "loa_reduce_pallas"]


def _loa_combine(x, y, *, approx_bits: int):
    """Gate-level LOA on int32 vectors (Fig. 3): OR-low, AND-carry, add-high."""
    if approx_bits == 0:
        return x + y
    l = approx_bits
    mask_l = jnp.int32((1 << l) - 1)
    low = (x & mask_l) | (y & mask_l)                     # 3 VPU ops
    cin = ((x >> (l - 1)) & (y >> (l - 1))) & jnp.int32(1)  # 3 VPU ops (shifts fuse)
    high = (x >> l) + (y >> l) + cin                      # the hard adds
    return (high << l) | low                              # 2 VPU ops


def _loa_add_kernel(x_ref, y_ref, o_ref, *, approx_bits):
    o_ref[...] = _loa_combine(x_ref[...], y_ref[...], approx_bits=approx_bits)


def loa_add_pallas(x: jax.Array, y: jax.Array, *, approx_bits: int,
                   width: int = 8, block: int = 1024,
                   interpret: bool = False) -> jax.Array:
    """Element-wise LOA addition of flat or 2-D int arrays."""
    del width  # semantic width is carried by the operand values themselves
    if x.shape != y.shape:
        raise ValueError(f"shape mismatch {x.shape} vs {y.shape}")
    orig_shape = x.shape
    x = x.reshape(-1).astype(jnp.int32)
    y = y.reshape(-1).astype(jnp.int32)
    n = x.shape[0]
    block = min(block, max(n, 1))
    pad = -n % block
    if pad:
        x = jnp.pad(x, (0, pad))
        y = jnp.pad(y, (0, pad))
    grid = (x.shape[0] // block,)
    out = pl.pallas_call(
        functools.partial(_loa_add_kernel, approx_bits=approx_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0],), jnp.int32),
        interpret=interpret,
    )(x, y)
    return out[:n].reshape(orig_shape)


def _loa_reduce_kernel(x_ref, o_ref, *, approx_bits):
    k = pl.program_id(1)
    block_sum = jnp.sum(x_ref[...].astype(jnp.int32), axis=0)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = block_sum

    @pl.when(k != 0)
    def _accum():
        o_ref[...] = _loa_combine(o_ref[...], block_sum, approx_bits=approx_bits)


def loa_reduce_pallas(x: jax.Array, *, approx_bits: int, width: int = 8,
                      block_n: int = 256, block_f: int = 256,
                      interpret: bool = False) -> jax.Array:
    """Approximate serialized MOA: ``(n, f) -> (f,)`` int32.

    ``n`` must be a multiple of ``block_n`` (the oracle
    :func:`repro.kernels.ref.loa_reduce_ref` shares this contract — LOA
    addition is not exact under zero-padding of the *accumulator chain*,
    so ragged tails are the caller's responsibility).
    """
    del width
    n, f = x.shape
    block_n = min(block_n, n)
    if n % block_n:
        raise ValueError(f"n={n} not a multiple of block_n={block_n}")
    block_f = min(block_f, f)
    f_pad = -f % block_f
    if f_pad:
        x = jnp.pad(x, ((0, 0), (0, f_pad)))
    f_p = x.shape[1]
    grid = (f_p // block_f, n // block_n)
    out = pl.pallas_call(
        functools.partial(_loa_reduce_kernel, approx_bits=approx_bits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, block_f), lambda i, k: (k, i))],
        out_specs=pl.BlockSpec((block_f,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((f_p,), jnp.int32),
        interpret=interpret,
    )(x.astype(jnp.int32))
    return out[:f]
