"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the kernel allclose sweeps in
``tests/test_kernels.py``. They intentionally reuse :mod:`repro.core` (the
LOA bitwise semantics live in exactly one place).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import loa as loa_lib

__all__ = ["moa_reduce_ref", "loa_add_ref", "loa_reduce_ref", "dot_moa_ref",
           "flash_attention_ref"]


def moa_reduce_ref(x, *, accum_dtype=jnp.float32):
    """Sum over axis 0 with ``accum_dtype`` accumulation: ``(n, f) -> (f,)``."""
    return jnp.sum(x.astype(accum_dtype), axis=0)


def loa_add_ref(x, y, *, approx_bits: int, width: int):
    """Element-wise Lower-part-OR approximate addition (int32 containers)."""
    return loa_lib.loa_add(x, y, approx_bits=approx_bits, width=width)


def loa_reduce_ref(x, *, approx_bits: int, width: int, block_n: int):
    """Serialized LOA MOA over axis 0: ``(n, f) -> (f,)``.

    Semantics mirror the kernel exactly: within each block of ``block_n``
    operands the sum is *exact* (the MXU/VPU hard adders — free), and each
    block partial is folded into the running accumulator through one LOA
    addition (the approximate serial accumulator of §3.1 + §3.2 combined).
    ``n`` must be a multiple of ``block_n``.
    """
    n, f = x.shape
    assert n % block_n == 0, (n, block_n)
    x = x.astype(jnp.int32).reshape(n // block_n, block_n, f)
    partials = jnp.sum(x, axis=1)
    acc = partials[0]
    for i in range(1, partials.shape[0]):
        acc = loa_lib.loa_add(acc, partials[i], approx_bits=approx_bits, width=width)
    return acc


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """One-shot softmax attention oracle: q/k/v ``(BH, S, D)``."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        Sq, Skv = q.shape[1], k.shape[1]
        mask = jnp.arange(Skv)[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def dot_moa_ref(a, b, *, accum_dtype=jnp.float32, out_dtype=None):
    """Plain matmul with explicit accumulation dtype: ``(m,k) @ (k,n)``."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(a, b, preferred_element_type=accum_dtype).astype(out_dtype)
