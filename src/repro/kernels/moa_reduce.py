"""Pallas TPU kernel: blocked Multi-Operand-Adder reduction.

``moa_reduce`` sums ``(n, f) -> (f,)`` — the paper's MOA with ``n`` operands,
scheduled the TPU-native way:

  * the *operand* axis is **serialized** over the grid (the §3.1 strategy):
    the last grid dimension walks operand blocks sequentially, carrying an
    ``accum_dtype`` accumulator in the output VMEM block. The "serializer"
    is the BlockSpec index_map + DMA pipeline — hard-wired, zero "fabric";
  * *within* a block the reduction is a spatial tree (`jnp.sum` lowers to
    the VPU's hard adder tree) — the §2 baseline.

So one kernel exhibits both of the paper's structures, with the serial/
spatial split set by ``block_n`` — the TPU incarnation of the paper's
cluster size ``n_c``.

Grid: ``(f_blocks, n_blocks)``; on TPU the trailing grid dim is sequential,
which makes the read-modify-write on the output block well-defined (the
canonical Pallas accumulation pattern). VMEM working set per step:
``block_n × block_f × itemsize`` — chosen so MXU/VPU-aligned tiles
(multiples of 8×128) fit comfortably in the 128 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["moa_reduce_pallas"]


def _moa_reduce_kernel(x_ref, o_ref, *, accum_dtype):
    """One (block_n, block_f) tile: tree-reduce, then serial-accumulate."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    block_sum = jnp.sum(x_ref[...].astype(accum_dtype), axis=0)
    o_ref[...] += block_sum.astype(o_ref.dtype)


def moa_reduce_pallas(x: jax.Array, *, block_n: int = 512, block_f: int = 256,
                      accum_dtype=jnp.float32, interpret: bool = False) -> jax.Array:
    """Sum ``x`` of shape ``(n, f)`` over axis 0.

    ``n`` and ``f`` are padded up to block multiples (zero padding — exact
    for addition).
    """
    n, f = x.shape
    block_n = min(block_n, max(n, 1))
    block_f = min(block_f, max(f, 1))
    n_pad = -n % block_n
    f_pad = -f % block_f
    if n_pad or f_pad:
        x = jnp.pad(x, ((0, n_pad), (0, f_pad)))
    n_p, f_p = x.shape

    out_dtype = accum_dtype if jnp.issubdtype(x.dtype, jnp.floating) else jnp.int32
    grid = (f_p // block_f, n_p // block_n)
    out = pl.pallas_call(
        functools.partial(_moa_reduce_kernel, accum_dtype=accum_dtype
                          if jnp.issubdtype(x.dtype, jnp.floating) else jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_f), lambda i, k: (k, i)),
        ],
        out_specs=pl.BlockSpec((block_f,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((f_p,), out_dtype),
        interpret=interpret,
    )(x)
    return out[:f]
