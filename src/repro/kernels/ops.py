"""Jitted public wrappers around the Pallas kernels.

``interpret`` is resolved automatically: on CPU backends the kernels run in
interpret mode (Python evaluation of the kernel body — correctness path);
on TPU they compile to Mosaic. Call sites never pass ``interpret``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import dot_moa as _dot_moa
from repro.kernels import flash_attention as _flash
from repro.kernels import loa_add as _loa_add
from repro.kernels import moa_reduce as _moa_reduce
from repro.kernels import paged_attention as _paged

__all__ = ["moa_reduce", "loa_add", "loa_reduce", "dot_moa",
           "flash_attention", "paged_attention"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_n", "block_f"))
def moa_reduce(x, *, block_n: int = 512, block_f: int = 256):
    """Blocked MOA reduction ``(n, f) -> (f,)`` (f32 accumulate)."""
    return _moa_reduce.moa_reduce_pallas(
        x, block_n=block_n, block_f=block_f, interpret=_interpret()
    )


@partial(jax.jit, static_argnames=("approx_bits", "width", "block"))
def loa_add(x, y, *, approx_bits: int, width: int = 8, block: int = 1024):
    """Element-wise LOA approximate addition (int32)."""
    return _loa_add.loa_add_pallas(
        x, y, approx_bits=approx_bits, width=width, block=block,
        interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("approx_bits", "width", "block_n", "block_f"))
def loa_reduce(x, *, approx_bits: int, width: int = 8, block_n: int = 256,
               block_f: int = 256):
    """Approximate serialized MOA ``(n, f) -> (f,)`` (int32)."""
    return _loa_add.loa_reduce_pallas(
        x, approx_bits=approx_bits, width=width, block_n=block_n,
        block_f=block_f, interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """Flash-attention forward ``(BH, S, D)`` (serialized softmax MOA)."""
    return _flash.flash_attention_pallas(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("dequant_dtype",))
def paged_attention(q, k_pool, v_pool, block_tables, start, *,
                    k_scale=None, v_scale=None, dequant_dtype=jnp.bfloat16):
    """Paged flash attention ``(B, T, H, D)`` over a block-table KV pool
    (fused int8 dequant when the scale leaves are given; the in-register
    values round through ``dequant_dtype`` — the gather reference's
    materialization dtype — so both backends see bit-equal KV)."""
    return _paged.paged_attention_pallas(
        q, k_pool, v_pool, block_tables, start,
        k_scale=k_scale, v_scale=v_scale, dequant_dtype=dequant_dtype,
        interpret=_interpret(),
    )


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                   "approx_bits", "out_dtype"))
def dot_moa(a, b, *, block_m: int = 256, block_n: int = 256,
            block_k: int = 512, approx_bits: int = 0, out_dtype=None):
    """K-blocked matmul with serialized-MOA contraction."""
    return _dot_moa.dot_moa_pallas(
        a, b, block_m=block_m, block_n=block_n, block_k=block_k,
        approx_bits=approx_bits, out_dtype=out_dtype, interpret=_interpret(),
    )
