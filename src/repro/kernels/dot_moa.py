"""Pallas TPU kernel: K-blocked matmul with a serialized-MOA contraction.

The contraction (K) dimension of ``(m, k) @ (k, n)`` is the MOA of every
dense layer. This kernel schedules it the way the paper's §3.1 *wanted* to
— time-multiplexed into an accumulator — on hardware where that actually
wins because serializer (DMA) and accumulator (MXU f32) are hard-wired:

  grid = (m_blocks, n_blocks, k_blocks); the trailing K dimension is
  sequential on TPU, each step issuing one ``block_m × block_k`` ×
  ``block_k × block_n`` MXU contraction accumulated into the f32 output
  block held in VMEM.

Variants:
  * float (f32/bf16 in, f32 accumulate — the MXU's hard-wired behaviour);
  * int8 (int8 in, int32 accumulate — the paper's 8-bit operand regime);
  * int8 + LOA accumulator (``approx_bits > 0``): every fold of a K-block
    partial into the accumulator goes through the Lower-part-OR adder —
    the §3.2 approximate MOA, measurably *not cheaper* (see
    benchmarks/fig5_loa.py): the LOA fold costs ~6 VPU ops where the exact
    fold is a single hard add the MXU gives away for free.

Block sizes default to MXU-aligned (multiples of 128 on the matmul dims);
VMEM per step = (block_m·block_k + block_k·block_n + block_m·block_n)·4 B —
512³ blocks ≈ 3 MiB, far under the 128 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["dot_moa_pallas"]


def _loa_combine(x, y, *, approx_bits: int):
    if approx_bits == 0:
        return x + y
    l = approx_bits
    mask_l = jnp.int32((1 << l) - 1)
    low = (x & mask_l) | (y & mask_l)
    cin = ((x >> (l - 1)) & (y >> (l - 1))) & jnp.int32(1)
    high = (x >> l) + (y >> l) + cin
    return (high << l) | low


def _dot_moa_kernel(a_ref, b_ref, o_ref, *, accum_dtype, approx_bits):
    k = pl.program_id(2)
    partial = jnp.dot(
        a_ref[...].astype(accum_dtype),
        b_ref[...].astype(accum_dtype),
        preferred_element_type=accum_dtype,
    )

    @pl.when(k == 0)
    def _init():
        o_ref[...] = partial.astype(o_ref.dtype)

    @pl.when(k != 0)
    def _accum():
        if approx_bits > 0:
            o_ref[...] = _loa_combine(
                o_ref[...], partial.astype(o_ref.dtype), approx_bits=approx_bits
            )
        else:
            o_ref[...] += partial.astype(o_ref.dtype)


def dot_moa_pallas(a: jax.Array, b: jax.Array, *, block_m: int = 256,
                   block_n: int = 256, block_k: int = 512,
                   approx_bits: int = 0, out_dtype=None,
                   interpret: bool = False) -> jax.Array:
    """``a @ b`` with serialized-MOA contraction.

    Args:
      a: ``(m, k)``; b: ``(k, n)``. Floats accumulate in f32, ints in int32.
      block_k: the cluster size ``n_c`` — how many operands fold per
        sequential step.
      approx_bits: LOA ``l`` for the accumulator folds (int paths only).
    """
    (m, k), (k2, n) = a.shape, b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {a.shape} @ {b.shape}")
    is_int = jnp.issubdtype(a.dtype, jnp.integer)
    if approx_bits and not is_int:
        raise TypeError("LOA accumulation requires integer operands")
    accum_dtype = jnp.int32 if is_int else jnp.float32
    out_dtype = out_dtype or (jnp.int32 if is_int else a.dtype)

    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    pad_m, pad_n, pad_k = -m % block_m, -n % block_n, -k % block_k
    if approx_bits and pad_k:
        # Zero-padding inserts exact-zero folds into the approximate
        # accumulator chain, which would change LOA semantics vs the oracle.
        raise ValueError(f"k={k} must be a multiple of block_k={block_k} for LOA")
    if pad_m or pad_k:
        a = jnp.pad(a, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        b = jnp.pad(b, ((0, pad_k), (0, pad_n)))
    m_p, k_p = a.shape
    _, n_p = b.shape

    grid = (m_p // block_m, n_p // block_n, k_p // block_k)
    out = pl.pallas_call(
        functools.partial(
            _dot_moa_kernel, accum_dtype=accum_dtype, approx_bits=approx_bits
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_p, n_p), accum_dtype),
        interpret=interpret,
    )(a, b)
    return out[:m, :n].astype(out_dtype)
