"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block.

Structure (arXiv:2411.15242, simplified as documented in
docs/architecture.md):
``n_layers`` Mamba-2 blocks; after every ``attn_every`` of them the single
shared (attention + SwiGLU) block is applied, with small *per-application*
input norms (stand-in for Zamba2's per-invocation LoRA). Weight sharing
keeps parameter count at 1.2B-class while giving the hybrid periodic global
mixing.

The shared block's KV caches (one per application point) are the only
sequence-length-proportional state — they, not the SSM states, dominate the
long_500k memory roofline term.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.layers import attention as attn_lib
from repro.layers.common import Params, init_rms_norm, rms_norm
from repro.layers.embedding import embed, init_embedding, unembed
from repro.layers.mlp import init_swiglu, swiglu
from repro.layers.ssd import (init_mamba2_block, init_ssm_state,
                              mamba2_decode, mamba2_forward)
from repro.models import mamba2 as mamba_lm
from repro.models import transformer as dense
from repro.models import verify_common
from repro.parallel import constrain

__all__ = ["init_params", "forward", "init_cache", "init_paged_cache",
           "prefill", "prefill_chunk", "decode_step", "paged_decode_step",
           "verify_step", "paged_verify_step", "commit_verified",
           "n_applications"]


#: Static-auditor registration (:mod:`repro.analysis.targets`): the serve
#: callables this family module exposes, its KV stack key (None = no KV),
#: and whether the paged layout / suffix prefill apply. The auditor
#: enumerates targets from this table, so a family module that grows a new
#: serve entry point must declare it here to be covered by CI.
SERVE_AUDIT = {
    "phases": ("prefill", "decode", "verify", "commit"),
    "paged": True,
    "kv_key": "kv",
    "suffix_prefill": False,
    "prefill_chunk": True,
}


def n_applications(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_every


def _grouped(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_apps, per_group, tail) — layers split into uniform groups + tail."""
    n_apps = n_applications(cfg)
    per_group = cfg.attn_every
    tail = cfg.n_layers - n_apps * per_group
    return n_apps, per_group, tail


def init_params(rng, cfg: ModelConfig) -> Params:
    ke, kl, ka, km, kn = jax.random.split(rng, 5)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: mamba_lm._init_layer(k, cfg))(layer_keys)
    n_apps = n_applications(cfg)
    app_norm_keys = jax.random.split(kn, n_apps)
    app_norms = jax.vmap(
        lambda k: {"attn": init_rms_norm(cfg.d_model, cfg.pdtype),
                   "mlp": init_rms_norm(cfg.d_model, cfg.pdtype)}
    )(app_norm_keys)
    return {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model,
                                tie=cfg.tie_embeddings, dtype=cfg.pdtype),
        "layers": layers,
        "shared_attn": attn_lib.init_attention(
            ka, d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            dtype=cfg.pdtype),
        "shared_mlp": init_swiglu(km, cfg.d_model, cfg.d_ff, cfg.pdtype),
        "app_norms": app_norms,
        "final_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
    }


def _split_layers(params: Params, cfg: ModelConfig):
    """Stacked (L, ...) mamba params → ((n_apps, per_group, ...), tail)."""
    n_apps, per_group, tail = _grouped(cfg)
    head = jax.tree.map(
        lambda a: a[: n_apps * per_group].reshape(
            (n_apps, per_group) + a.shape[1:]), params["layers"])
    tail_p = jax.tree.map(lambda a: a[n_apps * per_group:], params["layers"]) \
        if tail else None
    return head, tail_p


def _shared_block(params: Params, app_norm: Params, h, *, cfg: ModelConfig,
                  positions):
    hn = rms_norm(app_norm["attn"], h)
    a = attn_lib.attention_forward(
        params["shared_attn"], hn, positions=positions, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, causal=True,
        rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk, impl=cfg.attn_impl, compute_dtype=cfg.cdtype,
        context_parallel=cfg.attn_cp, strategy=cfg.moa_for("attention"))
    h = h + constrain(a, "batch", "seq", "embed")
    hn = rms_norm(app_norm["mlp"], h)
    m = swiglu(params["shared_mlp"], hn, strategy=cfg.moa_for("mlp"),
               compute_dtype=cfg.cdtype)
    return h + constrain(m, "batch", "seq", "embed")


def forward(params: Params, batch: dict, cfg: ModelConfig):
    h = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", "seq", "embed")
    positions = jnp.arange(h.shape[1])
    head, tail_p = _split_layers(params, cfg)

    def mamba_body(carry, layer):
        out, _ = mamba_lm._layer_fwd(layer, carry, cfg=cfg)
        return out, None

    def group_body(carry, xs):
        group_layers, app_norm = xs
        out, _ = lax.scan(dense._remat(mamba_body, cfg), carry, group_layers)
        out = _shared_block(params, app_norm, out, cfg=cfg,
                            positions=positions)
        return out, None

    h, _ = lax.scan(group_body, h, (head, params["app_norms"]))
    if tail_p is not None:
        h, _ = lax.scan(dense._remat(mamba_body, cfg), h, tail_p)
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return constrain(logits, "batch", "seq", "vocab")


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    n_apps = n_applications(cfg)
    ssm_one = init_ssm_state(batch, d_model=cfg.d_model, d_state=cfg.d_state,
                             headdim=cfg.headdim, n_groups=cfg.n_groups,
                             d_conv=cfg.d_conv, expand=cfg.expand)
    kv_one = attn_lib.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                    cfg.head_dim, dtype=cfg.cdtype)
    return {
        "ssm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), ssm_one),
        "kv": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_apps,) + a.shape), kv_one),
        "pos": jnp.zeros((), jnp.int32),
    }


def init_paged_cache(cfg: ModelConfig, n_slots: int, n_phys_blocks: int,
                     block_size: int, max_blocks: int) -> Params:
    """Paged hybrid state: the shared block's KV (the only sequence-
    proportional state) moves into a physical page pool per application
    point; the SSM states stay dense per slot — they are O(1) in sequence
    length, so paging them would buy nothing."""
    n_apps = n_applications(cfg)
    ssm_one = init_ssm_state(n_slots, d_model=cfg.d_model,
                             d_state=cfg.d_state, headdim=cfg.headdim,
                             n_groups=cfg.n_groups, d_conv=cfg.d_conv,
                             expand=cfg.expand)
    kv_one = attn_lib.init_kv_pool(n_phys_blocks, block_size,
                                   cfg.n_kv_heads, cfg.head_dim,
                                   dtype=cfg.cdtype)
    return {
        "ssm": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
            ssm_one),
        "kv": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_apps,) + a.shape), kv_one),
        "block_tables": jnp.zeros((n_slots, max_blocks), jnp.int32),
        "pos": jnp.zeros((n_slots,), jnp.int32),
    }


def prefill(params: Params, batch: dict, cfg: ModelConfig, *, max_len: int):
    """Prefill both the SSM states and the shared-block KV caches.

    Implemented as the forward pass with explicit state capture per group.
    """
    from repro.layers.rope import apply_rope

    h = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", "seq", "embed")
    S = h.shape[1]
    positions = jnp.arange(S)
    head, tail_p = _split_layers(params, cfg)

    def mamba_body(carry, layer):
        out, h_last = mamba_lm._layer_fwd(layer, carry, cfg=cfg)
        hn = rms_norm(layer["norm"], carry)[:, -(cfg.d_conv - 1):]
        proj = hn.astype(cfg.cdtype) @ layer["mixer"]["in_proj"] \
            .astype(cfg.cdtype)
        d_inner = cfg.d_inner
        bs = cfg.n_groups * cfg.d_state
        conv_state = jnp.concatenate(
            [proj[..., d_inner:2 * d_inner],
             proj[..., 2 * d_inner:2 * d_inner + 2 * bs]], axis=-1)
        return out, {"h": h_last, "conv": conv_state.astype(cfg.cdtype)}

    def group_body(carry, xs):
        group_layers, app_norm = xs
        out, ssm_states = lax.scan(dense._remat(mamba_body, cfg), carry,
                                   group_layers)
        # shared block with KV capture
        hn = rms_norm(app_norm["attn"], out)
        attn_strategy = cfg.moa_for("attention")
        q, k, v = attn_lib._project_qkv(
            params["shared_attn"], hn, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            compute_dtype=cfg.cdtype, strategy=attn_strategy)
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)
        o = attn_lib.flash_attention(q, k, v, causal=True,
                                     q_chunk=cfg.q_chunk,
                                     kv_chunk=cfg.kv_chunk)
        B = o.shape[0]
        o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
        out = out + attn_lib._moa_dot(
            o, params["shared_attn"]["wo"].astype(cfg.cdtype),
            strategy=attn_strategy, compute_dtype=cfg.cdtype)
        hn = rms_norm(app_norm["mlp"], out)
        out = out + swiglu(params["shared_mlp"], hn,
                           strategy=cfg.moa_for("mlp"),
                           compute_dtype=cfg.cdtype)
        pad = max_len - S
        kv = attn_lib._constrain_cache(
            {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
             "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))})
        return out, (ssm_states, kv)

    h, (ssm_head, kv_layers) = lax.scan(group_body, h,
                                        (head, params["app_norms"]))
    # ssm_head: (n_apps, per_group, ...) → flatten to (n_apps*per_group, ...)
    ssm_states = jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), ssm_head)
    if tail_p is not None:
        h, ssm_tail = lax.scan(dense._remat(mamba_body, cfg), h, tail_p)
        ssm_states = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), ssm_states, ssm_tail)
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h[:, -1:], compute_dtype=cfg.cdtype)
    cache = {"ssm": ssm_states, "kv": kv_layers,
             "pos": jnp.asarray(S, jnp.int32)}
    return constrain(logits, "batch", None, "vocab"), cache


def prefill_chunk(params: Params, batch: dict, cfg: ModelConfig, *,
                  state: Params, prefix_kv: Params):
    """Continue a chunked prefill from carried SSM state + cached prefix KV.

    ``state`` is the ``{"ssm", "pos"}`` portion of what :func:`prefill`
    (or a previous ``prefill_chunk``) produced — per-layer ``{"h", "conv"}``
    seeding both the SSD recurrence and the depthwise conv history.
    ``prefix_kv`` holds the shared block's already-computed prefix K/V,
    ``{"k", "v"}: (n_apps, 1, P, Hk, D)`` in compute dtype; this chunk's
    queries attend over ``concat(prefix, chunk)`` with explicit positions,
    exactly like :func:`repro.models.transformer.prefill_suffix`.

    Returns ``(logits, {"ssm", "kv", "pos"})`` where ``kv`` is the chunk's
    *suffix-only* K/V ``(n_apps, B, S, Hk, D)`` (unpadded — the engine
    accumulates it or scatters it into pool pages) and ``ssm``/``pos`` are
    the carried state advanced through this chunk. Bit-identical to the
    same positions of a one-shot :func:`prefill` when chunk boundaries
    align to ``cfg.ssd_chunk`` (see ``docs/slo-scheduling.md``).
    """
    from repro.layers.rope import apply_rope

    h = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", "seq", "embed")
    S = h.shape[1]
    P = prefix_kv["k"].shape[2]
    positions_q = P + jnp.arange(S)
    positions_kv = jnp.arange(P + S)
    n_apps, per_group, tail = _grouped(cfg)
    head, tail_p = _split_layers(params, cfg)
    head_states = jax.tree.map(
        lambda a: a[: n_apps * per_group].reshape(
            (n_apps, per_group) + a.shape[1:]), state["ssm"])
    tail_states = jax.tree.map(lambda a: a[n_apps * per_group:],
                               state["ssm"]) if tail else None

    def mamba_body(carry, xs):
        layer, st = xs
        out, h_last = mamba_lm._layer_fwd(layer, carry, cfg=cfg,
                                          initial_state=st)
        # conv state: last (d_conv - 1) conv inputs overall — splice this
        # chunk's recomputed tail behind the carried history so chunks
        # shorter than d_conv - 1 stay exact.
        hn = rms_norm(layer["norm"], carry)[:, -(cfg.d_conv - 1):]
        proj = hn.astype(cfg.cdtype) @ layer["mixer"]["in_proj"] \
            .astype(cfg.cdtype)
        d_inner = cfg.d_inner
        bs = cfg.n_groups * cfg.d_state
        tail_in = jnp.concatenate(
            [proj[..., d_inner:2 * d_inner],
             proj[..., 2 * d_inner:2 * d_inner + 2 * bs]],
            axis=-1).astype(st["conv"].dtype)
        conv_state = jnp.concatenate([st["conv"], tail_in],
                                     axis=1)[:, -(cfg.d_conv - 1):]
        return out, {"h": h_last, "conv": conv_state}

    def group_body(carry, xs):
        group_layers, group_states, app_norm, pre = xs
        out, ssm_states = lax.scan(dense._remat(mamba_body, cfg), carry,
                                   (group_layers, group_states))
        hn = rms_norm(app_norm["attn"], out)
        attn_strategy = cfg.moa_for("attention")
        q, k, v = attn_lib._project_qkv(
            params["shared_attn"], hn, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            compute_dtype=cfg.cdtype, strategy=attn_strategy)
        q = apply_rope(q, positions_q, theta=cfg.rope_theta)
        k = apply_rope(k, positions_q, theta=cfg.rope_theta)
        k_full = jnp.concatenate([pre["k"].astype(cfg.cdtype), k], axis=1)
        v_full = jnp.concatenate([pre["v"].astype(cfg.cdtype), v], axis=1)
        o = attn_lib.full_attention(q, k_full, v_full, causal=True,
                                    positions_q=positions_q,
                                    positions_kv=positions_kv)
        B = o.shape[0]
        o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
        out = out + attn_lib._moa_dot(
            o, params["shared_attn"]["wo"].astype(cfg.cdtype),
            strategy=attn_strategy, compute_dtype=cfg.cdtype)
        hn = rms_norm(app_norm["mlp"], out)
        out = out + swiglu(params["shared_mlp"], hn,
                           strategy=cfg.moa_for("mlp"),
                           compute_dtype=cfg.cdtype)
        return out, (ssm_states, {"k": k, "v": v})

    h, (ssm_head, kv_layers) = lax.scan(
        group_body, h, (head, head_states, params["app_norms"], prefix_kv))
    ssm_states = jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), ssm_head)
    if tail_p is not None:
        h, ssm_tail = lax.scan(dense._remat(mamba_body, cfg), h,
                               (tail_p, tail_states))
        ssm_states = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b], axis=0), ssm_states,
            ssm_tail)
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h[:, -1:], compute_dtype=cfg.cdtype)
    cache = {"ssm": ssm_states, "kv": kv_layers,
             "pos": state["pos"] + jnp.asarray(S, jnp.int32)}
    return constrain(logits, "batch", None, "vocab"), cache


def decode_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    pos = cache["pos"]
    h = embed(params["embed"], tokens, compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", None, "embed")
    n_apps, per_group, tail = _grouped(cfg)
    head_states = jax.tree.map(
        lambda a: a[: n_apps * per_group].reshape(
            (n_apps, per_group) + a.shape[1:]), cache["ssm"])
    tail_states = jax.tree.map(lambda a: a[n_apps * per_group:],
                               cache["ssm"]) if tail else None
    head, tail_p = _split_layers(params, cfg)

    def mamba_body(carry, xs):
        layer, state = xs
        hn = rms_norm(layer["norm"], carry)
        y, new_state = mamba2_decode(
            layer["mixer"], hn, state, d_state=cfg.d_state,
            headdim=cfg.headdim, n_groups=cfg.n_groups, expand=cfg.expand,
            compute_dtype=cfg.cdtype)
        return carry + constrain(y, "batch", None, "embed"), new_state

    def group_body(carry, xs):
        group_layers, group_states, app_norm, kv = xs
        out, new_states = lax.scan(mamba_body, carry,
                                   (group_layers, group_states))
        hn = rms_norm(app_norm["attn"], out)
        a, new_kv = attn_lib.attention_decode(
            params["shared_attn"], hn, kv, pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, compute_dtype=cfg.cdtype,
            strategy=cfg.moa_for("attention"))
        out = out + constrain(a, "batch", None, "embed")
        hn = rms_norm(app_norm["mlp"], out)
        m = swiglu(params["shared_mlp"], hn, strategy=cfg.moa_for("mlp"),
                   compute_dtype=cfg.cdtype)
        out = out + constrain(m, "batch", None, "embed")
        return out, (new_states, new_kv)

    h, (new_head_states, new_kv) = lax.scan(
        group_body, h,
        (head, head_states, params["app_norms"], cache["kv"]))
    new_ssm = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                           new_head_states)
    if tail_states is not None:
        h, new_tail = lax.scan(mamba_body, h, (tail_p, tail_states))
        new_ssm = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                               new_ssm, new_tail)
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return (constrain(logits, "batch", None, "vocab"),
            {"ssm": new_ssm, "kv": new_kv, "pos": pos + 1})


def paged_decode_step(params: Params, cache: Params, tokens,
                      cfg: ModelConfig, *, live_blocks=None):
    """Paged decode step: identical to :func:`decode_step` except the
    shared attention block reads/writes its KV through per-slot block
    tables (bounded to ``live_blocks``, dispatched per
    ``cfg.attn_backend``); the dense per-slot SSM recurrence is
    untouched."""
    pos, tables = cache["pos"], cache["block_tables"]
    h = embed(params["embed"], tokens, compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", None, "embed")
    n_apps, per_group, tail = _grouped(cfg)
    head_states = jax.tree.map(
        lambda a: a[: n_apps * per_group].reshape(
            (n_apps, per_group) + a.shape[1:]), cache["ssm"])
    tail_states = jax.tree.map(lambda a: a[n_apps * per_group:],
                               cache["ssm"]) if tail else None
    head, tail_p = _split_layers(params, cfg)

    def mamba_body(carry, xs):
        layer, state = xs
        hn = rms_norm(layer["norm"], carry)
        y, new_state = mamba2_decode(
            layer["mixer"], hn, state, d_state=cfg.d_state,
            headdim=cfg.headdim, n_groups=cfg.n_groups, expand=cfg.expand,
            compute_dtype=cfg.cdtype)
        return carry + constrain(y, "batch", None, "embed"), new_state

    def group_body(carry, xs):
        group_layers, group_states, app_norm, kv_pool = xs
        out, new_states = lax.scan(mamba_body, carry,
                                   (group_layers, group_states))
        hn = rms_norm(app_norm["attn"], out)
        a, new_pool = attn_lib.attention_decode_paged(
            params["shared_attn"], hn, kv_pool, tables, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            compute_dtype=cfg.cdtype, strategy=cfg.moa_for("attention"),
            backend=cfg.attn_backend, live_blocks=live_blocks)
        out = out + constrain(a, "batch", None, "embed")
        hn = rms_norm(app_norm["mlp"], out)
        m = swiglu(params["shared_mlp"], hn, strategy=cfg.moa_for("mlp"),
                   compute_dtype=cfg.cdtype)
        out = out + constrain(m, "batch", None, "embed")
        return out, (new_states, new_pool)

    h, (new_head_states, new_kv) = lax.scan(
        group_body, h,
        (head, head_states, params["app_norms"], cache["kv"]))
    new_ssm = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                           new_head_states)
    if tail_states is not None:
        h, new_tail = lax.scan(mamba_body, h, (tail_p, tail_states))
        new_ssm = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                               new_ssm, new_tail)
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return (constrain(logits, "batch", None, "vocab"),
            {"ssm": new_ssm, "kv": new_kv, "block_tables": tables,
             "pos": pos + 1})


# ---------------------------------------------------------------------------
# Speculative verify (docs/spec-decode.md)
# ---------------------------------------------------------------------------
# The hybrid's KV caches are position-addressed (cursor rewind suffices),
# but the Mamba-2 states are recurrent — verify is a scan of the family's
# own decode step with per-step SSM snapshots, and the commit restores
# each slot's snapshot at its accepted length.


def verify_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    """Score ``tokens (B, T)`` via T scanned decode steps; bit-identical
    to sequential decode by construction. Returns ``(logits, cache, aux)``
    — ``aux`` holds the stacked SSM snapshots for
    :func:`commit_verified`."""
    return verify_common.scan_verify(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        state_keys=("ssm",))


def paged_verify_step(params: Params, cache: Params, tokens,
                      cfg: ModelConfig, *, live_blocks=None):
    """Paged twin of :func:`verify_step`: the scanned step is
    :func:`paged_decode_step`, so tentative KV writes route through the
    block tables (slot-private pages — the engine's admission margin).
    ``live_blocks`` must already include the T-token verify window — every
    scanned step reuses the same static bound."""
    return verify_common.scan_verify(
        lambda p, c, t: paged_decode_step(p, c, t, cfg,
                                          live_blocks=live_blocks),
        params, cache, tokens, state_keys=("ssm",))


def commit_verified(cache: Params, keep, aux, cfg: ModelConfig) -> Params:
    del cfg
    return verify_common.scan_commit(cache, keep, aux)
