"""Mamba-2 LM (attention-free SSD stack) — mamba2-370m and friends.

Per layer:  h += mamba2(rms(h)).  No positional encoding (the recurrence
carries order). Decode keeps per-layer (ssm_state, conv_state) — constant
memory in sequence length, which is why long_500k runs for this family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.layers.common import Params, init_rms_norm, rms_norm
from repro.layers.embedding import embed, init_embedding, unembed
from repro.layers.ssd import (init_mamba2_block, init_ssm_state,
                              mamba2_decode, mamba2_forward)
from repro.models import transformer as dense
from repro.models import verify_common
from repro.parallel import constrain

__all__ = ["init_params", "forward", "init_cache", "prefill",
           "prefill_chunk", "decode_step", "verify_step", "commit_verified"]


#: Static-auditor registration (:mod:`repro.analysis.targets`): the serve
#: callables this family module exposes, its KV stack key (None = no KV),
#: and whether the paged layout / suffix prefill apply. The auditor
#: enumerates targets from this table, so a family module that grows a new
#: serve entry point must declare it here to be covered by CI.
SERVE_AUDIT = {
    "phases": ("prefill", "decode", "verify", "commit"),
    "paged": False,
    "kv_key": None,
    "suffix_prefill": False,
    "prefill_chunk": True,
}


def _init_layer(rng, cfg: ModelConfig) -> Params:
    return {
        "norm": init_rms_norm(cfg.d_model, cfg.pdtype),
        "mixer": init_mamba2_block(
            rng, d_model=cfg.d_model, d_state=cfg.d_state,
            headdim=cfg.headdim, n_groups=cfg.n_groups, d_conv=cfg.d_conv,
            expand=cfg.expand, dtype=cfg.pdtype),
    }


def init_params(rng, cfg: ModelConfig) -> Params:
    ke, kl = jax.random.split(rng)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    return {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model,
                                tie=cfg.tie_embeddings, dtype=cfg.pdtype),
        "layers": layers,
        "final_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
    }


def _layer_fwd(layer: Params, h, *, cfg: ModelConfig, initial_state=None):
    hn = rms_norm(layer["norm"], h)
    y, h_last = mamba2_forward(
        layer["mixer"], hn, d_state=cfg.d_state, headdim=cfg.headdim,
        n_groups=cfg.n_groups, expand=cfg.expand, ssd_chunk=cfg.ssd_chunk,
        compute_dtype=cfg.cdtype, initial_state=initial_state)
    return h + constrain(y, "batch", "seq", "embed"), h_last


def forward(params: Params, batch: dict, cfg: ModelConfig):
    h = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", "seq", "embed")

    def body(carry, layer):
        out, _ = _layer_fwd(layer, carry, cfg=cfg)
        return out, None

    h, _ = lax.scan(dense._remat(body, cfg), h, params["layers"])
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return constrain(logits, "batch", "seq", "vocab")


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    del max_len  # constant-size state: the SSM's whole point
    one = init_ssm_state(batch, d_model=cfg.d_model, d_state=cfg.d_state,
                         headdim=cfg.headdim, n_groups=cfg.n_groups,
                         d_conv=cfg.d_conv, expand=cfg.expand)
    return {
        "layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params: Params, batch: dict, cfg: ModelConfig, *, max_len: int):
    """Chunked-scan prefill; emits final (ssm, conv) state per layer."""
    del max_len
    h = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", "seq", "embed")
    S = h.shape[1]

    def body(carry, layer):
        out, h_last = _layer_fwd(layer, carry, cfg=cfg)
        # conv state: last (d_conv - 1) conv inputs of this layer. Recompute
        # the projection on the tail positions only (cheap, avoids carrying
        # the full conv stream through the scan).
        hn = rms_norm(layer["norm"], carry)[:, -(cfg.d_conv - 1):]
        proj = hn.astype(cfg.cdtype) @ layer["mixer"]["in_proj"] \
            .astype(cfg.cdtype)
        d_inner = cfg.d_inner
        bs = cfg.n_groups * cfg.d_state
        xp = proj[..., d_inner:2 * d_inner]
        bc = proj[..., 2 * d_inner:2 * d_inner + 2 * bs]
        conv_state = jnp.concatenate([xp, bc], axis=-1)
        return out, {"h": h_last, "conv": conv_state.astype(cfg.cdtype)}

    h, states = lax.scan(dense._remat(body, cfg), h, params["layers"])
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h[:, -1:], compute_dtype=cfg.cdtype)
    return (constrain(logits, "batch", None, "vocab"),
            {"layers": states, "pos": jnp.asarray(S, jnp.int32)})


def prefill_chunk(params: Params, batch: dict, cfg: ModelConfig, *,
                  state: Params):
    """Continue a chunked prefill from a cache-shaped ``state``.

    ``state`` is exactly what :func:`prefill` (or a previous
    ``prefill_chunk``) returned — per-layer ``{"h", "conv"}`` plus the
    token cursor — so the final chunk's state *is* the prefill cache. The
    per-layer dict seeds both the SSD recurrence (``h``) and the depthwise
    conv history (``conv``), making the chunked scan bit-identical to one
    long scan when the engine aligns chunk boundaries to ``cfg.ssd_chunk``
    (see ``docs/slo-scheduling.md``).
    """
    h = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", "seq", "embed")
    S = h.shape[1]

    def body(carry, xs):
        layer, st = xs
        out, h_last = _layer_fwd(layer, carry, cfg=cfg, initial_state=st)
        # conv state: last (d_conv - 1) conv inputs *overall* — recompute
        # this chunk's tail and splice it behind the carried history so
        # chunks shorter than d_conv - 1 stay exact.
        hn = rms_norm(layer["norm"], carry)[:, -(cfg.d_conv - 1):]
        proj = hn.astype(cfg.cdtype) @ layer["mixer"]["in_proj"] \
            .astype(cfg.cdtype)
        d_inner = cfg.d_inner
        bs = cfg.n_groups * cfg.d_state
        xp = proj[..., d_inner:2 * d_inner]
        bc = proj[..., 2 * d_inner:2 * d_inner + 2 * bs]
        tail = jnp.concatenate([xp, bc], axis=-1).astype(st["conv"].dtype)
        conv_state = jnp.concatenate([st["conv"], tail],
                                     axis=1)[:, -(cfg.d_conv - 1):]
        return out, {"h": h_last, "conv": conv_state}

    h, states = lax.scan(dense._remat(body, cfg), h,
                         (params["layers"], state["layers"]))
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h[:, -1:], compute_dtype=cfg.cdtype)
    return (constrain(logits, "batch", None, "vocab"),
            {"layers": states,
             "pos": state["pos"] + jnp.asarray(S, jnp.int32)})


def decode_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    h = embed(params["embed"], tokens, compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", None, "embed")

    def body(carry, xs):
        layer, state = xs
        hn = rms_norm(layer["norm"], carry)
        y, new_state = mamba2_decode(
            layer["mixer"], hn, state, d_state=cfg.d_state,
            headdim=cfg.headdim, n_groups=cfg.n_groups, expand=cfg.expand,
            compute_dtype=cfg.cdtype)
        return carry + constrain(y, "batch", None, "embed"), new_state

    h, new_layers = lax.scan(body, h, (params["layers"], cache["layers"]))
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return (constrain(logits, "batch", None, "vocab"),
            {"layers": new_layers, "pos": cache["pos"] + 1})


def verify_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    """Score ``tokens (B, T)`` via T scanned decode steps with per-step
    state snapshots — the recurrent state cannot be cursor-rewound, so the
    commit restores the snapshot at each slot's accepted length (see
    :mod:`repro.models.verify_common`)."""
    return verify_common.scan_verify(
        lambda p, c, t: decode_step(p, c, t, cfg), params, cache, tokens,
        state_keys=("layers",))


def commit_verified(cache: Params, keep, aux, cfg: ModelConfig) -> Params:
    del cfg
    return verify_common.scan_commit(cache, keep, aux)
