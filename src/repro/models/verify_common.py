"""Scan-based speculative verify for families with recurrent state.

Attention-only families verify a ``T``-token draft window in one wide
call (:func:`repro.layers.attention.attention_verify`) because their decode
state is position-addressed: rejecting a draft suffix is a cursor rewind.
SSM and hybrid families carry a *recurrent* state that the draft tokens
mutate irreversibly, so their verify is a ``lax.scan`` of the family's own
single-token ``decode_step`` — bit-identical to sequential decode by
construction — that snapshots the recurrent leaves after every step. The
commit then selects, per slot, the snapshot at the accepted length: slots
that rejected the whole window restore the pre-verify state (snapshot 0).

Conventions shared with the attention-family verify:

* ``verify`` returns ``(logits (B, T, V), cache, aux)`` with the cache's
  ``pos`` cursor left at its *pre-verify* value (position-addressed leaves
  hold all T tentative writes; recurrent leaves hold the post-T state,
  which ``commit`` overwrites from ``aux``);
* ``commit(cache, keep, aux)`` advances ``pos`` by the per-slot ``keep``
  (accepted drafts + 1; 0 for idle slots) and restores recurrent leaves
  from snapshot ``keep``.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["scan_verify", "select_snapshots", "scan_commit"]


def scan_verify(decode_fn, params, cache, tokens,
                state_keys: Sequence[str]) -> Tuple[jax.Array, dict, dict]:
    """Verify ``tokens (B, T)`` as T sequential ``decode_fn`` steps.

    ``decode_fn(params, cache, (B, 1) tokens) -> (logits, cache)`` is the
    family's decode step. ``state_keys`` name the cache entries holding
    recurrent (non-position-addressed) state; their post-step values are
    stacked into ``aux`` with the pre-verify state prepended, so
    ``aux[key]`` leaves are ``(T + 1, ...)``.
    """
    pos0 = cache["pos"]

    def step(c, tok):
        logits, c2 = decode_fn(params, c, tok[:, None])
        return c2, (logits[:, 0], {k: c2[k] for k in state_keys})

    final, (logits, snaps) = lax.scan(step, cache, tokens.T)
    aux = {
        key: jax.tree.map(
            lambda first, rest: jnp.concatenate([first[None], rest], axis=0),
            cache[key], snaps[key])
        for key in state_keys
    }
    new_cache = dict(final)
    new_cache["pos"] = pos0
    return jnp.moveaxis(logits, 0, 1), new_cache, aux


def select_snapshots(aux: dict, keep) -> dict:
    """Per-slot snapshot selection: leaf ``(T+1, stack, B, ...)`` →
    ``(stack, B, ...)`` taking step ``keep[b]`` for slot ``b``.

    All recurrent cache leaves in this repo are laid out
    ``(stack, batch, ...)`` (layer or application-point stack first), so
    the snapshot axis order is ``(T+1, stack, B, ...)`` after stacking.
    """

    def sel(leaf):
        per_slot = jnp.moveaxis(leaf, 2, 0)              # (B, T+1, stack, ..)
        out = jax.vmap(lambda snaps, i: snaps[i])(per_slot, keep)
        return jnp.moveaxis(out, 0, 1)                   # (stack, B, ...)

    return {key: jax.tree.map(sel, tree) for key, tree in aux.items()}


def scan_commit(cache, keep, aux) -> dict:
    """Advance ``pos`` by ``keep`` and restore recurrent leaves from the
    per-slot accepted snapshot."""
    new_cache = dict(cache)
    new_cache.update(select_snapshots(aux, keep))
    new_cache["pos"] = cache["pos"] + keep.astype(cache["pos"].dtype)
    return new_cache
