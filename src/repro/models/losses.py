"""Cross-entropy losses — vocab-parallel by construction.

The softmax denominator over a 128k–202k vocab is a distributed MOA: with
logits sharded ``(batch, seq, vocab→model)`` the max/sum-exp reductions
lower to small per-shard partials + an all-reduce over ``model`` instead of
an all-gather of the full logits tensor (the naive "gather" baseline kept
for the §Perf before/after).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel import constrain

__all__ = ["softmax_cross_entropy", "masked_lm_loss"]


def softmax_cross_entropy(logits, labels, *, mask=None,
                          impl: str = "vocab_parallel") -> Tuple[jax.Array, dict]:
    """Mean CE of ``logits (B, S, V)`` vs ``labels (B, S)``.

    ``impl="vocab_parallel"`` keeps logits sharded over vocab through the
    reduction; ``impl="gather"`` forces replication first (baseline).
    """
    logits = logits.astype(jnp.float32)
    if impl == "gather":
        logits = constrain(logits, "batch", "seq", None)
    else:
        logits = constrain(logits, "batch", "seq", "vocab")

    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    label_logit = jnp.take_along_axis(
        logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - label_logit

    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    total = jnp.sum(nll * mask)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = total / denom
    metrics = {
        "loss": loss,
        "tokens": denom,
        "accuracy": jnp.sum(
            (jnp.argmax(logits, -1) == labels).astype(jnp.float32) * mask
        ) / denom,
    }
    return loss, metrics


def masked_lm_loss(logits, targets, mask_positions, *,
                   impl: str = "vocab_parallel"):
    """HuBERT-style masked-prediction loss: CE only at masked frames."""
    return softmax_cross_entropy(logits, targets, mask=mask_positions,
                                 impl=impl)
