"""Uniform model API: one entry point for train / serve / dry-run / tests.

``build_model(cfg)`` dispatches on ``cfg.family`` and returns a
:class:`Model` exposing:

  init(rng) → params
  loss(params, batch) → (loss, metrics)          [train phase]
  forward(params, batch) → logits                 [prefill-shaped forward]
  init_cache(batch, max_len) → cache
  prefill(params, batch, max_len) → (logits, cache)
  decode_step(params, cache, tokens) → (logits, cache)
  input_specs(shape) → pytree of ShapeDtypeStruct  [dry-run stand-ins]
  make_batch(rng, shape, scale=1.0) → concrete batch [smoke/integration]
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import losses, mamba2, moe_transformer, transformer, zamba2

__all__ = ["CacheSpec", "Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Decode-cache layout summary (the serve engine's and cost model's
    shared vocabulary for cache memory).

    ``n_kv_stacks`` is the leading stack axis of the KV leaves — layers
    for dense/MoE, application points for the hybrid, 0 when the family
    keeps no KV at all (pure SSM). ``kv_bytes_per_token`` covers K and V
    across all stacks (int8 scales included); ``slot_state_bytes`` is the
    per-slot sequence-length-independent state (SSM/conv)."""

    family: str
    n_kv_stacks: int
    n_kv_heads: int
    head_dim: int
    kv_bytes_per_token: int
    slot_state_bytes: int

    @property
    def pageable(self) -> bool:
        """Whether this family has KV state worth paging."""
        return self.n_kv_stacks > 0

    def kv_block_bytes(self, block_size: int) -> int:
        """Bytes of one physical page across all KV stacks."""
        return self.kv_bytes_per_token * block_size

    def dense_kv_bytes(self, n_slots: int, max_len: int) -> int:
        """The dense-slot layout's resident KV bytes: ``n_slots·max_len``
        tokens reserved whether used or not — the over-provisioning the
        paged pool removes."""
        return self.kv_bytes_per_token * n_slots * max_len


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    _mod: Any

    # ---- parameters -------------------------------------------------------
    def init(self, rng):
        return self._mod.init_params(rng, self.cfg)

    def abstract_params(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    # ---- training ---------------------------------------------------------
    def _forward_with_aux(self, params, batch):
        """Normalize the family modules' ``logits | (logits, aux)`` returns."""
        out = self._mod.forward(params, batch, self.cfg)
        if isinstance(out, tuple):
            return out
        return out, None

    def forward(self, params, batch):
        logits, _ = self._forward_with_aux(params, batch)
        return logits

    def loss(self, params, batch):
        cfg = self.cfg
        logits, aux = self._forward_with_aux(params, batch)
        if cfg.family == "encoder":
            loss, metrics = losses.masked_lm_loss(
                logits, batch["targets"], batch["mask"], impl=cfg.loss_impl)
        else:
            labels = batch["labels"]
            loss, metrics = losses.softmax_cross_entropy(
                logits, labels, mask=batch.get("loss_mask"),
                impl=cfg.loss_impl)
        if aux is not None:
            loss = loss + 0.01 * aux
            metrics = dict(metrics, aux_loss=aux)
        metrics = dict(metrics, loss=loss)
        return loss, metrics

    # ---- serving ----------------------------------------------------------
    @property
    def supports_padded_prefill(self) -> bool:
        """Whether ``prefill(..., prompt_len=p)`` with right-padded prompts
        is exact: attention families mask padded K/V rows away; SSM/hybrid
        recurrent state would absorb the pad tokens, so they require
        exact-length prompts (the serve engine compiles one prefill per
        bucket length instead of padding). VLM is excluded: ``prompt_len``
        indexes the text positions only, but the prefill sequence carries
        the patch prefix, so the padded slice/pos bookkeeping would be
        offset by ``n_patches``. MoE is included only in the dropless
        regime (``capacity_factor >= n_experts / top_k``): below that, pad
        tokens compete with real tokens for expert capacity and padded
        prefill silently diverges from the exact-length path."""
        cfg = self.cfg
        if cfg.family == "moe":
            return cfg.capacity_factor >= cfg.n_experts / max(cfg.top_k, 1)
        return cfg.family == "dense"

    def init_cache(self, batch: int, max_len: int):
        """Zeroed decode state for ``batch`` sequences of capacity
        ``max_len`` tokens (KV caches and/or SSM states, plus a ``pos``
        write cursor — scalar int32; the serve engine broadcasts it to a
        ``(batch,)`` vector for per-slot positions)."""
        return self._mod.init_cache(self.cfg, batch, max_len)

    def cache_spec(self) -> CacheSpec:
        """Cache layout summary: which leaves scale with sequence length
        (KV — pageable) vs per-slot constant state (SSM), and their byte
        rates. Derived from ``init_cache`` shapes via ``eval_shape``, so it
        cannot drift from the real layout."""
        cfg = self.cfg
        if cfg.family == "encoder":
            return CacheSpec(family=cfg.family, n_kv_stacks=0, n_kv_heads=0,
                             head_dim=0, kv_bytes_per_token=0,
                             slot_state_bytes=0)
        # batch=1, max_len=1: KV leaf bytes are then exactly per-token
        shapes = jax.eval_shape(lambda: self.init_cache(1, 1))

        def nbytes(tree):
            return sum(s.size * s.dtype.itemsize
                       for s in jax.tree.leaves(tree))

        if cfg.family == "hybrid":
            kv, slot_state = shapes["kv"], shapes["ssm"]
            n_stacks = zamba2.n_applications(cfg)
        elif cfg.family == "ssm":
            kv, slot_state = {}, shapes["layers"]
            n_stacks = 0
        else:
            kv, slot_state = shapes["layers"], {}
            n_stacks = cfg.n_layers
        return CacheSpec(family=cfg.family, n_kv_stacks=n_stacks,
                         n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                         kv_bytes_per_token=nbytes(kv),
                         slot_state_bytes=nbytes(slot_state))

    def init_paged_cache(self, n_slots: int, n_phys_blocks: int,
                         block_size: int, max_blocks: int):
        """Paged decode state: pooled KV pages + per-slot block tables and
        a ``(n_slots,)`` position vector (SSM state, if any, stays dense
        per slot). Only meaningful for KV-bearing families
        (``cache_spec().pageable``)."""
        if not self.cache_spec().pageable:
            raise ValueError(
                f"family {self.cfg.family!r} has no KV cache to page — its "
                "decode state is constant-size per slot")
        return self._mod.init_paged_cache(self.cfg, n_slots, n_phys_blocks,
                                          block_size, max_blocks)

    def paged_decode_step(self, params, cache, tokens, *, live_blocks=None):
        """One decode step against the paged cache; bit-identical math to
        :meth:`decode_step` (``tests/test_paged_kv.py`` parity suite).
        ``live_blocks`` (static) bounds the KV stream to the batch's
        high-water logical block — pages past every cursor were fully
        masked, so truncating them is exact."""
        return self._mod.paged_decode_step(params, cache, tokens, self.cfg,
                                           live_blocks=live_blocks)

    def prefill_suffix(self, params, batch, *, prefix, prompt_len):
        """Suffix-only prefill against cached prefix K/V.

        Dense always; MoE only in the dropless regime — below it, expert
        capacity couples the suffix tokens to the prefix tokens they no
        longer see (the padded-prefill condition again). SSM/hybrid
        recurrence has no position-addressed prefix to resume from — those
        families share paged *storage* but continue chunked prefill through
        :meth:`prefill_chunk` instead; see docs/paged-kv.md."""
        cfg = self.cfg
        ok = cfg.family == "dense" or \
            (cfg.family == "moe" and self.supports_padded_prefill)
        if not ok:
            raise ValueError(
                f"family {cfg.family!r} cannot skip prefix prefill "
                "compute (expert-capacity or recurrent-state coupling)")
        return self._mod.prefill_suffix(params, batch, self.cfg,
                                        prefix=prefix, prompt_len=prompt_len)

    # ---- chunked prefill (docs/slo-scheduling.md) --------------------------
    @property
    def supports_chunked_prefill(self) -> bool:
        """Whether a prompt can be prefilled in fixed-budget chunks
        interleaved with decode ticks, bit-identical to one-shot prefill.

        Attention families chunk via :meth:`prefill_suffix` (dense always,
        MoE dropless-only); SSM/hybrid chunk via :meth:`prefill_chunk`
        (carried recurrent state). Encoder has no decode; VLM is not
        served."""
        cfg = self.cfg
        if cfg.family == "moe":
            return self.supports_padded_prefill
        return cfg.family in ("dense", "ssm", "hybrid")

    @property
    def prefill_chunk_alignment(self) -> int:
        """Chunk boundaries must land on multiples of this many tokens for
        chunked prefill to be bit-identical to one-shot: recurrent families
        need SSD-chunk alignment (the chunked scan's intra-chunk grouping
        must match the one-shot scan's), attention families have no
        constraint (the engine still aligns to ``block_size`` when
        paged)."""
        if self.cfg.family in ("ssm", "hybrid"):
            return self.cfg.ssd_chunk
        return 1

    def prefill_chunk(self, params, batch, *, state, prefix_kv=None):
        """Continue a recurrent family's chunked prefill from carried
        state: ``state`` is what the chunk-0 :meth:`prefill` (or a previous
        ``prefill_chunk``) returned; the hybrid additionally takes
        ``prefix_kv`` — the shared block's cached prefix K/V
        ``(n_apps, 1, P, Hk, D)``. Attention families raise: they chunk
        through :meth:`prefill_suffix` (no carried state)."""
        if self.cfg.family == "ssm":
            return self._mod.prefill_chunk(params, batch, self.cfg,
                                           state=state)
        if self.cfg.family == "hybrid":
            return self._mod.prefill_chunk(params, batch, self.cfg,
                                           state=state, prefix_kv=prefix_kv)
        raise ValueError(
            f"family {self.cfg.family!r} has no carried-state prefill "
            "chunk — attention families chunk via prefill_suffix")

    def split_prefill_cache(self, pre):
        """Split a prefill cache into (kv leaves laid out
        ``(stack, 1, max_len, ...)``, per-slot state leaves or None) — the
        serve engine's family-agnostic hook for scattering a prefill into
        the paged pool."""
        if self.cfg.family == "hybrid":
            return pre["kv"], pre["ssm"]
        return pre["layers"], None

    def prefill(self, params, batch, *, max_len: int, prompt_len=None):
        """Run the prompt through the model, filling the cache.

        Returns ``(logits, cache)`` where ``logits`` is ``(B, 1, vocab)``
        at the last *real* prompt position. ``prompt_len`` (scalar int,
        tokens) marks the true length of a right-padded prompt; only
        supported when :attr:`supports_padded_prefill` (exactness —
        ValueError otherwise).
        """
        if self.cfg.family == "encoder":
            # encoder "prefill" is a bidirectional encode: no KV cache, no
            # decode step exists (assignment skip rule covers decode shapes)
            logits = self._mod.forward(params, batch, self.cfg)
            return logits, {"pos": jnp.asarray(logits.shape[1], jnp.int32)}
        if prompt_len is None:
            return self._mod.prefill(params, batch, self.cfg, max_len=max_len)
        if not self.supports_padded_prefill:
            raise ValueError(
                f"family {self.cfg.family!r} cannot prefill padded prompts: "
                "recurrent state would absorb the pad tokens")
        return self._mod.prefill(params, batch, self.cfg, max_len=max_len,
                                 prompt_len=prompt_len)

    def decode_step(self, params, cache, tokens):
        """One decode step: ``tokens (B, 1) int32`` → ``(logits, cache)``.

        ``cache["pos"]`` may be a scalar (lockstep batch) or a ``(B,)``
        vector (continuous batching: each slot writes/attends at its own
        position).
        """
        return self._mod.decode_step(params, cache, tokens, self.cfg)

    # ---- speculative decoding (docs/spec-decode.md) ------------------------
    @property
    def supports_spec_decode(self) -> bool:
        """Whether a T-token verify is exact for this family.

        Attention families verify in one wide call; MoE only in the
        dropless regime (below it, expert capacity couples the draft
        window's tokens — the padded-prefill condition again). SSM/hybrid
        verify by a scanned decode step with state snapshots, exact by
        construction. Encoder has no decode; VLM is not served.
        """
        cfg = self.cfg
        if cfg.family == "moe":
            return cfg.capacity_factor >= cfg.n_experts / max(cfg.top_k, 1)
        return cfg.family in ("dense", "ssm", "hybrid")

    def verify_step(self, params, cache, tokens):
        """Score ``tokens (B, T)`` in one call: column 0 is each slot's
        pending next token, columns ``1..T-1`` the drafted continuation.

        Returns ``(logits (B, T, V), cache, aux)``: ``logits[:, i]``
        bit-matches the ``i``-th of T sequential :meth:`decode_step`
        calls; the cache holds all T tentative writes with ``pos`` still
        at the pre-verify cursor; ``aux`` is the opaque rewind state for
        :meth:`commit_verified` (``None`` for attention families, stacked
        recurrent-state snapshots for SSM/hybrid).
        """
        if not self.supports_spec_decode:
            raise ValueError(
                f"family {self.cfg.family!r} (cfg {self.cfg.name!r}) has no "
                "exact multi-token verify (capacity-limited MoE couples the "
                "draft window through expert capacity)")
        return self._mod.verify_step(params, cache, tokens, self.cfg)

    def paged_verify_step(self, params, cache, tokens, *, live_blocks=None):
        """:meth:`verify_step` against the paged cache layout (same
        contract; tentative writes route through the block tables).
        ``live_blocks`` must cover the deepest cursor plus the verify
        window."""
        if not self.supports_spec_decode:
            raise ValueError(
                f"family {self.cfg.family!r} (cfg {self.cfg.name!r}) has no "
                "exact multi-token verify (capacity-limited MoE couples the "
                "draft window through expert capacity)")
        return self._mod.paged_verify_step(params, cache, tokens, self.cfg,
                                           live_blocks=live_blocks)

    def commit_verified(self, cache, keep, aux=None):
        """Finalize a verify: advance each slot's ``pos`` by ``keep (B,)``
        (accepted drafts + 1; 0 for idle slots) and — recurrent families —
        restore the state snapshot at the accepted length. Rejected
        positions need no physical rollback: position-addressed rows past
        the cursor are masked garbage until overwritten."""
        return self._mod.commit_verified(cache, keep, aux, self.cfg)

    # ---- shapes ------------------------------------------------------------
    def _token_split(self, seq_len: int):
        """VLM: split total sequence into (patch prefix, text)."""
        cfg = self.cfg
        if cfg.family == "vlm":
            n_patches = min(cfg.n_patches, seq_len // 2)
            return n_patches, seq_len - n_patches
        return 0, seq_len

    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the given phase (no allocation).

        For decode shapes: the *cache* spec has sequence capacity
        ``shape.seq_len`` and the step input is one token per sequence —
        "one new token with a KV cache of seq_len" per the assignment.
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        f32, i32 = jnp.bfloat16, jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.phase == "decode":
            cache = jax.eval_shape(lambda: self.init_cache(B, S))
            return {"tokens": sds((B, 1), i32), "cache": cache}
        if cfg.family == "encoder":
            specs = {"frames": sds((B, S, cfg.d_model), f32),
                     "mask": sds((B, S), jnp.bool_)}
            if shape.phase == "train":
                specs["targets"] = sds((B, S), i32)
            return specs
        n_patches, s_text = self._token_split(S)
        specs: Dict[str, Any] = {"tokens": sds((B, s_text), i32)}
        if n_patches:
            specs["patches"] = sds((B, n_patches, cfg.d_model), f32)
        if shape.phase == "train":
            specs["labels"] = sds((B, s_text), i32)
        return specs

    def make_batch(self, rng, shape: ShapeSpec, *,
                   batch_override: Optional[int] = None,
                   seq_override: Optional[int] = None) -> Dict[str, Any]:
        """Concrete random batch (smoke tests, examples)."""
        cfg = self.cfg
        B = batch_override or shape.global_batch
        S = seq_override or shape.seq_len
        ks = jax.random.split(rng, 4)
        if cfg.family == "encoder":
            out = {
                "frames": 0.02 * jax.random.normal(
                    ks[0], (B, S, cfg.d_model), jnp.float32),
                "mask": jax.random.bernoulli(ks[1], 0.35, (B, S)),
            }
            if shape.phase == "train":
                out["targets"] = jax.random.randint(
                    ks[2], (B, S), 0, cfg.vocab, jnp.int32)
            return out
        n_patches, s_text = self._token_split(S)
        out = {"tokens": jax.random.randint(ks[0], (B, s_text), 0,
                                            cfg.vocab, jnp.int32)}
        if n_patches:
            out["patches"] = 0.02 * jax.random.normal(
                ks[1], (B, n_patches, cfg.d_model), jnp.float32)
        if shape.phase == "train":
            out["labels"] = jax.random.randint(ks[2], (B, s_text), 0,
                                               cfg.vocab, jnp.int32)
        return out


_FAMILY_MODULES = {
    "dense": transformer,
    "encoder": transformer,
    "vlm": transformer,
    "moe": moe_transformer,
    "ssm": mamba2,
    "hybrid": zamba2,
}


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family not in _FAMILY_MODULES:
        raise ValueError(f"unknown family {cfg.family!r}")
    return Model(cfg=cfg, _mod=_FAMILY_MODULES[cfg.family])
