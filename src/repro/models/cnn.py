"""LeNet-5 and AlexNet in JAX — the paper's own experimental subjects.

These exist for the DHM experiments (Table 1, Figs. 4/5 end-to-end): their
conv layers are the MOAs under study. Forward supports two accumulation
paths: the standard ``lax.conv`` (XLA's fused reduction) and an explicit
im2col path whose ``C·kh·kw`` contraction routes through a
:mod:`repro.moa` strategy (``resolve`` spec strings, :func:`moa_scope`
overrides, jnp/pallas backends) — making tree/serial/LOA scheduling,
including the quantized int8 + LOA variant, observable end-to-end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.layers.common import Params, dense_init
from repro.moa import active_strategy, resolve

__all__ = ["init_lenet5", "init_alexnet", "lenet5_forward", "alexnet_forward",
           "im2col_conv", "LENET5_LAYOUT", "ALEXNET_LAYOUT"]

# (name, out_ch, in_ch(per group), kh, kw, stride, groups, padding, pool)
LENET5_LAYOUT = [
    ("conv1", 6, 1, 5, 5, 1, 1, "VALID", True),
    ("conv2", 16, 6, 5, 5, 1, 1, "VALID", True),
]
ALEXNET_LAYOUT = [
    ("conv1", 96, 3, 11, 11, 4, 1, "VALID", True),
    ("conv2", 256, 48, 5, 5, 1, 2, "SAME", True),
    ("conv3", 384, 256, 3, 3, 1, 1, "SAME", False),
    ("conv4", 384, 192, 3, 3, 1, 2, "SAME", False),
    ("conv5", 256, 192, 3, 3, 1, 2, "SAME", True),
]


def _init_convnet(rng, layout, fc_dims, n_classes, dtype):
    params = {}
    keys = jax.random.split(rng, len(layout) + len(fc_dims) + 1)
    for (name, oc, ic, kh, kw, *_), k in zip(layout, keys):
        params[name] = {
            "w": dense_init(k, (oc, ic, kh, kw), dtype, fan_in=ic * kh * kw),
            "b": jnp.zeros((oc,), dtype),
        }
    prev = fc_dims[0]
    for i, d in enumerate(fc_dims[1:], 1):
        params[f"fc{i}"] = {
            "w": dense_init(keys[len(layout) + i - 1], (prev, d), dtype,
                            fan_in=prev),
            "b": jnp.zeros((d,), dtype),
        }
        prev = d
    params["head"] = {
        "w": dense_init(keys[-1], (prev, n_classes), dtype, fan_in=prev),
        "b": jnp.zeros((n_classes,), dtype),
    }
    return params


def init_lenet5(rng, dtype=jnp.float32) -> Params:
    # 32×32×1 → conv5×5 VALID → 28, pool → 14, conv5×5 VALID → 10, pool → 5:
    # flatten 16·5·5 = 400 → 120 → 84 → 10
    return _init_convnet(rng, LENET5_LAYOUT, [400, 120, 84], 10, dtype)


def init_alexnet(rng, dtype=jnp.float32) -> Params:
    # 227×227×3 → 55 → 27 → 13 → 13 → 13 → 6: flatten 6·6·256 = 9216.
    # Classifier truncated to one hidden FC (the paper studies conv MOAs).
    return _init_convnet(rng, ALEXNET_LAYOUT, [9216, 4096], 1000, dtype)


def _conv(x, w, b, *, stride, groups, padding):
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "OIHW", "NHWC"),
        feature_group_count=groups)
    return y + b


def im2col_conv(x, w, b, *, stride: int, padding: str = "VALID",
                strategy=None):
    """Explicit DHM-style conv: unfold patches, then one MOA per filter.

    ``x: (B, H, W, C)``, ``w: (O, C, kh, kw)``; ``padding`` is
    ``"VALID"`` or ``"SAME"``. The ``C·kh·kw`` contraction is the paper's
    MOA; it routes through ``strategy.dot`` so tree/serial/LOA scheduling
    applies end-to-end. ``strategy`` accepts anything
    :func:`repro.moa.resolve` does; defaults to ``"tree"`` (the
    synthesis-tool baseline) unless a :func:`repro.moa.moa_scope` override
    is active.
    """
    B, H, W, C = x.shape
    O, Ci, kh, kw = w.shape
    assert Ci == C, (Ci, C)
    patches = lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding=padding,
        dimension_numbers=("NHWC", "OIHW", "NHWC"))  # (B, Ho, Wo, C*kh*kw)
    Ho, Wo = patches.shape[1], patches.shape[2]
    cols = patches.reshape(B * Ho * Wo, C * kh * kw)
    wmat = w.reshape(O, C * kh * kw).T               # (CKK, O)
    strat = active_strategy(strategy) or resolve("tree")
    if jnp.issubdtype(cols.dtype, jnp.integer):
        y = strat.dot(cols, wmat, out_dtype=jnp.int32)
        return y.reshape(B, Ho, Wo, O) + b.astype(jnp.int32)
    y = strat.dot(cols, wmat, out_dtype=jnp.float32)
    return y.reshape(B, Ho, Wo, O) + b


def _stack_forward(params: Params, x, layout, n_fc: int,
                   accum: str = "conv", strategy=None) -> jax.Array:
    """Shared conv-stack forward with selectable accumulation path.

    ``accum="conv"`` uses ``lax.conv`` (XLA's fused reduction — the
    baseline); ``accum="im2col"`` routes every ``groups == 1`` conv
    through :func:`im2col_conv` so its ``C·kh·kw`` contraction is
    scheduled by the active MOA strategy. Grouped convs (AlexNet's
    two-GPU-era split layers) keep the ``lax.conv`` path — the MOA engine
    schedules single dense contractions, not per-group scatter.
    """
    if accum not in ("conv", "im2col"):
        raise ValueError(f"accum must be 'conv' or 'im2col', got {accum!r}")
    h = x
    for name, oc, ic, kh, kw, stride, groups, padding, pool in layout:
        p = params[name]
        if accum == "im2col" and groups == 1:
            h = im2col_conv(h, p["w"], p["b"], stride=stride,
                            padding=padding, strategy=strategy)
        else:
            h = _conv(h, p["w"], p["b"], stride=stride, groups=groups,
                      padding=padding)
        h = jax.nn.relu(h)
        if pool:
            h = lax.reduce_window(
                h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    for i in range(1, n_fc + 1):
        p = params[f"fc{i}"]
        assert h.shape[-1] == p["w"].shape[0], \
            f"fc{i}: got {h.shape[-1]}, expected {p['w'].shape[0]}"
        h = jax.nn.relu(h @ p["w"] + p["b"])
    p = params["head"]
    return h @ p["w"] + p["b"]


def lenet5_forward(params: Params, x, *, accum: str = "conv",
                   strategy=None) -> jax.Array:
    """``x: (B, 32, 32, 1)`` → logits ``(B, 10)``; ``accum``/``strategy``
    select the conv accumulation path (see :func:`_stack_forward`)."""
    return _stack_forward(params, x, LENET5_LAYOUT, n_fc=2, accum=accum,
                          strategy=strategy)


def alexnet_forward(params: Params, x, *, accum: str = "conv",
                    strategy=None) -> jax.Array:
    """``x: (B, 227, 227, 3)`` → logits ``(B, 1000)``; ``accum``/
    ``strategy`` select the conv accumulation path for the ``groups == 1``
    layers (conv1/conv3 — the others are grouped)."""
    return _stack_forward(params, x, ALEXNET_LAYOUT, n_fc=1, accum=accum,
                          strategy=strategy)
