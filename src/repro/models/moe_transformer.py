"""MoE transformer LM (llama4-maverick 128e top-1, moonshot 64e top-6).

Identical skeleton to the dense transformer; the MLP is replaced by the
EP-shardable MoE layer. The router aux loss is accumulated through the
layer scan and surfaced in metrics. The expert dispatch scatter is the
cross-device MOA: under ``experts → model`` sharding the token permutation
lowers to the all-to-all that the §Roofline collective term measures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.layers import attention as attn_lib
from repro.layers.common import Params, init_rms_norm, rms_norm
from repro.layers.embedding import embed, init_embedding, unembed
from repro.layers.moe import init_moe, moe_forward
from repro.models import transformer as dense
from repro.parallel import constrain

__all__ = ["init_params", "forward", "init_cache", "init_paged_cache",
           "prefill", "prefill_suffix", "decode_step", "paged_decode_step",
           "verify_step", "paged_verify_step", "commit_verified"]


#: Static-auditor registration (:mod:`repro.analysis.targets`): the serve
#: callables this family module exposes, its KV stack key (None = no KV),
#: and whether the paged layout / suffix prefill apply. The auditor
#: enumerates targets from this table, so a family module that grows a new
#: serve entry point must declare it here to be covered by CI.
SERVE_AUDIT = {
    "phases": ("prefill", "decode", "verify", "commit"),
    "paged": True,
    "kv_key": "layers",
    "suffix_prefill": True,
}


def _init_layer(rng, cfg: ModelConfig) -> Params:
    ka, km = jax.random.split(rng)
    return {
        "attn_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
        "attn": attn_lib.init_attention(
            ka, d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=cfg.pdtype),
        "mlp_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
        "moe": init_moe(km, d_model=cfg.d_model, d_ff=cfg.d_ff,
                        n_experts=cfg.n_experts, dtype=cfg.pdtype),
    }


def init_params(rng, cfg: ModelConfig) -> Params:
    ke, kl = jax.random.split(rng)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg))(layer_keys)
    return {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model,
                                tie=cfg.tie_embeddings, dtype=cfg.pdtype),
        "layers": layers,
        "final_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
    }


def _layer_fwd(layer: Params, h, *, cfg: ModelConfig, positions):
    hn = rms_norm(layer["attn_norm"], h)
    a = attn_lib.attention_forward(
        layer["attn"], hn, positions=positions, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, causal=True,
        rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk, impl=cfg.attn_impl, compute_dtype=cfg.cdtype,
        context_parallel=cfg.attn_cp, strategy=cfg.moa_for("attention"))
    h = h + constrain(a, "batch", "seq", "embed")
    hn = rms_norm(layer["mlp_norm"], h)
    m, aux = moe_forward(layer["moe"], hn, n_experts=cfg.n_experts,
                         top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                         compute_dtype=cfg.cdtype,
                         strategy=cfg.moa_for("moe"))
    h = h + constrain(m, "batch", "seq", "embed")
    return h, aux


def forward(params: Params, batch: dict, cfg: ModelConfig):
    """→ (logits, aux_loss_mean)."""
    h = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", "seq", "embed")
    positions = jnp.arange(h.shape[1])

    def body(carry, layer):
        h, aux_sum = carry
        h, aux = _layer_fwd(layer, h, cfg=cfg, positions=positions)
        return (h, aux_sum + aux), None

    (h, aux_sum), _ = lax.scan(dense._remat(body, cfg),
                               (h, jnp.zeros((), jnp.float32)),
                               params["layers"])
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return constrain(logits, "batch", "seq", "vocab"), aux_sum / cfg.n_layers


init_cache = dense.init_cache
init_paged_cache = dense.init_paged_cache


def prefill(params: Params, batch: dict, cfg: ModelConfig, *, max_len: int,
            prompt_len=None):
    """Prefill; ``prompt_len`` as in :func:`repro.models.transformer.prefill`.

    CAVEAT (documented in docs/serving.md): with right-padded prompts the
    pad tokens still compete for expert capacity during prefill, so padded
    MoE prefill is exact only in the dropless regime —
    ``Model.supports_padded_prefill`` gates on
    ``capacity_factor >= n_experts / top_k`` (smoke configs use 8).
    """
    from repro.layers.rope import apply_rope

    h = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", "seq", "embed")
    positions = jnp.arange(h.shape[1])

    def body(carry, layer):
        hn = rms_norm(layer["attn_norm"], carry)
        attn_strategy = cfg.moa_for("attention")
        q, k, v = attn_lib._project_qkv(
            layer["attn"], hn, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            compute_dtype=cfg.cdtype, strategy=attn_strategy)
        q = apply_rope(q, positions, theta=cfg.rope_theta)
        k = apply_rope(k, positions, theta=cfg.rope_theta)
        o = attn_lib.flash_attention(q, k, v, causal=True,
                                     q_chunk=cfg.q_chunk,
                                     kv_chunk=cfg.kv_chunk)
        B, S, _, _ = o.shape
        o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
        h2 = carry + attn_lib._moa_dot(
            o, layer["attn"]["wo"].astype(cfg.cdtype),
            strategy=attn_strategy, compute_dtype=cfg.cdtype)
        hn = rms_norm(layer["mlp_norm"], h2)
        m, _ = moe_forward(layer["moe"], hn, n_experts=cfg.n_experts,
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           compute_dtype=cfg.cdtype,
                           strategy=cfg.moa_for("moe"))
        h2 = h2 + m
        pad = max_len - k.shape[1]

        def pad_seq(t):
            return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

        if cfg.kv_cache_dtype == "int8":
            kq, ks = attn_lib.quantize_kv(k)
            vq, vs = attn_lib.quantize_kv(v)
            kv = attn_lib._constrain_cache(
                {"k": pad_seq(kq), "v": pad_seq(vq),
                 "k_scale": pad_seq(ks), "v_scale": pad_seq(vs)})
        else:
            kv = attn_lib._constrain_cache({"k": pad_seq(k),
                                            "v": pad_seq(v)})
        return h2, kv

    h, kv_layers = lax.scan(dense._remat(body, cfg), h, params["layers"])
    h = rms_norm(params["final_norm"], h)
    h_last, pos = dense._last_real_slice(h, prompt_len)
    logits = unembed(params["embed"], h_last, compute_dtype=cfg.cdtype)
    return (constrain(logits, "batch", None, "vocab"),
            {"layers": kv_layers, "pos": pos})


def prefill_suffix(params: Params, batch: dict, cfg: ModelConfig, *,
                   prefix: Params, prompt_len):
    """Suffix-only prefill behind a cached prefix — the MoE twin of
    :func:`repro.models.transformer.prefill_suffix`.

    The attention path is identical (suffix queries attend over
    ``concat(prefix, suffix)`` with explicit positions); only the MLP is
    the expert layer. Exactness caveat: routing just the suffix through
    the experts matches routing the whole prompt only in the *dropless*
    regime — below it, expert capacity couples the suffix tokens to the
    prefix tokens they no longer see, so
    ``Model.prefill_suffix`` gates MoE on ``supports_padded_prefill``
    (the same ``capacity_factor >= n_experts / top_k`` condition).
    """
    from repro.layers.rope import apply_rope

    P = prefix["k"].shape[2]
    h = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", "seq", "embed")
    S = h.shape[1]
    positions_q = P + jnp.arange(S)
    positions_kv = jnp.arange(P + S)

    def body(carry, xs):
        layer, pre = xs
        hn = rms_norm(layer["attn_norm"], carry)
        attn_strategy = cfg.moa_for("attention")
        q, k, v = attn_lib._project_qkv(
            layer["attn"], hn, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            compute_dtype=cfg.cdtype, strategy=attn_strategy)
        q = apply_rope(q, positions_q, theta=cfg.rope_theta)
        k = apply_rope(k, positions_q, theta=cfg.rope_theta)
        k_full = jnp.concatenate([pre["k"].astype(cfg.cdtype), k], axis=1)
        v_full = jnp.concatenate([pre["v"].astype(cfg.cdtype), v], axis=1)
        o = attn_lib.full_attention(q, k_full, v_full, causal=True,
                                    positions_q=positions_q,
                                    positions_kv=positions_kv)
        B = o.shape[0]
        o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
        o = attn_lib._moa_dot(o, layer["attn"]["wo"].astype(cfg.cdtype),
                              strategy=attn_strategy,
                              compute_dtype=cfg.cdtype)
        h2 = carry + constrain(o, "batch", "seq", "embed")
        hn = rms_norm(layer["mlp_norm"], h2)
        m, _ = moe_forward(layer["moe"], hn, n_experts=cfg.n_experts,
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           compute_dtype=cfg.cdtype,
                           strategy=cfg.moa_for("moe"))
        h2 = h2 + m
        if cfg.kv_cache_dtype == "int8":
            kq, ks = attn_lib.quantize_kv(k)
            vq, vs = attn_lib.quantize_kv(v)
            return h2, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        return h2, {"k": k, "v": v}

    h, kv_layers = lax.scan(dense._remat(body, cfg), h,
                            (params["layers"], prefix))
    h = rms_norm(params["final_norm"], h)
    h_last, pos = dense._last_real_slice(h, prompt_len - P)
    logits = unembed(params["embed"], h_last, compute_dtype=cfg.cdtype)
    cache = {"layers": kv_layers, "pos": jnp.asarray(prompt_len, jnp.int32)}
    return constrain(logits, "batch", "seq", "vocab"), cache


def decode_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    pos = cache["pos"]
    h = embed(params["embed"], tokens, compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", None, "embed")

    def body(carry, xs):
        layer, layer_cache = xs
        hn = rms_norm(layer["attn_norm"], carry)
        a, new_cache = attn_lib.attention_decode(
            layer["attn"], hn, layer_cache, pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, compute_dtype=cfg.cdtype,
            strategy=cfg.moa_for("attention"))
        h2 = carry + constrain(a, "batch", None, "embed")
        hn = rms_norm(layer["mlp_norm"], h2)
        m, _ = moe_forward(layer["moe"], hn, n_experts=cfg.n_experts,
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           compute_dtype=cfg.cdtype,
                           strategy=cfg.moa_for("moe"))
        return h2 + constrain(m, "batch", None, "embed"), new_cache

    h, new_layers = lax.scan(body, h, (params["layers"], cache["layers"]))
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return (constrain(logits, "batch", None, "vocab"),
            {"layers": new_layers, "pos": pos + 1})


def paged_decode_step(params: Params, cache: Params, tokens,
                      cfg: ModelConfig, *, live_blocks=None):
    """Paged decode step (same layout contract as
    :func:`repro.models.transformer.paged_decode_step`); the MoE layers are
    untouched — only the attention KV read/write goes through the block
    tables (bounded to ``live_blocks``, dispatched per
    ``cfg.attn_backend``)."""
    pos, tables = cache["pos"], cache["block_tables"]
    h = embed(params["embed"], tokens, compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", None, "embed")

    def body(carry, xs):
        layer, layer_pool = xs
        hn = rms_norm(layer["attn_norm"], carry)
        a, new_pool = attn_lib.attention_decode_paged(
            layer["attn"], hn, layer_pool, tables, pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, compute_dtype=cfg.cdtype,
            strategy=cfg.moa_for("attention"),
            backend=cfg.attn_backend, live_blocks=live_blocks)
        h2 = carry + constrain(a, "batch", None, "embed")
        hn = rms_norm(layer["mlp_norm"], h2)
        m, _ = moe_forward(layer["moe"], hn, n_experts=cfg.n_experts,
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           compute_dtype=cfg.cdtype,
                           strategy=cfg.moa_for("moe"))
        return h2 + constrain(m, "batch", None, "embed"), new_pool

    h, new_layers = lax.scan(body, h, (params["layers"], cache["layers"]))
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return (constrain(logits, "batch", None, "vocab"),
            {"layers": new_layers, "block_tables": tables, "pos": pos + 1})


# ---------------------------------------------------------------------------
# Speculative verify (docs/spec-decode.md)
# ---------------------------------------------------------------------------
# The dense verify skeleton with the MoE MLP swapped in. Exactness
# caveat: routing a (B, T) window through the experts in one call matches
# T sequential decode steps only in the *dropless* regime — below it,
# expert capacity couples tokens across the window
# (``Model.supports_spec_decode`` gates on exactly this, the same
# condition as padded prefill).


def _moe_mlp_fn(cfg: ModelConfig):
    def mlp_fn(layer, hn):
        m, _ = moe_forward(layer["moe"], hn, n_experts=cfg.n_experts,
                           top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           compute_dtype=cfg.cdtype,
                           strategy=cfg.moa_for("moe"))
        return m
    return mlp_fn


def verify_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    """Score ``tokens (B, T)`` in one call; same contract as
    :func:`repro.models.transformer.verify_step`."""
    return dense.verify_impl(params, cache, tokens, cfg, paged=False,
                             mlp_fn=_moe_mlp_fn(cfg))


def paged_verify_step(params: Params, cache: Params, tokens,
                      cfg: ModelConfig, *, live_blocks=None):
    """Paged twin of :func:`verify_step`; same contract as
    :func:`repro.models.transformer.paged_verify_step`."""
    return dense.verify_impl(params, cache, tokens, cfg, paged=True,
                             mlp_fn=_moe_mlp_fn(cfg),
                             live_blocks=live_blocks)


commit_verified = dense.commit_verified
