"""Dense GQA transformer LM — also the encoder (HuBERT) and VLM backbone.

Structure per layer (pre-norm):  h += attn(rms(h));  h += mlp(rms(h)).
Layers are *stacked* (leading L axis) and executed with ``lax.scan`` so the
HLO is O(1) in depth — llama3-405b's 126 layers compile as one layer.
Remat policy per config. Every contraction routes through the MOA strategy.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.layers import attention as attn_lib
from repro.layers.common import Params, init_rms_norm, rms_norm, split_keys
from repro.layers.embedding import embed, init_embedding, unembed
from repro.layers.mlp import gelu_mlp, init_gelu_mlp, init_swiglu, swiglu
from repro.parallel import constrain

__all__ = [
    "init_params", "forward", "init_cache", "init_paged_cache", "prefill",
    "prefill_suffix", "decode_step", "paged_decode_step", "verify_step",
    "paged_verify_step", "commit_verified", "init_layer", "layer_forward",
]


#: Static-auditor registration (:mod:`repro.analysis.targets`): the serve
#: callables this family module exposes, its KV stack key (None = no KV),
#: and whether the paged layout / suffix prefill apply. The auditor
#: enumerates targets from this table, so a family module that grows a new
#: serve entry point must declare it here to be covered by CI.
SERVE_AUDIT = {
    "phases": ("prefill", "decode", "verify", "commit"),
    "paged": True,
    "kv_key": "layers",
    "suffix_prefill": True,
}


# ---------------------------------------------------------------------------
# Layer
# ---------------------------------------------------------------------------


def init_layer(rng, cfg: ModelConfig) -> Params:
    ka, km = jax.random.split(rng)
    mlp_init = init_gelu_mlp if cfg.family == "encoder" else init_swiglu
    return {
        "attn_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
        "attn": attn_lib.init_attention(
            ka, d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            qkv_bias=cfg.qkv_bias, dtype=cfg.pdtype),
        "mlp_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, cfg.pdtype),
    }


def layer_forward(layer: Params, h, *, cfg: ModelConfig, positions):
    hn = rms_norm(layer["attn_norm"], h)
    a = attn_lib.attention_forward(
        layer["attn"], hn, positions=positions, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        causal=cfg.is_causal, rope_theta=cfg.rope_theta,
        use_rope=(cfg.family != "encoder"), q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk, impl=cfg.attn_impl,
        compute_dtype=cfg.cdtype, context_parallel=cfg.attn_cp,
        strategy=cfg.moa_for("attention"))
    h = h + constrain(a, "batch", "seq", "embed")
    hn = rms_norm(layer["mlp_norm"], h)
    mlp_fn = gelu_mlp if cfg.family == "encoder" else swiglu
    m = mlp_fn(layer["mlp"], hn, strategy=cfg.moa_for("mlp"),
               compute_dtype=cfg.cdtype)
    h = h + constrain(m, "batch", "seq", "embed")
    return h, None


def _remat(f, cfg: ModelConfig):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ModelConfig) -> Params:
    ke, kl = jax.random.split(rng)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    params = {
        "embed": init_embedding(ke, cfg.vocab, cfg.d_model,
                                tie=cfg.tie_embeddings, dtype=cfg.pdtype),
        "layers": layers,
        "final_norm": init_rms_norm(cfg.d_model, cfg.pdtype),
    }
    if cfg.family == "encoder":
        # learned absolute positions (conv-positional stub) + mask embedding
        kp, km2 = jax.random.split(ke)
        pos_len = min(cfg.max_position, 32768)
        params["pos_embed"] = 0.02 * jax.random.normal(
            kp, (pos_len, cfg.d_model), cfg.pdtype)
        params["mask_embed"] = 0.02 * jax.random.normal(
            km2, (cfg.d_model,), cfg.pdtype)
    if cfg.family == "vlm":
        kv2 = jax.random.fold_in(ke, 7)
        params["mm_projector"] = {
            "w": 0.02 * jax.random.normal(
                kv2, (cfg.d_model, cfg.d_model), cfg.pdtype)}
    return params


def _run_layers(params: Params, h, *, cfg: ModelConfig, positions):
    def body(carry, layer):
        out, _ = layer_forward(layer, carry, cfg=cfg, positions=positions)
        return out, None

    h, _ = lax.scan(_remat(body, cfg), h, params["layers"])
    return h


def embed_inputs(params: Params, batch: dict, cfg: ModelConfig):
    """Token (+ modality prefix) embedding → (h, positions, text_offset)."""
    if cfg.family == "encoder":
        frames = batch["frames"].astype(cfg.cdtype)       # (B, T, d) stub
        if "mask" in batch:
            m = batch["mask"][..., None]
            frames = jnp.where(m, params["mask_embed"].astype(cfg.cdtype),
                               frames)
        T = frames.shape[1]
        pos_tab = params["pos_embed"][:T].astype(cfg.cdtype)
        h = frames + pos_tab[None]
        return h, jnp.arange(T), 0
    tok = embed(params["embed"], batch["tokens"], compute_dtype=cfg.cdtype)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(cfg.cdtype)     # (B, P, d) stub
        patches = patches @ params["mm_projector"]["w"].astype(cfg.cdtype)
        h = jnp.concatenate([patches, tok], axis=1)
        S = h.shape[1]
        return h, jnp.arange(S), patches.shape[1]
    S = tok.shape[1]
    return tok, jnp.arange(S), 0


def forward(params: Params, batch: dict, cfg: ModelConfig):
    """Full forward → logits ``(B, S_text, V)`` (VLM: text positions only)."""
    h, positions, text_off = embed_inputs(params, batch, cfg)
    h = constrain(h, "batch", "seq", "embed")
    h = _run_layers(params, h, cfg=cfg, positions=positions)
    h = rms_norm(params["final_norm"], h)
    if text_off:
        h = h[:, text_off:]
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    kv_dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else cfg.cdtype
    one = attn_lib.init_kv_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim,
                                 dtype=kv_dtype)
    return {
        "layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one),
        "pos": jnp.zeros((), jnp.int32),
    }


def init_paged_cache(cfg: ModelConfig, n_slots: int, n_phys_blocks: int,
                     block_size: int, max_blocks: int) -> Params:
    """Paged decode state: one shared physical page pool (per layer) plus
    per-slot block tables and position cursors. Physical block 0 is the
    engine's write-trash page; a zeroed table row therefore maps every
    logical block to trash (the freed-slot state)."""
    kv_dtype = jnp.int8 if cfg.kv_cache_dtype == "int8" else cfg.cdtype
    one = attn_lib.init_kv_pool(n_phys_blocks, block_size, cfg.n_kv_heads,
                                cfg.head_dim, dtype=kv_dtype)
    return {
        "layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one),
        "block_tables": jnp.zeros((n_slots, max_blocks), jnp.int32),
        "pos": jnp.zeros((n_slots,), jnp.int32),
    }


def _layer_prefill(layer: Params, h, *, cfg: ModelConfig, positions, max_len):
    """Layer forward that also emits its (post-rope) K/V for the cache."""
    from repro.layers.rope import apply_rope

    hn = rms_norm(layer["attn_norm"], h)
    attn_strategy = cfg.moa_for("attention")
    q, k, v = attn_lib._project_qkv(
        layer["attn"], hn, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, compute_dtype=cfg.cdtype,
        strategy=attn_strategy)
    q = apply_rope(q, positions, theta=cfg.rope_theta)
    k = apply_rope(k, positions, theta=cfg.rope_theta)
    o = attn_lib.flash_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                                 kv_chunk=cfg.kv_chunk)
    B, S, _, _ = o.shape
    o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
    o = attn_lib._moa_dot(o, layer["attn"]["wo"].astype(cfg.cdtype),
                          strategy=attn_strategy, compute_dtype=cfg.cdtype)
    h = h + constrain(o, "batch", "seq", "embed")
    hn = rms_norm(layer["mlp_norm"], h)
    m = swiglu(layer["mlp"], hn, strategy=cfg.moa_for("mlp"),
               compute_dtype=cfg.cdtype)
    h = h + constrain(m, "batch", "seq", "embed")
    pad = max_len - k.shape[1]

    def pad_seq(t):
        return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))

    if cfg.kv_cache_dtype == "int8":
        kq, ks = attn_lib.quantize_kv(k)
        vq, vs = attn_lib.quantize_kv(v)
        return h, attn_lib._constrain_cache(
            {"k": pad_seq(kq), "v": pad_seq(vq),
             "k_scale": pad_seq(ks), "v_scale": pad_seq(vs)})
    return h, attn_lib._constrain_cache({"k": pad_seq(k), "v": pad_seq(v)})


def _last_real_slice(h, prompt_len):
    """Select the last *real* position of a (possibly right-padded) prefill.

    Returns ``(h_last (B, 1, d), pos scalar int32)``: with ``prompt_len``
    given, ``h_last`` is the hidden state at ``prompt_len - 1`` and ``pos``
    the cache cursor ``prompt_len``; with ``None`` the full sequence is
    real. Shared by the dense and MoE prefill paths so the padded-prefill
    semantics live in one place.
    """
    if prompt_len is None:
        return h[:, -1:], jnp.asarray(h.shape[1], jnp.int32)
    pos = jnp.asarray(prompt_len, jnp.int32)
    return lax.dynamic_slice_in_dim(h, pos - 1, 1, axis=1), pos


def prefill(params: Params, batch: dict, cfg: ModelConfig, *, max_len: int,
            prompt_len=None):
    """Prefill the cache; returns (last-position logits, cache).

    ``prompt_len`` (scalar, tokens): true prompt length when the batch is
    right-padded to a bucketed shape. Positions ``>= prompt_len`` are
    causal-masked garbage; the returned logits are taken at position
    ``prompt_len - 1`` and the cache ``pos`` is set to ``prompt_len`` so
    decode masks (and then overwrites) the padded K/V rows. ``None`` means
    the full sequence is real.
    """
    h, positions, text_off = embed_inputs(params, batch, cfg)
    h = constrain(h, "batch", "seq", "embed")

    def body(carry, layer):
        out, kv = _layer_prefill(layer, carry, cfg=cfg, positions=positions,
                                 max_len=max_len)
        return out, kv

    h, kv_layers = lax.scan(_remat(body, cfg), h, params["layers"])
    h = rms_norm(params["final_norm"], h)
    h_last, pos = _last_real_slice(h, prompt_len)
    logits = unembed(params["embed"], h_last, compute_dtype=cfg.cdtype)
    cache = {"layers": kv_layers, "pos": pos}
    return constrain(logits, "batch", "seq", "vocab"), cache


def prefill_suffix(params: Params, batch: dict, cfg: ModelConfig, *,
                   prefix: Params, prompt_len):
    """Prefill only the *suffix* of a prompt whose leading blocks hit the
    prefix cache; returns (last-position logits, suffix cache).

    ``prefix`` holds the cached prefix K/V gathered from the paged pool:
    ``{"k", "v"}: (L, 1, P, Hk, D)`` in compute dtype (dequantized if the
    pool is int8). ``batch["tokens"]`` carries the remaining suffix tokens,
    right-padded to a block-aligned bucket; ``prompt_len`` (scalar) is the
    *total* true prompt length, so the suffix occupies positions
    ``P .. prompt_len - 1``. Suffix queries attend over
    ``concat(prefix, suffix)`` with explicit positions — padded suffix K/V
    rows sit at positions ``>= prompt_len`` and are causally masked away.
    This is the compute a prefix-cache hit *skips*: the prefix's O(P·L)
    projection + attention work is never redone.
    """
    from repro.layers.rope import apply_rope

    P = prefix["k"].shape[2]
    h, _, _ = embed_inputs(params, batch, cfg)
    h = constrain(h, "batch", "seq", "embed")
    S = h.shape[1]
    positions_q = P + jnp.arange(S)
    positions_kv = jnp.arange(P + S)

    def body(carry, xs):
        layer, pre = xs
        hn = rms_norm(layer["attn_norm"], carry)
        attn_strategy = cfg.moa_for("attention")
        q, k, v = attn_lib._project_qkv(
            layer["attn"], hn, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            compute_dtype=cfg.cdtype, strategy=attn_strategy)
        q = apply_rope(q, positions_q, theta=cfg.rope_theta)
        k = apply_rope(k, positions_q, theta=cfg.rope_theta)
        k_full = jnp.concatenate([pre["k"].astype(cfg.cdtype), k], axis=1)
        v_full = jnp.concatenate([pre["v"].astype(cfg.cdtype), v], axis=1)
        o = attn_lib.full_attention(q, k_full, v_full, causal=True,
                                    positions_q=positions_q,
                                    positions_kv=positions_kv)
        B = o.shape[0]
        o = o.reshape(B, S, cfg.n_heads * cfg.head_dim)
        o = attn_lib._moa_dot(o, layer["attn"]["wo"].astype(cfg.cdtype),
                              strategy=attn_strategy,
                              compute_dtype=cfg.cdtype)
        h2 = carry + constrain(o, "batch", "seq", "embed")
        hn = rms_norm(layer["mlp_norm"], h2)
        m = swiglu(layer["mlp"], hn, strategy=cfg.moa_for("mlp"),
                   compute_dtype=cfg.cdtype)
        h2 = h2 + constrain(m, "batch", "seq", "embed")
        if cfg.kv_cache_dtype == "int8":
            kq, ks = attn_lib.quantize_kv(k)
            vq, vs = attn_lib.quantize_kv(v)
            return h2, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        return h2, {"k": k, "v": v}

    h, kv_layers = lax.scan(_remat(body, cfg), h, (params["layers"], prefix))
    h = rms_norm(params["final_norm"], h)
    h_last, pos = _last_real_slice(h, prompt_len - P)
    logits = unembed(params["embed"], h_last, compute_dtype=cfg.cdtype)
    cache = {"layers": kv_layers, "pos": jnp.asarray(prompt_len, jnp.int32)}
    return constrain(logits, "batch", "seq", "vocab"), cache


def decode_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    """One token step for the whole batch. ``tokens: (B, 1)`` int32."""
    pos = cache["pos"]
    h = embed(params["embed"], tokens, compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", None, "embed")

    def body(carry, xs):
        layer, layer_cache = xs
        hn = rms_norm(layer["attn_norm"], carry)
        a, new_cache = attn_lib.attention_decode(
            layer["attn"], hn, layer_cache, pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, compute_dtype=cfg.cdtype,
            strategy=cfg.moa_for("attention"))
        h2 = carry + a
        hn = rms_norm(layer["mlp_norm"], h2)
        mlp_fn = gelu_mlp if cfg.family == "encoder" else swiglu
        m = mlp_fn(layer["mlp"], hn, strategy=cfg.moa_for("mlp"),
                   compute_dtype=cfg.cdtype)
        return h2 + m, new_cache

    h, new_layers = lax.scan(body, h, (params["layers"], cache["layers"]))
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    new_cache = {"layers": new_layers, "pos": pos + 1}
    return constrain(logits, "batch", None, "vocab"), new_cache


def paged_decode_step(params: Params, cache: Params, tokens,
                      cfg: ModelConfig, *, live_blocks=None):
    """One token step against the paged pool (``init_paged_cache`` layout).

    Same layer scan as :func:`decode_step`; the KV read/write is routed
    through per-slot block tables, so the step's math — and its greedy
    continuation — is bit-identical to the dense-slot path (the gathered
    logical view has exactly the dense cache's shape; see
    ``docs/paged-kv.md``). ``live_blocks`` (static) bounds the KV stream to
    the batch's high-water logical block; ``cfg.attn_backend`` picks the
    gather-based jnp path or the fused Pallas block-table kernel.
    """
    pos, tables = cache["pos"], cache["block_tables"]
    h = embed(params["embed"], tokens, compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", None, "embed")

    def body(carry, xs):
        layer, layer_pool = xs
        hn = rms_norm(layer["attn_norm"], carry)
        a, new_pool = attn_lib.attention_decode_paged(
            layer["attn"], hn, layer_pool, tables, pos, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta, compute_dtype=cfg.cdtype,
            strategy=cfg.moa_for("attention"),
            backend=cfg.attn_backend, live_blocks=live_blocks)
        h2 = carry + a
        hn = rms_norm(layer["mlp_norm"], h2)
        mlp_fn = gelu_mlp if cfg.family == "encoder" else swiglu
        m = mlp_fn(layer["mlp"], hn, strategy=cfg.moa_for("mlp"),
                   compute_dtype=cfg.cdtype)
        return h2 + m, new_pool

    h, new_layers = lax.scan(body, h, (params["layers"], cache["layers"]))
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    new_cache = {"layers": new_layers, "block_tables": tables,
                 "pos": pos + 1}
    return constrain(logits, "batch", None, "vocab"), new_cache


# ---------------------------------------------------------------------------
# Speculative verify (docs/spec-decode.md)
# ---------------------------------------------------------------------------


def _verify_scan(params: Params, cache: Params, tokens, cfg: ModelConfig,
                 attn_fn, mlp_fn):
    """Shared T-token verify skeleton: ``tokens (B, T)`` scored in one
    forward, each slot's window starting at its own ``pos`` cursor.
    ``attn_fn(layer, hn, layer_cache) -> (attn_out, new_layer_cache)``
    abstracts the dense-vs-paged KV read/write; ``mlp_fn(layer, hn)`` the
    dense-vs-MoE MLP."""
    h = embed(params["embed"], tokens, compute_dtype=cfg.cdtype)
    h = constrain(h, "batch", None, "embed")

    def body(carry, xs):
        layer, layer_cache = xs
        hn = rms_norm(layer["attn_norm"], carry)
        a, new_cache = attn_fn(layer, hn, layer_cache)
        h2 = carry + a
        hn = rms_norm(layer["mlp_norm"], h2)
        return h2 + mlp_fn(layer, hn), new_cache

    h, new_layers = lax.scan(body, h, (params["layers"], cache["layers"]))
    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h, compute_dtype=cfg.cdtype)
    return constrain(logits, "batch", None, "vocab"), new_layers


def verify_impl(params: Params, cache: Params, tokens, cfg: ModelConfig, *,
                paged: bool, mlp_fn=None, live_blocks=None):
    """Verify implementation shared by the dense and MoE families (which
    differ only in the MLP block); ``paged`` selects the KV read/write
    path (``live_blocks`` bounds its KV stream, as in
    :func:`paged_decode_step`). See :func:`verify_step` for the contract."""
    if mlp_fn is None:
        def mlp_fn(layer, hn):
            return swiglu(layer["mlp"], hn, strategy=cfg.moa_for("mlp"),
                          compute_dtype=cfg.cdtype)
    pos = cache["pos"]
    if paged:
        tables = cache["block_tables"]

        def attn_fn(layer, hn, layer_pool):
            return attn_lib.attention_verify_paged(
                layer["attn"], hn, layer_pool, tables, pos,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                compute_dtype=cfg.cdtype,
                strategy=cfg.moa_for("attention"),
                backend=cfg.attn_backend, live_blocks=live_blocks)
    else:
        def attn_fn(layer, hn, layer_cache):
            return attn_lib.attention_verify(
                layer["attn"], hn, layer_cache, pos, n_heads=cfg.n_heads,
                n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
                rope_theta=cfg.rope_theta, compute_dtype=cfg.cdtype,
                strategy=cfg.moa_for("attention"))

    logits, new_layers = _verify_scan(params, cache, tokens, cfg, attn_fn,
                                      mlp_fn)
    new_cache = {"layers": new_layers, "pos": pos}
    if paged:
        new_cache["block_tables"] = tables
    return logits, new_cache, None


def verify_step(params: Params, cache: Params, tokens, cfg: ModelConfig):
    """Score ``T`` tokens per slot in one call (speculative verify).

    ``tokens (B, T)``: column 0 is each slot's pending next token, columns
    ``1..T-1`` the drafted continuation. All T K/V entries are written
    *tentatively* and logits are returned at every position — logits
    ``[:, i]`` bit-match the ``i``-th of T sequential :func:`decode_step`
    calls. The returned cache's ``pos`` stays at the pre-verify cursor;
    :func:`commit_verified` advances it by the per-slot accepted length,
    which is the whole rewind story for position-addressed KV (rejected
    rows are masked garbage until overwritten, same as freed-slot rows).
    Returns ``(logits (B, T, V), cache, aux)`` with ``aux=None`` (no
    recurrent state in this family).
    """
    return verify_impl(params, cache, tokens, cfg, paged=False)


def paged_verify_step(params: Params, cache: Params, tokens,
                      cfg: ModelConfig, *, live_blocks=None):
    """Paged twin of :func:`verify_step` (``init_paged_cache`` layout).

    Tentative writes scatter through the block tables; the engine's
    admission margin guarantees they land on slot-private pages (or the
    trash page), so rejection rolls back by rewinding ``pos`` alone.
    ``live_blocks`` must cover the deepest slot's cursor *plus the verify
    window* (the engine adds the margin).
    """
    return verify_impl(params, cache, tokens, cfg, paged=True,
                       live_blocks=live_blocks)


def commit_verified(cache: Params, keep, aux, cfg: ModelConfig) -> Params:
    """Advance each slot's cursor past its accepted tokens.

    ``keep (B,)``: accepted drafts + 1 for active slots (at least the
    pending token survives), 0 for idle slots. ``aux`` is unused — the KV
    cache is position-addressed, so the cursor *is* the rollback.
    """
    del aux
    new_cache = dict(cache)
    new_cache["pos"] = cache["pos"] + keep.astype(cache["pos"].dtype)
    return new_cache
