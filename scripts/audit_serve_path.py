#!/usr/bin/env python3
"""Static serve-path audit: jaxpr invariants + repo lint, one CI gate.

Traces every serve-path callable (families × dense/paged × mesh/no-mesh)
without executing it and checks the lowered jaxprs against the repo
invariants, then lints the source tree. Prints each violation with its
source site and exits 1 if any are found. See docs/static-analysis.md
for the rule catalog.

  PYTHONPATH=src python scripts/audit_serve_path.py
  PYTHONPATH=src python scripts/audit_serve_path.py --json report.json
  PYTHONPATH=src python scripts/audit_serve_path.py --families ssm,hybrid
  PYTHONPATH=src python scripts/audit_serve_path.py --cost \\
      --cost-json cost-report.json

``--cost`` additionally walks every target's jaxpr with trip-count-aware
FLOP/byte accounting and reconciles it against the analytic model in
``launch/costing.py`` (rules ``audit-cost-drift`` /
``audit-unbounded-loop``); ``--cost-json`` writes the per-target
``analysis-v2`` record. ``--json`` writes a schema-tagged ``analysis-v1``
record; both reports self-validate against the registry in
``check_bench_schema.py`` before exiting, so a malformed report can
never slip through CI as a pass. Exit status is 1 only on error-severity
violations — warnings (diagnostics on unchecked helper targets) print
but do not gate.
"""

from __future__ import annotations

import argparse
import importlib.util
import json
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_schema_registry():
    """scripts/ is not a package; load the validator by file path."""
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "check_bench_schema.py")
    spec = importlib.util.spec_from_file_location("check_bench_schema", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _self_validated_dump(report, path) -> bool:
    errors = _load_schema_registry().validate(report)
    if errors:
        for e in errors:
            print(f"INTERNAL: report fails its own schema: {e}",
                  file=sys.stderr)
        return False
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path} ({report['schema']})")
    return True


def main(argv=None) -> int:
    from repro.analysis import (SERVE_FAMILIES, audit_targets, build_report,
                                build_cost_report, cost_audit_targets,
                                enumerate_targets, run_lint, summarize)
    from repro.analysis.cost_audit import FLOPS_RTOL, KV_BYTES_RTOL

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--families", default=",".join(SERVE_FAMILIES),
                    help="comma-separated families to audit")
    ap.add_argument("--mesh-modes", default="none,mesh",
                    help="comma-separated subset of: none, mesh")
    ap.add_argument("--skip-lint", action="store_true",
                    help="jaxpr audit only")
    ap.add_argument("--skip-jaxpr", action="store_true",
                    help="lint only")
    ap.add_argument("--cost", action="store_true",
                    help="trip-count-aware static cost audit reconciled "
                         "against launch/costing.py")
    ap.add_argument("--json", metavar="PATH",
                    help="write a schema-validated analysis-v1 report")
    ap.add_argument("--cost-json", metavar="PATH",
                    help="write a schema-validated analysis-v2 cost report "
                         "(implies --cost)")
    args = ap.parse_args(argv)
    if args.cost_json:
        args.cost = True

    families = tuple(f for f in args.families.split(",") if f)
    mesh_modes = tuple(m for m in args.mesh_modes.split(",") if m)
    unknown = set(families) - set(SERVE_FAMILIES)
    if unknown:
        ap.error(f"unknown families: {sorted(unknown)}")

    t0 = time.time()
    violations, targets = [], []
    if not args.skip_jaxpr:
        targets = enumerate_targets(families=families, mesh_modes=mesh_modes)
        print(f"auditing {len(targets)} serve-path targets "
              f"({len(families)} families x {mesh_modes})...")
        violations.extend(audit_targets(targets))

    files_linted = 0
    if not args.skip_lint:
        lint_violations, files_linted = run_lint(REPO_ROOT)
        print(f"linted {files_linted} source files")
        violations.extend(lint_violations)

    cost_records, cost_violations = [], []
    if args.cost:
        cost_targets = targets or enumerate_targets(
            families=families, mesh_modes=mesh_modes)
        print(f"cost-auditing {len(cost_targets)} targets against "
              "launch/costing.py...")
        cost_records, cost_violations = cost_audit_targets(cost_targets)
        checked = sum(1 for r in cost_records if r["drift_checked"])
        unbounded = sum(r["loops"]["unbounded"] for r in cost_records)
        print(f"cost audit: {len(cost_records)} targets, {checked} "
              f"drift-checked, {unbounded} unbounded loops")
        violations.extend(cost_violations)

    for v in violations:
        print(v.format())
    print(f"{summarize(violations)} [{time.time() - t0:.1f}s]")

    if args.json:
        report = build_report(
            violations, targets_audited=len(targets),
            files_linted=files_linted,
            config={"families": list(families),
                    "mesh_modes": list(mesh_modes),
                    "skip_lint": args.skip_lint,
                    "skip_jaxpr": args.skip_jaxpr,
                    "cost": args.cost})
        if not _self_validated_dump(report, args.json):
            return 2
    if args.cost_json:
        cost_report = build_cost_report(
            cost_records, cost_violations,
            config={"families": list(families),
                    "mesh_modes": list(mesh_modes),
                    "flops_rtol": FLOPS_RTOL,
                    "kv_bytes_rtol": KV_BYTES_RTOL})
        if not _self_validated_dump(cost_report, args.cost_json):
            return 2

    return 1 if any(v.severity == "error" for v in violations) else 0


if __name__ == "__main__":
    sys.exit(main())
