#!/usr/bin/env python3
"""Markdown link check: every relative link in README.md + docs/ resolves.

Stdlib-only (runs in CI without extra deps). External (http/https/mailto)
links are not fetched — only intra-repo targets are verified, anchors
stripped. Exit code 1 with a per-link report on any broken target.

  python scripts/check_md_links.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — skips images' leading ! capture-wise irrelevant; ignores
# fenced code blocks below
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SCHEMES = ("http://", "https://", "mailto:", "ftp://")


def _links(text: str):
    in_fence = False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        yield from _LINK.findall(line)


def check(root: pathlib.Path) -> int:
    files = [root / "README.md"] + sorted((root / "docs").glob("**/*.md"))
    broken = []
    for md in files:
        if not md.exists():
            broken.append((md, "<file missing>"))
            continue
        for target in _links(md.read_text()):
            if target.startswith(_SCHEMES) or target.startswith("#"):
                continue
            rel = target.split("#")[0]
            if not (md.parent / rel).exists():
                broken.append((md, target))
    for md, target in broken:
        print(f"BROKEN {md.relative_to(root)}: {target}")
    print(f"checked {len(files)} files; {len(broken)} broken links")
    return 1 if broken else 0


if __name__ == "__main__":
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    sys.exit(check(root))
