#!/usr/bin/env python3
"""Markdown link check + docs coverage: every relative link in README.md +
docs/ resolves, every ``*.md`` file a ``src/`` docstring or comment cites
exists, and every ``src/repro/`` package is mentioned in at least one
``docs/`` page (no orphan subsystems — the docs tree is the map).

Stdlib-only (runs in CI without extra deps). External (http/https/mailto)
links are not fetched — only intra-repo targets are verified, anchors
stripped. Source references are resolved against the repo root (regression
guard: docstrings once cited an EXPERIMENTS.md that never existed). A
package counts as documented when some docs page names it as
``repro.<pkg>`` or ``<pkg>/``. Exit code 1 with a per-link / per-orphan
report on any violation.

  python scripts/check_md_links.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — skips images' leading ! capture-wise irrelevant; ignores
# fenced code blocks below
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SCHEMES = ("http://", "https://", "mailto:", "ftp://")
# a .md path mentioned anywhere in Python source (docstrings, comments)
_SRC_MD_REF = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.md\b")


def _links(text: str):
    in_fence = False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        yield from _LINK.findall(line)


def _src_md_refs(root: pathlib.Path):
    """Yield (py_file, referenced .md path) pairs from src/ sources."""
    for py in sorted((root / "src").glob("**/*.py")):
        for line_no, line in enumerate(py.read_text().splitlines(), 1):
            for ref in _SRC_MD_REF.findall(line):
                yield py, line_no, ref


def _doc_orphans(root: pathlib.Path):
    """``src/repro`` packages never mentioned in any docs page.

    A package is any ``src/repro/`` subdirectory holding Python sources;
    a mention is ``repro.<pkg>`` or ``<pkg>/`` anywhere in ``docs/``.
    """
    pkg_root = root / "src" / "repro"
    pkgs = sorted(d.name for d in pkg_root.iterdir()
                  if d.is_dir() and any(d.glob("*.py")))
    docs_text = "\n".join(p.read_text()
                          for p in sorted((root / "docs").glob("**/*.md")))
    orphans = [p for p in pkgs
               if f"repro.{p}" not in docs_text
               and f"{p}/" not in docs_text]
    return pkgs, orphans


def check(root: pathlib.Path) -> int:
    files = [root / "README.md"] + sorted((root / "docs").glob("**/*.md"))
    broken = []
    for md in files:
        if not md.exists():
            broken.append((md, "<file missing>"))
            continue
        for target in _links(md.read_text()):
            if target.startswith(_SCHEMES) or target.startswith("#"):
                continue
            rel = target.split("#")[0]
            if not (md.parent / rel).exists():
                broken.append((md, target))
    n_refs = 0
    for py, line_no, ref in _src_md_refs(root):
        n_refs += 1
        if not (root / ref).exists():
            broken.append((pathlib.Path(f"{py}:{line_no}"), ref))
    for md, target in broken:
        try:
            name = md.relative_to(root)
        except ValueError:
            name = md
        print(f"BROKEN {name}: {target}")
    pkgs, orphans = _doc_orphans(root)
    for pkg in orphans:
        print(f"ORPHAN src/repro/{pkg}: not mentioned in any docs/ page")
    print(f"checked {len(files)} markdown files + {n_refs} source "
          f"references + {len(pkgs)} packages; {len(broken)} broken, "
          f"{len(orphans)} undocumented")
    return 1 if broken or orphans else 0


if __name__ == "__main__":
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    sys.exit(check(root))
