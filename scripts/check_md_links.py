#!/usr/bin/env python3
"""Markdown link check: every relative link in README.md + docs/ resolves,
and every ``*.md`` file a ``src/`` docstring or comment cites exists.

Stdlib-only (runs in CI without extra deps). External (http/https/mailto)
links are not fetched — only intra-repo targets are verified, anchors
stripped. Source references are resolved against the repo root (regression
guard: docstrings once cited an EXPERIMENTS.md that never existed). Exit
code 1 with a per-link report on any broken target.

  python scripts/check_md_links.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — skips images' leading ! capture-wise irrelevant; ignores
# fenced code blocks below
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SCHEMES = ("http://", "https://", "mailto:", "ftp://")
# a .md path mentioned anywhere in Python source (docstrings, comments)
_SRC_MD_REF = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.md\b")


def _links(text: str):
    in_fence = False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        yield from _LINK.findall(line)


def _src_md_refs(root: pathlib.Path):
    """Yield (py_file, referenced .md path) pairs from src/ sources."""
    for py in sorted((root / "src").glob("**/*.py")):
        for line_no, line in enumerate(py.read_text().splitlines(), 1):
            for ref in _SRC_MD_REF.findall(line):
                yield py, line_no, ref


def check(root: pathlib.Path) -> int:
    files = [root / "README.md"] + sorted((root / "docs").glob("**/*.md"))
    broken = []
    for md in files:
        if not md.exists():
            broken.append((md, "<file missing>"))
            continue
        for target in _links(md.read_text()):
            if target.startswith(_SCHEMES) or target.startswith("#"):
                continue
            rel = target.split("#")[0]
            if not (md.parent / rel).exists():
                broken.append((md, target))
    n_refs = 0
    for py, line_no, ref in _src_md_refs(root):
        n_refs += 1
        if not (root / ref).exists():
            broken.append((pathlib.Path(f"{py}:{line_no}"), ref))
    for md, target in broken:
        try:
            name = md.relative_to(root)
        except ValueError:
            name = md
        print(f"BROKEN {name}: {target}")
    print(f"checked {len(files)} markdown files + {n_refs} source "
          f"references; {len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    sys.exit(check(root))
