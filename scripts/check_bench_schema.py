#!/usr/bin/env python3
"""Validate serving benchmark JSON records (``serving-v1`` / ``serving-v2``
/ ``serving-v3`` / ``serving-v4``).

Stdlib-only (runs in CI without extra deps). Checks required keys and
value types — extra keys are allowed (schemas grow forward-compatibly),
missing or mistyped ones fail with a per-field report. Exit 1 on any
violation.

  python scripts/check_bench_schema.py out.json [more.json ...]
"""

from __future__ import annotations

import json
import numbers
import sys

NUM = numbers.Real      # int or float (bool excluded below)
STR = str

_DIST = {"mean": NUM, "p50": NUM, "p95": NUM}

_REQUEST = {
    "uid": int, "prompt_tokens": int, "new_tokens": int, "slot": int,
    "finish_reason": STR, "arrival_s": NUM, "admitted_s": NUM,
    "ttft_ms": NUM, "per_token_ms": NUM, "tok_per_s": NUM,
    "moa_flops": NUM, "cached_prompt_tokens": int,
}

_AGGREGATE = {
    "n_requests": int, "n_slots": int, "decode_steps": int, "wall_s": NUM,
    "compile_s": NUM, "total_new_tokens": int, "tok_per_s": NUM,
    "ttft_ms": _DIST, "per_token_ms": _DIST, "slot_occupancy": NUM,
    "moa_flops_total": NUM, "slot_reuse": int, "arch": STR, "moa": STR,
}

_PAGED_AGGREGATE = {
    "block_size": int, "n_blocks": int, "admissions": int,
    "prefix_hits": int, "prefix_hit_rate": NUM, "shared_block_hits": int,
    "cow_count": int, "block_occupancy": NUM, "peak_blocks_in_use": int,
    "resident_kv_bytes": NUM, "dense_equiv_kv_bytes": NUM,
}

_CONFIG_V1 = {
    "arch": STR, "family": STR, "smoke": bool, "moa": STR, "n_slots": int,
    "max_len": int, "requests": int, "rate_rps": NUM,
    "prompt_len_range": list, "gen_len_range": list, "temperature": NUM,
    "seed": int, "warmup": bool,
}

_CONFIG_V2 = dict(_CONFIG_V1, block_size=int, n_blocks=int,
                  shared_prefix=bool, prefix_len=int, n_prefixes=int)

_COMPARISON = {
    "ttft_p50_ms_dense": NUM, "ttft_p50_ms_paged": NUM, "prefix_hits": int,
    "prefix_hit_rate": NUM, "cached_prompt_tokens": int,
    "resident_kv_bytes": NUM, "dense_equiv_kv_bytes": NUM,
}

_CONFIG_V3 = dict(_CONFIG_V1, spec_k=int, accept_probs=list, drafter=STR)

_SPEC_AGGREGATE = {
    "k": int, "verify_ticks": int, "emitted_tokens": int,
    "tokens_per_step": NUM, "accepted_hist": list, "accept_rate": NUM,
    "mean_accepted": NUM, "draft_steps": int, "draft_steps_per_tick": NUM,
}

_SPEC_POINT = {
    "accept_prob": NUM, "measured_accept_rate": NUM, "tokens_per_step": NUM,
    "speedup_vs_plain": NUM, "predicted_tokens_per_step": NUM,
    "predicted_flops_overhead": NUM, "ttft_p50_ms": NUM,
}

_SPEC_COMPARISON = {
    "tokens_per_step_plain": NUM, "ttft_p50_ms_plain": NUM,
    "best_tokens_per_step": NUM, "best_accept_prob": NUM,
}

_CONFIG_V4 = dict(_CONFIG_V1,
                  mesh={"shape": list, "axes": list, "n_devices": int})

_V4_COMPARISON = {
    "greedy_tokens_match": bool, "tok_per_s_single": NUM,
    "tok_per_s_sharded": NUM, "sharded_speedup": NUM,
    "ttft_p50_ms_single": NUM, "ttft_p50_ms_sharded": NUM,
    "compile_s_single": NUM, "compile_s_sharded": NUM,
}


def _check(record, schema, path, errors):
    """Recursively check required keys + types (dict schemas nest)."""
    if not isinstance(record, dict):
        errors.append(f"{path}: expected object, got {type(record).__name__}")
        return
    for key, want in schema.items():
        if key not in record:
            errors.append(f"{path}.{key}: missing")
            continue
        got = record[key]
        if isinstance(want, dict):
            _check(got, want, f"{path}.{key}", errors)
        elif want is bool:
            if not isinstance(got, bool):
                errors.append(f"{path}.{key}: expected bool, "
                              f"got {type(got).__name__}")
        elif want is int:
            if isinstance(got, bool) or not isinstance(got, int):
                errors.append(f"{path}.{key}: expected int, "
                              f"got {type(got).__name__}")
        elif isinstance(got, bool) or not isinstance(got, want):
            errors.append(f"{path}.{key}: expected "
                          f"{getattr(want, '__name__', want)}, "
                          f"got {type(got).__name__}")


def _check_run(run, path, errors):
    _check(run, {"aggregate": _AGGREGATE}, path, errors)
    reqs = run.get("requests")
    if not isinstance(reqs, list) or not reqs:
        errors.append(f"{path}.requests: expected non-empty list")
        return
    for i, r in enumerate(reqs):
        _check(r, _REQUEST, f"{path}.requests[{i}]", errors)


def validate(record: dict) -> list:
    """Return a list of violations (empty = valid)."""
    errors: list = []
    schema = record.get("schema")
    if schema == "serving-v1":
        _check(record, {"config": _CONFIG_V1}, "$", errors)
        _check_run(record, "$", errors)
    elif schema == "serving-v2":
        _check(record, {"config": _CONFIG_V2, "comparison": _COMPARISON},
               "$", errors)
        for mode in ("dense", "paged"):
            _check_run(record.get(mode, {}), f"$.{mode}", errors)
        paged_agg = record.get("paged", {}).get("aggregate", {})
        _check(paged_agg.get("paged", {}), _PAGED_AGGREGATE,
               "$.paged.aggregate.paged", errors)
    elif schema == "serving-v3":
        _check(record, {"config": _CONFIG_V3,
                        "comparison": _SPEC_COMPARISON}, "$", errors)
        _check_run(record.get("plain", {}), "$.plain", errors)
        runs = record.get("spec_runs")
        if not isinstance(runs, list) or not runs:
            errors.append("$.spec_runs: expected non-empty list")
        else:
            for i, sr in enumerate(runs):
                path = f"$.spec_runs[{i}]"
                _check(sr, {"accept_prob": NUM}, path, errors)
                _check_run(sr, path, errors)
                _check(sr.get("aggregate", {}).get("spec", {}),
                       _SPEC_AGGREGATE, f"{path}.aggregate.spec", errors)
        curve = record.get("comparison", {}).get("curve")
        if not isinstance(curve, list) or not curve:
            errors.append("$.comparison.curve: expected non-empty list")
        else:
            for i, pt in enumerate(curve):
                _check(pt, _SPEC_POINT, f"$.comparison.curve[{i}]", errors)
    elif schema == "serving-v4":
        _check(record, {"config": _CONFIG_V4,
                        "comparison": _V4_COMPARISON}, "$", errors)
        for mode in ("single", "sharded"):
            _check_run(record.get(mode, {}), f"$.{mode}", errors)
        mesh = record.get("config", {}).get("mesh", {})
        if isinstance(mesh, dict):
            shape, n = mesh.get("shape"), mesh.get("n_devices")
            if isinstance(shape, list) and isinstance(n, int):
                prod = 1
                for s in shape:
                    prod *= s if isinstance(s, int) else 0
                if prod != n:
                    errors.append("$.config.mesh: shape does not multiply "
                                  f"to n_devices ({shape} vs {n})")
    else:
        errors.append(f"$.schema: unknown schema {schema!r} (expected "
                      "serving-v1, serving-v2, serving-v3 or serving-v4)")
    return errors


def main(paths) -> int:
    if not paths:
        print("usage: check_bench_schema.py RECORD.json [...]",
              file=sys.stderr)
        return 2
    bad = 0
    for p in paths:
        try:
            with open(p) as f:
                record = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"INVALID {p}: {e}")
            bad += 1
            continue
        errors = validate(record)
        for e in errors:
            print(f"INVALID {p}: {e}")
        if errors:
            bad += 1
        else:
            print(f"ok {p}: {record['schema']}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
