#!/usr/bin/env python3
"""Validate repo JSON records against the schema registry.

Every machine-readable artifact the repo emits carries a ``schema`` tag —
serving benchmark records (``serving-v1`` .. ``serving-v7``) and the
static-analysis reports (``analysis-v1`` invariants, ``analysis-v2``
cost audit). Each schema registers a
validator in :data:`SCHEMAS` via :func:`register`; adding a new record
format means adding one decorated function here.

Stdlib-only (runs in CI without extra deps). Checks required keys and
value types — extra keys are allowed (schemas grow forward-compatibly),
missing or mistyped ones fail with a per-field report. Exit 1 on any
violation.

  python scripts/check_bench_schema.py RECORD.json [more.json ...]
"""

from __future__ import annotations

import json
import numbers
import sys
from typing import Callable, Dict, List

NUM = numbers.Real      # int or float (bool excluded below)
STR = str

_DIST = {"mean": NUM, "p50": NUM, "p95": NUM, "p99": NUM}

_REQUEST = {
    "uid": int, "prompt_tokens": int, "new_tokens": int, "slot": int,
    "finish_reason": STR, "arrival_s": NUM, "admitted_s": NUM,
    "ttft_ms": NUM, "per_token_ms": NUM, "tok_per_s": NUM,
    "moa_flops": NUM, "cached_prompt_tokens": int,
}

_AGGREGATE = {
    "n_requests": int, "n_slots": int, "decode_steps": int, "wall_s": NUM,
    "compile_s": NUM, "total_new_tokens": int, "tok_per_s": NUM,
    "ttft_ms": _DIST, "per_token_ms": _DIST, "slot_occupancy": NUM,
    "moa_flops_total": NUM, "slot_reuse": int, "arch": STR, "moa": STR,
}

_PAGED_AGGREGATE = {
    "block_size": int, "n_blocks": int, "admissions": int,
    "prefix_hits": int, "prefix_hit_rate": NUM, "shared_block_hits": int,
    "cow_count": int, "block_occupancy": NUM, "peak_blocks_in_use": int,
    "resident_kv_bytes": NUM, "dense_equiv_kv_bytes": NUM,
    "attn_backend": STR, "gathered_kv_bytes": NUM, "fused_kv_bytes": NUM,
    "gathered_kv_bytes_per_step": NUM, "fused_kv_bytes_per_step": NUM,
}

_CONFIG_V1 = {
    "arch": STR, "family": STR, "smoke": bool, "moa": STR, "n_slots": int,
    "max_len": int, "requests": int, "rate_rps": NUM,
    "prompt_len_range": list, "gen_len_range": list, "temperature": NUM,
    "seed": int, "warmup": bool,
}

_CONFIG_V2 = dict(_CONFIG_V1, block_size=int, n_blocks=int,
                  shared_prefix=bool, prefix_len=int, n_prefixes=int)

_COMPARISON = {
    "ttft_p50_ms_dense": NUM, "ttft_p50_ms_paged": NUM, "prefix_hits": int,
    "prefix_hit_rate": NUM, "cached_prompt_tokens": int,
    "resident_kv_bytes": NUM, "dense_equiv_kv_bytes": NUM,
}

_CONFIG_V3 = dict(_CONFIG_V1, spec_k=int, accept_probs=list, drafter=STR)

_SPEC_AGGREGATE = {
    "k": int, "verify_ticks": int, "emitted_tokens": int,
    "tokens_per_step": NUM, "accepted_hist": list, "accept_rate": NUM,
    "mean_accepted": NUM, "draft_steps": int, "draft_steps_per_tick": NUM,
}

_SPEC_POINT = {
    "accept_prob": NUM, "measured_accept_rate": NUM, "tokens_per_step": NUM,
    "speedup_vs_plain": NUM, "predicted_tokens_per_step": NUM,
    "predicted_flops_overhead": NUM, "ttft_p50_ms": NUM,
}

_SPEC_COMPARISON = {
    "tokens_per_step_plain": NUM, "ttft_p50_ms_plain": NUM,
    "best_tokens_per_step": NUM, "best_accept_prob": NUM,
}

_CONFIG_V4 = dict(_CONFIG_V1,
                  mesh={"shape": list, "axes": list, "n_devices": int})

_V4_COMPARISON = {
    "greedy_tokens_match": bool, "tok_per_s_single": NUM,
    "tok_per_s_sharded": NUM, "sharded_speedup": NUM,
    "ttft_p50_ms_single": NUM, "ttft_p50_ms_sharded": NUM,
    "compile_s_single": NUM, "compile_s_sharded": NUM,
}

_CONFIG_V6 = dict(_CONFIG_V1, block_size=int, n_blocks=int,
                  shared_prefix=bool, backends=list, default_backend=STR)

_V6_COMPARISON = {
    "greedy_tokens_match": bool, "tok_per_s_jnp": NUM,
    "tok_per_s_pallas": NUM, "pallas_speedup": NUM,
    "ttft_p50_ms_jnp": NUM, "ttft_p50_ms_pallas": NUM,
    "compile_s_jnp": NUM, "compile_s_pallas": NUM,
    "gathered_kv_bytes": NUM, "fused_kv_bytes": NUM,
    "kv_bytes_per_step": list, "fused_le_gathered_every_step": bool,
    "kv_bytes_saved_frac": NUM,
}

_CONFIG_V5 = {
    "arch": STR, "family": STR, "smoke": bool, "moa": STR, "n_slots": int,
    "max_len": int, "n_long": int, "n_burst": int, "long_prompt_len": int,
    "long_gen_len": int, "burst_prompt_len": int, "burst_gen_len": int,
    "burst_at_s": NUM, "burst_deadline_s": NUM,
    "prefill_chunk_tokens": int, "clock_dt": NUM, "seed": int,
}

_SLO_AGGREGATE = {
    "deadline_requests": int, "deadline_met": int, "attainment": NUM,
    "goodput_tok_per_s": NUM, "deadline_ttft_ms": _DIST,
    "preemptions": int, "spills": int, "revivals": int,
    "preempted_requests": int, "prefill_chunk_tokens": int,
    "prefill_chunk_count": int,
}

_SLO_COMPARISON = {
    "greedy_tokens_match": bool, "attainment_fifo": NUM,
    "attainment_slo": NUM, "deadline_ttft_p99_ms_fifo": NUM,
    "deadline_ttft_p99_ms_slo": NUM, "goodput_tok_per_s_fifo": NUM,
    "goodput_tok_per_s_slo": NUM, "preemptions": int, "spills": int,
    "revivals": int, "prefill_chunk_count": int, "slo_wins_p99": bool,
    "slo_wins_goodput": bool,
}

_CONFIG_V7 = {
    "arch": STR, "family": STR, "smoke": bool, "moa": STR,
    "n_replicas": int, "n_slots": int, "max_len": int, "requests": int,
    "rate_rps": NUM, "prompt_len_range": list, "gen_len_range": list,
    "kill_schedule": list, "reload_at_step": int, "miss_limit": int,
    "clock_dt": NUM, "seed": int,
}

_FLEET = {
    "n_replicas": int, "router_steps": int, "wall_s": NUM, "requests": int,
    "completed": int, "lost_requests": int, "kills": int,
    "deaths_detected": int, "requeues": int, "requeued_requests": int,
    "requeue_latency_ms": _DIST, "reloads_completed": int,
    "reload_dropped": int, "stragglers": int, "total_new_tokens": int,
    "tok_per_s": NUM, "replicas": list,
}

_FLEET_REPLICA = {
    "rid": int, "state": STR, "ticks": int, "completed": int,
    "param_version": int, "kills": int, "revivals": int, "reloads": int,
}

_FLEET_REQUEST = {
    "uid": int, "prompt_tokens": int, "new_tokens": int, "ttft_ms": NUM,
}

_V7_COMPARISON = {
    "greedy_tokens_match": bool, "lost_requests": int, "kills": int,
    "deaths_detected": int, "requeues": int, "requeue_latency_ms": _DIST,
    "reloads_completed": int, "reload_dropped": int,
    "goodput_tok_per_s_baseline": NUM, "goodput_tok_per_s_chaos": NUM,
    "goodput_ratio": NUM, "router_steps_baseline": int,
    "router_steps_chaos": int,
}

_ANALYSIS_SUMMARY = {
    "targets_audited": int, "files_linted": int, "violations": int,
    "rules_checked": list,
}

_ANALYSIS_VIOLATION = {
    "rule": STR, "severity": STR, "target": STR, "file": STR, "line": int,
    "message": STR, "provenance": STR,
}

_COST_SUMMARY = {
    "targets_costed": int, "targets_drift_checked": int, "violations": int,
    "unbounded_loops": int, "max_abs_drift": NUM,
}

_COST_STATIC = {
    "flops": NUM, "gather_bytes": NUM, "scatter_bytes": NUM,
    "kv_gather_bytes": NUM, "pallas_stream_bytes": NUM, "peak_bytes": NUM,
    "arg_bytes": NUM, "out_bytes": NUM,
}

_COST_LOOPS = {
    "scans": int, "pallas_grids": int, "max_trip_count": int,
    "unbounded": int,
}

_COST_TARGET = {
    "target": STR, "family": STR, "phase": STR, "mesh": bool,
    "drift_checked": bool, "static": _COST_STATIC, "loops": _COST_LOOPS,
}


def _check(record, schema, path, errors):
    """Recursively check required keys + types (dict schemas nest)."""
    if not isinstance(record, dict):
        errors.append(f"{path}: expected object, got {type(record).__name__}")
        return
    for key, want in schema.items():
        if key not in record:
            errors.append(f"{path}.{key}: missing")
            continue
        got = record[key]
        if isinstance(want, dict):
            _check(got, want, f"{path}.{key}", errors)
        elif want is bool:
            if not isinstance(got, bool):
                errors.append(f"{path}.{key}: expected bool, "
                              f"got {type(got).__name__}")
        elif want is int:
            if isinstance(got, bool) or not isinstance(got, int):
                errors.append(f"{path}.{key}: expected int, "
                              f"got {type(got).__name__}")
        elif isinstance(got, bool) or not isinstance(got, want):
            errors.append(f"{path}.{key}: expected "
                          f"{getattr(want, '__name__', want)}, "
                          f"got {type(got).__name__}")


def _check_run(run, path, errors):
    _check(run, {"aggregate": _AGGREGATE}, path, errors)
    reqs = run.get("requests")
    if not isinstance(reqs, list) or not reqs:
        errors.append(f"{path}.requests: expected non-empty list")
        return
    for i, r in enumerate(reqs):
        _check(r, _REQUEST, f"{path}.requests[{i}]", errors)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

#: schema tag → validator(record, errors)
SCHEMAS: Dict[str, Callable[[dict, List[str]], None]] = {}


def register(name: str):
    """Register a validator for one ``schema`` tag."""

    def deco(fn):
        SCHEMAS[name] = fn
        return fn

    return deco


@register("serving-v1")
def _serving_v1(record, errors):
    _check(record, {"config": _CONFIG_V1}, "$", errors)
    _check_run(record, "$", errors)


@register("serving-v2")
def _serving_v2(record, errors):
    _check(record, {"config": _CONFIG_V2, "comparison": _COMPARISON},
           "$", errors)
    for mode in ("dense", "paged"):
        _check_run(record.get(mode, {}), f"$.{mode}", errors)
    paged_agg = record.get("paged", {}).get("aggregate", {})
    _check(paged_agg.get("paged", {}), _PAGED_AGGREGATE,
           "$.paged.aggregate.paged", errors)


@register("serving-v3")
def _serving_v3(record, errors):
    _check(record, {"config": _CONFIG_V3,
                    "comparison": _SPEC_COMPARISON}, "$", errors)
    _check_run(record.get("plain", {}), "$.plain", errors)
    runs = record.get("spec_runs")
    if not isinstance(runs, list) or not runs:
        errors.append("$.spec_runs: expected non-empty list")
    else:
        for i, sr in enumerate(runs):
            path = f"$.spec_runs[{i}]"
            _check(sr, {"accept_prob": NUM}, path, errors)
            _check_run(sr, path, errors)
            _check(sr.get("aggregate", {}).get("spec", {}),
                   _SPEC_AGGREGATE, f"{path}.aggregate.spec", errors)
    curve = record.get("comparison", {}).get("curve")
    if not isinstance(curve, list) or not curve:
        errors.append("$.comparison.curve: expected non-empty list")
    else:
        for i, pt in enumerate(curve):
            _check(pt, _SPEC_POINT, f"$.comparison.curve[{i}]", errors)


@register("serving-v4")
def _serving_v4(record, errors):
    _check(record, {"config": _CONFIG_V4,
                    "comparison": _V4_COMPARISON}, "$", errors)
    for mode in ("single", "sharded"):
        _check_run(record.get(mode, {}), f"$.{mode}", errors)
    mesh = record.get("config", {}).get("mesh", {})
    if isinstance(mesh, dict):
        shape, n = mesh.get("shape"), mesh.get("n_devices")
        if isinstance(shape, list) and isinstance(n, int):
            prod = 1
            for s in shape:
                prod *= s if isinstance(s, int) else 0
            if prod != n:
                errors.append("$.config.mesh: shape does not multiply "
                              f"to n_devices ({shape} vs {n})")


@register("serving-v6")
def _serving_v6(record, errors):
    """Paged attention backend comparison (jnp gather vs fused pallas)."""
    _check(record, {"config": _CONFIG_V6,
                    "comparison": _V6_COMPARISON}, "$", errors)
    for backend in ("jnp", "pallas"):
        _check_run(record.get(backend, {}), f"$.{backend}", errors)
        _check(record.get(backend, {}).get("aggregate", {}).get("paged", {}),
               _PAGED_AGGREGATE, f"$.{backend}.aggregate.paged", errors)
    comp = record.get("comparison", {})
    steps = comp.get("kv_bytes_per_step")
    if isinstance(steps, list):
        for i, pair in enumerate(steps):
            if not (isinstance(pair, list) and len(pair) == 2
                    and all(isinstance(x, numbers.Real)
                            and not isinstance(x, bool) for x in pair)):
                errors.append(f"$.comparison.kv_bytes_per_step[{i}]: "
                              "expected [gathered, fused] number pair")
            elif pair[1] > pair[0]:
                errors.append(f"$.comparison.kv_bytes_per_step[{i}]: fused "
                              f"bytes exceed gathered ({pair[1]} > "
                              f"{pair[0]}) — the fused kernel must never "
                              "touch more than the gather path streams")


@register("serving-v5")
def _serving_v5(record, errors):
    _check(record, {"config": _CONFIG_V5,
                    "comparison": _SLO_COMPARISON}, "$", errors)
    for policy in ("fifo", "slo"):
        _check_run(record.get(policy, {}), f"$.{policy}", errors)
        _check(record.get(policy, {}).get("aggregate", {}).get("slo", {}),
               _SLO_AGGREGATE, f"$.{policy}.aggregate.slo", errors)
    slo_agg = record.get("slo", {}).get("aggregate", {}).get("slo", {})
    comp = record.get("comparison", {})
    if isinstance(slo_agg, dict) and isinstance(comp, dict):
        spills = slo_agg.get("spills")
        preemptions = slo_agg.get("preemptions")
        if isinstance(spills, int) and isinstance(preemptions, int) \
                and spills > preemptions:
            errors.append("$.slo.aggregate.slo: spills exceed preemptions "
                          f"({spills} > {preemptions})")


@register("serving-v7")
def _serving_v7(record, errors):
    """Replica-set chaos benchmark (kill + reload vs failure-free)."""
    _check(record, {"config": _CONFIG_V7,
                    "comparison": _V7_COMPARISON}, "$", errors)
    for mode in ("baseline", "chaos"):
        run = record.get(mode, {})
        _check(run, {"fleet": _FLEET}, f"$.{mode}", errors)
        reqs = run.get("requests") if isinstance(run, dict) else None
        if not isinstance(reqs, list) or not reqs:
            errors.append(f"$.{mode}.requests: expected non-empty list")
        else:
            for i, r in enumerate(reqs):
                _check(r, _FLEET_REQUEST, f"$.{mode}.requests[{i}]", errors)
        replicas = run.get("fleet", {}).get("replicas") \
            if isinstance(run, dict) else None
        if isinstance(replicas, list):
            for i, rep in enumerate(replicas):
                _check(rep, _FLEET_REPLICA,
                       f"$.{mode}.fleet.replicas[{i}]", errors)
    comp = record.get("comparison", {})
    chaos_fleet = record.get("chaos", {}).get("fleet", {})
    if isinstance(comp, dict) and isinstance(chaos_fleet, dict):
        for key in ("lost_requests", "requeues", "reloads_completed",
                    "reload_dropped"):
            a, b = comp.get(key), chaos_fleet.get(key)
            if isinstance(a, int) and isinstance(b, int) and a != b:
                errors.append(f"$.comparison.{key}: disagrees with "
                              f"$.chaos.fleet.{key} ({a} vs {b})")


@register("analysis-v1")
def _analysis_v1(record, errors):
    """Static-analysis report (scripts/audit_serve_path.py)."""
    _check(record, {"config": dict, "summary": _ANALYSIS_SUMMARY},
           "$", errors)
    violations = record.get("violations")
    if not isinstance(violations, list):
        errors.append("$.violations: expected list")
        return
    for i, v in enumerate(violations):
        _check(v, _ANALYSIS_VIOLATION, f"$.violations[{i}]", errors)
        if isinstance(v, dict) and v.get("severity") not in ("error",
                                                            "warning"):
            errors.append(f"$.violations[{i}].severity: expected "
                          f"'error' or 'warning', got {v.get('severity')!r}")
    summary = record.get("summary", {})
    if isinstance(summary, dict) and \
            summary.get("violations") != len(violations):
        errors.append("$.summary.violations: count does not match "
                      f"len(violations) ({summary.get('violations')} vs "
                      f"{len(violations)})")


@register("analysis-v2")
def _analysis_v2(record, errors):
    """Static cost-audit report: per-target static vs analytic counts.

    Cross-field invariants beyond key/type checks:

    * ``summary.violations`` / ``summary.targets_costed`` /
      ``summary.unbounded_loops`` must equal what the record bodies sum to;
    * a ``drift_checked`` target must carry ``analytic.flops`` and
      ``drift.flops``, and the drift ratio must actually BE
      ``static/analytic − 1`` (a report that states one number and
      implies another is how cost models rot);
    * an unchecked target must carry ``analytic: null`` — coverage is
      reported, never faked.
    """
    _check(record, {"config": dict, "summary": _COST_SUMMARY}, "$", errors)
    violations = record.get("violations")
    if not isinstance(violations, list):
        errors.append("$.violations: expected list")
        return
    for i, v in enumerate(violations):
        _check(v, _ANALYSIS_VIOLATION, f"$.violations[{i}]", errors)
        if isinstance(v, dict) and v.get("severity") not in ("error",
                                                            "warning"):
            errors.append(f"$.violations[{i}].severity: expected "
                          f"'error' or 'warning', got {v.get('severity')!r}")
    targets = record.get("targets")
    if not isinstance(targets, list) or not targets:
        errors.append("$.targets: expected non-empty list")
        return
    n_checked = n_unbounded = 0
    for i, t in enumerate(targets):
        path = f"$.targets[{i}]"
        _check(t, _COST_TARGET, path, errors)
        if not isinstance(t, dict):
            continue
        loops = t.get("loops")
        if isinstance(loops, dict) and isinstance(loops.get("unbounded"),
                                                  int):
            n_unbounded += loops["unbounded"]
        if not t.get("drift_checked"):
            if t.get("analytic") is not None:
                errors.append(f"{path}.analytic: expected null on an "
                              "unchecked target (drift_checked=false)")
            continue
        n_checked += 1
        analytic, drift = t.get("analytic"), t.get("drift")
        if not isinstance(analytic, dict) or not isinstance(drift, dict):
            errors.append(f"{path}: drift_checked target must carry "
                          "analytic and drift objects")
            continue
        _check(analytic, {"flops": NUM}, f"{path}.analytic", errors)
        _check(drift, {"flops": NUM}, f"{path}.drift", errors)
        static = t.get("static", {})
        for qty, stated in drift.items():
            a = analytic.get(qty)
            s = static.get(qty) if isinstance(static, dict) else None
            if not all(isinstance(x, numbers.Real) and not isinstance(x, bool)
                       for x in (a, s, stated)):
                continue            # key/type errors already reported
            implied = (s / a - 1.0) if a else (0.0 if not s else None)
            if implied is not None and abs(stated - implied) > 1e-9 \
                    + 1e-9 * abs(implied):
                errors.append(
                    f"{path}.drift.{qty}: stated ratio {stated} does not "
                    f"equal static/analytic - 1 = {implied} "
                    f"(static={s}, analytic={a})")
    summary = record.get("summary", {})
    if isinstance(summary, dict):
        for key, got in (("violations", len(violations)),
                         ("targets_costed", len(targets)),
                         ("targets_drift_checked", n_checked),
                         ("unbounded_loops", n_unbounded)):
            if isinstance(summary.get(key), int) and summary[key] != got:
                errors.append(f"$.summary.{key}: count does not match the "
                              f"record body ({summary[key]} vs {got})")


def validate(record: dict) -> list:
    """Return a list of violations (empty = valid)."""
    errors: list = []
    schema = record.get("schema")
    checker = SCHEMAS.get(schema)
    if checker is None:
        known = ", ".join(sorted(SCHEMAS))
        errors.append(f"$.schema: unknown schema {schema!r} "
                      f"(expected one of: {known})")
    else:
        checker(record, errors)
    return errors


def main(paths) -> int:
    if not paths:
        print("usage: check_bench_schema.py RECORD.json [...]",
              file=sys.stderr)
        return 2
    bad = 0
    for p in paths:
        try:
            with open(p) as f:
                record = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"INVALID {p}: {e}")
            bad += 1
            continue
        errors = validate(record)
        for e in errors:
            print(f"INVALID {p}: {e}")
        if errors:
            bad += 1
        else:
            print(f"ok {p}: {record['schema']}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
