"""Beyond-paper benchmark: MOA reduction strategies through real layers.

Sweeps the strategy axis **from the registry** — every strategy registered
with :func:`repro.moa.register_strategy` contributes its ``bench_specs()``
variants, so new strategies appear here without editing this file. Each
spec runs through ``strategy.dot`` on its own backend (jnp reference or
Pallas kernel), verifying schedule-invariance of the math, and the
model-level sweep uses :func:`repro.moa.moa_scope` to retarget one built
model instead of rebuilding configs. Also reports the analytic
collective-byte delta of int8 gradient compression (the approximate MOA
that *does* pay — the wire is not hard-wired, unlike the ALM/MXU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, smoke_config
from repro.models.api import build_model
from repro.moa import (available_strategies, get_strategy_class, moa_scope,
                       resolve)

__all__ = ["run"]


def _time(f, *args, reps=3):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(verbose: bool = True):
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    ka, kb = jax.random.split(key)
    M, K, N = 256, 4096, 256
    a = jax.random.normal(ka, (M, K), jnp.float32)
    b = jax.random.normal(kb, (K, N), jnp.float32)
    want_f = np.asarray(a @ b)
    # integer-only strategies (LOA) materialize (M, K, N) partial products
    # on the jnp oracle path — keep their problem DHM-conv-sized
    Mi, Ki, Ni = 64, 512, 64
    ai = jax.random.randint(ka, (Mi, Ki), 0, 8, jnp.int32)
    bi = jax.random.randint(kb, (Ki, Ni), 0, 8, jnp.int32)
    want_i = np.asarray(ai) @ np.asarray(bi)

    if verbose:
        print(f"# registry-driven MOA sweep on ({M}x{K})·({K}x{N}); "
              f"strategies: {available_strategies()}")
        print(f"{'spec':>28s} {'us':>9s} {'max_err':>9s}")
    exact_max_err = 0.0
    for name in available_strategies():
        for spec in get_strategy_class(name).bench_specs():
            strat = resolve(spec)
            if strat.integer_only:
                f = lambda: strat.dot(ai, bi, out_dtype=jnp.int32)
                want = want_i
            else:
                f = lambda: strat.dot(a, b)
                want = want_f
            us = _time(lambda: f(), reps=3)
            err = float(np.abs(np.asarray(f()) - want).max())
            if strat.cost(K)["exact"]:
                exact_max_err = max(exact_max_err, err)
            if verbose:
                print(f"{spec:>28s} {us:9.0f} {err:9.2e}")

    # model-level: one built model retargeted via moa_scope (the strategies
    # resolve at trace time, so each unjitted loss call sees the override)
    cfg = smoke_config(get_config("llama3-8b"))
    model = build_model(cfg)
    params = model.init(key)
    batch = model.make_batch(key, ShapeSpec("t", 64, 4, "train"),
                             batch_override=4, seq_override=64)
    with moa_scope("tree"):
        lt = float(model.loss(params, batch)[0])
    with moa_scope("serial?chunk=16"):
        ls = float(model.loss(params, batch)[0])

    # gradient compression wire-byte delta (analytic, llama3-8b, 16×16 pod)
    pbytes = get_config("llama3-8b").param_count() * 4
    full = 2 * (pbytes / 16) * 15 / 16
    compressed = full / 4  # int8 vs f32
    if verbose:
        print(f"# model-level loss under moa_scope: tree={lt:.4f} "
              f"serial={ls:.4f} (delta {abs(lt-ls):.2e})")
        print(f"# int8 grad all-reduce wire bytes: {full/1e9:.1f}GB → "
              f"{compressed/1e9:.1f}GB per device (4.0x)")
    elapsed_us = (time.perf_counter() - t0) * 1e6
    return {
        "us_per_call": elapsed_us,
        "derived": (f"strategy_max_err={exact_max_err:.2e}"
                    f";loss_delta={abs(lt-ls):.2e};grad_compress=4.0x"),
    }
