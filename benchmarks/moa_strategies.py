"""Beyond-paper benchmark: MOA reduction strategies through real layers.

Sweeps the ReductionStrategy knob (tree / serial×chunk / LOA-int8) through
(a) the Pallas ``dot_moa`` kernel and (b) a full smoke-model train step,
verifying schedule-invariance of the math and reporting the measured
timing plus the analytic collective-byte delta of int8 gradient
compression (the approximate MOA that *does* pay — the wire is not
hard-wired, unlike the ALM/MXU).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, smoke_config
from repro.core.moa import ReductionStrategy, moa_dot
from repro.kernels import ops
from repro.models.api import build_model

__all__ = ["run"]


def _time(f, *args, reps=3):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(verbose: bool = True):
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    ka, kb = jax.random.split(key)
    M, K, N = 256, 4096, 256
    a = jax.random.normal(ka, (M, K), jnp.float32)
    b = jax.random.normal(kb, (K, N), jnp.float32)
    want = np.asarray(a @ b)

    if verbose:
        print("# MOA strategy sweep on (256×4096)·(4096×256)")
        print(f"{'strategy':>22s} {'us':>9s} {'max_err':>9s}")
    rows = {}
    for name, f in [
        ("tree (one-shot)", lambda: moa_dot(a, b, strategy=ReductionStrategy(
            kind="tree"))),
        ("serial chunk=1024", lambda: moa_dot(a, b,
                                              strategy=ReductionStrategy(
                                                  kind="serial", chunk=1024))),
        ("serial chunk=256", lambda: moa_dot(a, b,
                                             strategy=ReductionStrategy(
                                                 kind="serial", chunk=256))),
        ("pallas blk_k=512", lambda: ops.dot_moa(a, b, block_k=512)),
        ("pallas blk_k=1024", lambda: ops.dot_moa(a, b, block_k=1024)),
    ]:
        us = _time(lambda: f(), reps=3)
        err = float(np.abs(np.asarray(f()) - want).max())
        rows[name] = (us, err)
        if verbose:
            print(f"{name:>22s} {us:9.0f} {err:9.2e}")
    max_err = max(v[1] for v in rows.values())

    # model-level: serial chunking through a full train loss
    cfg = smoke_config(get_config("llama3-8b"))
    model_tree = build_model(dataclasses.replace(cfg, moa_kind="tree"))
    model_ser = build_model(dataclasses.replace(cfg, moa_kind="serial",
                                                moa_chunk=16))
    params = model_tree.init(key)
    batch = model_tree.make_batch(key, ShapeSpec("t", 64, 4, "train"),
                                  batch_override=4, seq_override=64)
    lt = float(model_tree.loss(params, batch)[0])
    ls = float(model_ser.loss(params, batch)[0])

    # gradient compression wire-byte delta (analytic, llama3-8b, 16×16 pod)
    pbytes = get_config("llama3-8b").param_count() * 4
    full = 2 * (pbytes / 16) * 15 / 16
    compressed = full / 4  # int8 vs f32
    if verbose:
        print(f"# model-level loss: tree={lt:.4f} serial={ls:.4f} "
              f"(delta {abs(lt-ls):.2e})")
        print(f"# int8 grad all-reduce wire bytes: {full/1e9:.1f}GB → "
              f"{compressed/1e9:.1f}GB per device (4.0x)")
    elapsed_us = (time.perf_counter() - t0) * 1e6
    return {
        "us_per_call": elapsed_us,
        "derived": (f"strategy_max_err={max_err:.2e}"
                    f";loss_delta={abs(lt-ls):.2e};grad_compress=4.0x"),
    }
