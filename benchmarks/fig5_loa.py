"""Benchmark/repro of paper Fig. 5: LOA accuracy (MRED) and area.

Accuracy: MRED over uniform random operands for b ∈ {4,8,12,16} and
approximation ratios l/b ∈ {0…50%} — matches the paper's curves (<10 %
MRED at 8 bits).

Area/cost: (a) the ALM model — flat in l (the FPGA negative result);
(b) the TPU analogue *measured*: the LOA Pallas kernel's VPU-op count and
interpret-mode timing vs the hard add — approximation costs MORE on TPU,
same root cause (hard-wired exact adders), sign flipped.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import cost_model, loa, metrics
from repro.kernels import ops
from repro.moa import resolve

__all__ = ["run"]


def _time(f, *args, reps=5):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(verbose: bool = True):
    t0 = time.perf_counter()
    key = jax.random.PRNGKey(0)
    n = 200_000
    if verbose:
        print("# Fig. 5 — LOA MRED vs approximation ratio (top) and "
              "cost (bottom)")
        print(f"{'b':>3s} {'l':>3s} {'ratio':>6s} {'MRED':>8s} "
              f"{'ALMs':>5s}")
    mred_8bit_max = 0.0
    flat_alms = True
    for bits in (4, 8, 12, 16):
        kx, ky = jax.random.split(jax.random.fold_in(key, bits))
        x = jax.random.randint(kx, (n,), 0, 2 ** bits, jnp.int32)
        y = jax.random.randint(ky, (n,), 0, 2 ** bits, jnp.int32)
        base_alm = cost_model.alm_loa_adder(bits, 0)
        for l in range(0, bits // 2 + 1):
            s_hat = loa.loa_add(x, y, approx_bits=l, width=bits)
            m = float(metrics.mred(s_hat, x + y))
            alms = cost_model.alm_loa_adder(bits, l)
            flat_alms &= (alms == base_alm)
            if bits == 8:
                mred_8bit_max = max(mred_8bit_max, m)
            if verbose:
                print(f"{bits:3d} {l:3d} {l/bits:6.1%} {m:8.4f} {alms:5d}")

    # TPU measured analogue: LOA kernel vs exact add; the op-count ratio now
    # comes from the strategy's own cost model (what launch/costing charges)
    xk = jax.random.randint(key, (1 << 16,), 0, 256, jnp.int32)
    yk = jax.random.randint(jax.random.fold_in(key, 1), (1 << 16,), 0, 256,
                            jnp.int32)
    t_loa = _time(lambda a, b: ops.loa_add(a, b, approx_bits=4), xk, yk)
    t_exact = _time(lambda a, b: a + b, xk, yk)
    loa_strategy = resolve("loa?approx_bits=4")
    ratio = (loa_strategy.cost(2, "int8")["ops_per_add"]
             / cost_model.vpu_ops_exact_add())
    if verbose:
        print(f"# TPU analogue: LOA = {ratio:.0f} VPU "
              f"ops vs 1 hard add ({ratio:.0f}x); measured interpret-mode "
              f"{t_loa:.0f}us vs {t_exact:.0f}us")
        print("# → approximation saves NOTHING on either substrate: the "
              "exact adder is hard-wired (ALM / MXU-VPU). "
              "'How not to solve it', reproduced.")
    elapsed_us = (time.perf_counter() - t0) * 1e6
    return {
        "us_per_call": elapsed_us,
        "derived": (f"mred8bit_max={mred_8bit_max:.4f}(paper:<0.10)"
                    f";alm_flat={flat_alms};tpu_loa_cost={ratio:.0f}x"),
    }
