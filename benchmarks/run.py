# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV (plus verbose per-benchmark detail above each block).

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (fig4_serialization, fig5_loa, moa_strategies,
                            roofline, table1_moa_counts)

    benches = [
        ("table1_moa_counts", table1_moa_counts.run),
        ("fig4_serialization", fig4_serialization.run),
        ("fig5_loa", fig5_loa.run),
        ("moa_strategies", moa_strategies.run),
        ("roofline", roofline.run),
    ]
    results = []
    for name, fn in benches:
        print(f"\n=== {name} " + "=" * (68 - len(name)))
        try:
            res = fn(verbose=True)
            results.append((name, res["us_per_call"], res["derived"]))
        except Exception as e:  # pragma: no cover
            results.append((name, float("nan"), f"ERROR:{type(e).__name__}"))
            print(f"[bench] {name} failed: {e}", file=sys.stderr)

    print("\nname,us_per_call,derived")
    for name, us, derived in results:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
