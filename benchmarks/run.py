# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV (plus verbose per-benchmark detail above each block).
#
#   PYTHONPATH=src python -m benchmarks.run                       # full suite
#   PYTHONPATH=src python -m benchmarks.run --list-strategies     # registry
#   PYTHONPATH=src python -m benchmarks.run --strategy "serial?chunk=256"
#
# ``--strategy`` runs the whole suite under a ``repro.moa.moa_scope``
# override, so every MOA-routed contraction (model losses included) uses
# the given spec regardless of the per-benchmark defaults.

from __future__ import annotations

import argparse
import contextlib
import sys


def _list_strategies() -> None:
    from repro.moa import available_strategies, get_strategy_class

    print("registered MOA strategies (spec grammar: name?key=val&key=val):")
    for name in available_strategies():
        cls = get_strategy_class(name)
        doc = (cls.__doc__ or "").strip().splitlines()[0]
        print(f"  {name:<8s} {doc}")
        print(f"  {'':<8s}   bench variants: {', '.join(cls.bench_specs())}")


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(
        description="Paper table/figure benchmarks (MOA scheduling study)")
    parser.add_argument(
        "--strategy", metavar="SPEC", default=None,
        help="repro.moa spec string; run all benchmarks under "
             "moa_scope(SPEC), e.g. 'serial?chunk=256' or 'tree'")
    parser.add_argument(
        "--list-strategies", action="store_true",
        help="print the strategy registry and exit")
    args = parser.parse_args(argv)

    if args.list_strategies:
        _list_strategies()
        return

    from benchmarks import (fig4_serialization, fig5_loa, moa_strategies,
                            roofline, table1_moa_counts)
    from repro.moa import moa_scope, resolve

    benches = [
        ("table1_moa_counts", table1_moa_counts.run),
        ("fig4_serialization", fig4_serialization.run),
        ("fig5_loa", fig5_loa.run),
        ("moa_strategies", moa_strategies.run),
        ("roofline", roofline.run),
    ]
    scope = (moa_scope(resolve(args.strategy)) if args.strategy
             else contextlib.nullcontext())
    if args.strategy:
        print(f"# moa_scope override: {resolve(args.strategy).spec}")
    results = []
    with scope:
        for name, fn in benches:
            print(f"\n=== {name} " + "=" * (68 - len(name)))
            try:
                res = fn(verbose=True)
                results.append((name, res["us_per_call"], res["derived"]))
            except Exception as e:  # pragma: no cover
                results.append((name, float("nan"), f"ERROR:{type(e).__name__}"))
                print(f"[bench] {name} failed: {e}", file=sys.stderr)

    print("\nname,us_per_call,derived")
    for name, us, derived in results:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
