"""Roofline table reader: aggregates artifacts/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (per arch × shape × mesh: three terms in
seconds, dominant bottleneck, useful-compute ratio, one-line lever).

Also prices the **paged-decode** memory term analytically
(:func:`paged_decode_cell`): at each context depth, the bytes a decode
step *must* stream (live KV at depth ``pos+1`` — the bandwidth ceiling)
vs. what the fused block-table kernel touches (live pages, block-size
granularity) vs. what the gathered jnp path streams (the full
high-water-bucketed padded view) — the gap the fused kernel closes
(``docs/kernels.md``)."""

from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List, Optional

__all__ = ["run", "load_cells", "format_table", "paged_decode_cell",
           "format_paged_decode"]

_LEVERS = {
    ("compute_s", "train"): "raise arithmetic intensity: causal chunk-skip "
                            "in flash attention / lighter remat",
    ("compute_s", "prefill"): "causal block skipping halves score FLOPs",
    ("compute_s", "decode"): "batch more sequences per chip",
    ("memory_s", "train"): "shard activations wider (model axis), remat "
                           "more, fuse optimizer traffic",
    ("memory_s", "prefill"): "keep KV in VMEM across q-chunks (larger "
                             "q_chunk)",
    ("memory_s", "decode"): "quantize KV cache to int8 (halves cache "
                            "stream)",
    ("collective_s", "train"): "int8 gradient compression + reduce-scatter;"
                               " overlap FSDP gathers with compute",
    ("collective_s", "prefill"): "reduce TP all-reduces: fuse attn+mlp "
                                 "blocks per all-reduce",
    ("collective_s", "decode"): "replicate small weights; shrink TP degree "
                                "for decode",
    ("memory_s", "paged_decode"): "fused block-table kernel streams live "
                                  "pages only (kernels/paged_attention.py)",
}


def paged_decode_cell(*, arch: str = "llama3-8b", n_slots: int = 64,
                      max_len: int = 4096, block_size: int = 16,
                      depths=(128, 512, 1024, 2048, 4095)) -> Dict:
    """Analytic paged-decode KV-stream cell at a sweep of context depths.

    For one batched decode step with every slot at depth ``pos``:

    * ``ceiling_bytes`` — live KV at depth ``pos + 1``, the stream no
      attention implementation can beat (the bandwidth ceiling);
    * ``fused_bytes`` — what the fused block-table kernel touches: live
      pages only, rounded up to block granularity;
    * ``gathered_bytes`` — what the jnp gather path materializes: the
      high-water block count rounded to the engine's power-of-two bucket,
      for **all** slots.

    Each is also expressed in seconds against the chip's HBM bandwidth
    and as a fraction of the ceiling, so the cell reads directly as "how
    far off the roofline is each path".
    """
    from repro.configs.registry import get_config
    from repro.core.cost_model import TPU_V5E
    from repro.launch.costing import kv_bytes_per_token
    cfg = get_config(arch)
    # CacheSpec-derived, NOT a hand formula: int8 scale planes and the
    # hybrid's attn-application-only KV stacks are part of the stream the
    # engine's _kv_bytes_tick meters, and the static cost audit
    # (analysis/cost_audit.py) pins all three to the same number
    # (tests/test_cost_audit.py::TestKvBytesAgree)
    kv_bytes_tok = kv_bytes_per_token(cfg)
    max_blocks = max_len // block_size
    bw = TPU_V5E.hbm_bandwidth
    rows = []
    for pos in depths:
        pos = min(pos, max_len - 1)
        live_blocks = pos // block_size + 1
        hw = 1
        while hw < live_blocks:
            hw <<= 1
        hw = min(hw, max_blocks)
        ceiling = n_slots * (pos + 1) * kv_bytes_tok
        fused = n_slots * live_blocks * block_size * kv_bytes_tok
        gathered = n_slots * hw * block_size * kv_bytes_tok
        rows.append({
            "pos": pos,
            "ceiling_bytes": ceiling,
            "fused_bytes": fused,
            "gathered_bytes": gathered,
            "ceiling_s": ceiling / bw,
            "fused_s": fused / bw,
            "gathered_s": gathered / bw,
            "fused_x_ceiling": fused / ceiling,
            "gathered_x_ceiling": gathered / ceiling,
        })
    return {
        "arch": cfg.name, "phase": "paged_decode", "n_slots": n_slots,
        "max_len": max_len, "block_size": block_size,
        "kv_bytes_per_token": kv_bytes_tok,
        "hbm_bandwidth": bw,
        "lever": _LEVERS[("memory_s", "paged_decode")],
        "rows": rows,
    }


def format_paged_decode(cell: Dict) -> str:
    lines = [
        f"| depth | ceiling_s | fused_s | gathered_s | fused/ceil | "
        f"gathered/ceil |",
        "|---|---|---|---|---|---|",
    ]
    for r in cell["rows"]:
        lines.append(
            f"| {r['pos']} | {r['ceiling_s']:.4g} | {r['fused_s']:.4g} | "
            f"{r['gathered_s']:.4g} | {r['fused_x_ceiling']:.2f} | "
            f"{r['gathered_x_ceiling']:.2f} |")
    return "\n".join(lines)


def load_cells(art_dir: str = "artifacts/dryrun",
               variant: Optional[str] = "baseline") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("skipped") or "error" in d:
            cells.append(d)
            continue
        if variant is not None and d.get("variant") != variant:
            continue
        cells.append(d)
    return cells


def format_table(cells: List[Dict], *, mesh: str = "single_pod_16x16") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO | lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    seen_skips = set()
    for d in cells:
        if d.get("skipped"):
            key = (d["arch"], d["shape"])
            if mesh.startswith("single") and key not in seen_skips:
                seen_skips.add(key)
                lines.append(
                    f"| {d['arch']} | {d['shape']} | — | — | — | SKIP | — | "
                    f"{d['reason'][:60]} |")
            continue
        if "error" in d or d.get("mesh") != mesh:
            continue
        r = d["roofline"]
        lever = _LEVERS.get((r["dominant"], d["phase"]), "")
        ratio = d.get("useful_compute_ratio")
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.4g} | "
            f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{ratio:.2f} | {lever} |")
    return "\n".join(lines)


def run(verbose: bool = True):
    t0 = time.perf_counter()
    cells = load_cells()
    ok = [c for c in cells if not c.get("skipped") and "error" not in c]
    errors = [c for c in cells if "error" in c]
    skips = [c for c in cells if c.get("skipped")]
    if verbose:
        if ok:
            print("# Roofline (single-pod 16×16; terms in seconds/step)")
            print(format_table(cells))
            by_dom: Dict[str, int] = {}
            for c in ok:
                if c["mesh"].startswith("single"):
                    k = c["roofline"]["dominant"]
                    by_dom[k] = by_dom.get(k, 0) + 1
            print(f"# bottleneck census (single-pod): {by_dom}")
        else:
            print("# no dry-run artifacts found — run "
                  "`python -m repro.launch.dryrun --all --mesh both` first")
        cell = paged_decode_cell()
        print(f"# Paged decode KV stream ({cell['arch']}, "
              f"{cell['n_slots']} slots, block {cell['block_size']}, "
              f"seconds/step vs the bandwidth ceiling; lever: "
              f"{cell['lever']})")
        print(format_paged_decode(cell))
    elapsed_us = (time.perf_counter() - t0) * 1e6
    return {
        "us_per_call": elapsed_us,
        "derived": (f"cells_ok={len(ok)};skips={len(skips)};"
                    f"errors={len(errors)}"),
    }
