"""Roofline table reader: aggregates artifacts/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (per arch × shape × mesh: three terms in
seconds, dominant bottleneck, useful-compute ratio, one-line lever)."""

from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List, Optional

__all__ = ["run", "load_cells", "format_table"]

_LEVERS = {
    ("compute_s", "train"): "raise arithmetic intensity: causal chunk-skip "
                            "in flash attention / lighter remat",
    ("compute_s", "prefill"): "causal block skipping halves score FLOPs",
    ("compute_s", "decode"): "batch more sequences per chip",
    ("memory_s", "train"): "shard activations wider (model axis), remat "
                           "more, fuse optimizer traffic",
    ("memory_s", "prefill"): "keep KV in VMEM across q-chunks (larger "
                             "q_chunk)",
    ("memory_s", "decode"): "quantize KV cache to int8 (halves cache "
                            "stream)",
    ("collective_s", "train"): "int8 gradient compression + reduce-scatter;"
                               " overlap FSDP gathers with compute",
    ("collective_s", "prefill"): "reduce TP all-reduces: fuse attn+mlp "
                                 "blocks per all-reduce",
    ("collective_s", "decode"): "replicate small weights; shrink TP degree "
                                "for decode",
}


def load_cells(art_dir: str = "artifacts/dryrun",
               variant: Optional[str] = "baseline") -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if d.get("skipped") or "error" in d:
            cells.append(d)
            continue
        if variant is not None and d.get("variant") != variant:
            continue
        cells.append(d)
    return cells


def format_table(cells: List[Dict], *, mesh: str = "single_pod_16x16") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO | lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    seen_skips = set()
    for d in cells:
        if d.get("skipped"):
            key = (d["arch"], d["shape"])
            if mesh.startswith("single") and key not in seen_skips:
                seen_skips.add(key)
                lines.append(
                    f"| {d['arch']} | {d['shape']} | — | — | — | SKIP | — | "
                    f"{d['reason'][:60]} |")
            continue
        if "error" in d or d.get("mesh") != mesh:
            continue
        r = d["roofline"]
        lever = _LEVERS.get((r["dominant"], d["phase"]), "")
        ratio = d.get("useful_compute_ratio")
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.4g} | "
            f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{ratio:.2f} | {lever} |")
    return "\n".join(lines)


def run(verbose: bool = True):
    t0 = time.perf_counter()
    cells = load_cells()
    ok = [c for c in cells if not c.get("skipped") and "error" not in c]
    errors = [c for c in cells if "error" in c]
    skips = [c for c in cells if c.get("skipped")]
    if verbose:
        if ok:
            print("# Roofline (single-pod 16×16; terms in seconds/step)")
            print(format_table(cells))
            by_dom: Dict[str, int] = {}
            for c in ok:
                if c["mesh"].startswith("single"):
                    k = c["roofline"]["dominant"]
                    by_dom[k] = by_dom.get(k, 0) + 1
            print(f"# bottleneck census (single-pod): {by_dom}")
        else:
            print("# no dry-run artifacts found — run "
                  "`python -m repro.launch.dryrun --all --mesh both` first")
    elapsed_us = (time.perf_counter() - t0) * 1e6
    return {
        "us_per_call": elapsed_us,
        "derived": (f"cells_ok={len(ok)};skips={len(skips)};"
                    f"errors={len(errors)}"),
    }
