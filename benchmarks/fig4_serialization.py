"""Benchmark/repro of paper Fig. 4: serialized MOA vs pipelined adder tree.

FPGA side: the calibrated ALM model shows the serializer's linear overhead
burying the accumulator's savings at every cluster size — the paper's first
negative result.

TPU side (the inversion): the *same schedule* — serial accumulation over
operand clusters — is measured through the registry
(``resolve("serial?backend=pallas&chunk=512")`` → the Pallas ``moa_reduce``
kernel: grid-serialized accumulator; the DMA pipeline is the hard-wired
serializer) against the one-shot ``tree`` strategy. On TPU serialization
costs nothing and bounds the working set; we report the kernel-vs-oracle
timing ratio and the VMEM working-set reduction straight from
``strategy.cost``.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.kernels import ref
from repro.moa import resolve

__all__ = ["run"]

CLUSTERS = [2, 4, 6, 8, 16, 32, 64, 128, 325, 957, 1774]


def _time(f, *args, reps=5):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(verbose: bool = True):
    t0 = time.perf_counter()
    if verbose:
        print("# Fig. 4 — FPGA ALM model: serialized MOA vs binary adder "
              "tree (8-bit operands)")
        print(f"{'n_c':>6s} {'tree':>7s} {'serializer':>10s} "
              f"{'accum':>6s} {'serial':>7s} {'verdict':>9s}")
    serial_wins = 0
    for n in CLUSTERS:
        tree = cost_model.alm_adder_tree(n, 8)
        ser = cost_model.alm_serializer(n, 8)
        acc = cost_model.alm_accumulator(n, 8)
        serial = ser + acc
        if serial < tree:
            serial_wins += 1
        if verbose:
            print(f"{n:6d} {tree:7d} {ser:10d} {acc:6d} {serial:7d} "
                  f"{'SERIAL' if serial < tree else 'tree':>9s}")

    # TPU inversion: serialized Pallas reduction vs one-shot oracle, both
    # resolved from the strategy registry
    serial = resolve("serial?backend=pallas&chunk=512")
    tree = resolve("tree")
    x = jax.random.normal(jax.random.PRNGKey(0), (4096, 256), jnp.float32)
    t_kernel = _time(lambda a: serial.sum(a, axis=0), x)
    # timing oracle stays the fused one-shot reduction (XLA's hard adder
    # tree) — tree.sum's explicit per-level jnp path fixes reassociation
    # order for parity tests but is a multi-dispatch eager loop, not a fair
    # latency baseline
    t_oracle = _time(lambda a: jnp.sum(a, axis=0), x)
    got = np.asarray(serial.sum(x, axis=0))
    np.testing.assert_allclose(got, np.asarray(ref.moa_reduce_ref(x)),
                               rtol=1e-5, atol=1e-4)
    # working set straight from the strategies' own cost model
    # (live operands per sequential step × feature width × f32)
    ws_serial = serial.cost(4096, "float32")["working_set_operands"] * 256 * 4
    ws_tree = tree.cost(4096, "float32")["working_set_operands"] * 256 * 4
    if verbose:
        print(f"# TPU analogue (interpret-mode timing, structural VMEM):")
        print(f"#   serialized kernel {t_kernel:.0f}us vs one-shot "
              f"{t_oracle:.0f}us; working set {ws_serial//1024}KiB vs "
              f"{ws_tree//1024}KiB ({ws_tree/ws_serial:.0f}x smaller)")
    elapsed_us = (time.perf_counter() - t0) * 1e6
    return {
        "us_per_call": elapsed_us,
        "derived": (f"fpga_serial_wins={serial_wins}/{len(CLUSTERS)}"
                    f"(paper:0);tpu_vmem_reduction={ws_tree/ws_serial:.0f}x"),
    }
