"""Serving benchmark: continuous-batching engine under a Poisson workload,
JSON results (the BENCH trajectory's machine-readable record).

Two record schemas (both validated by ``scripts/check_bench_schema.py``):

* ``serving-v1`` (default): one engine run — run configuration,
  per-request records (TTFT ms, per-token latency ms, tok/s,
  strategy-priced MOA FLOPs) and the aggregate report.
* ``serving-v2`` (``--paged``): the same workload through **both** cache
  layouts — dense per-slot regions and the paged block pool — plus a
  comparison block (paged-vs-dense TTFT, prefix hits, resident KV bytes
  vs the dense reservation). ``--shared-prefix`` swaps in the
  system-prompt-style workload that actually exercises the prefix cache.

  PYTHONPATH=src python -m benchmarks.serving --smoke --json out.json
  PYTHONPATH=src python -m benchmarks.serving --smoke --paged \
      --shared-prefix --block-size 8 --json paged.json
"""

from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.configs.registry import get_config, smoke_config
from repro.models.api import build_model
from repro.serve import (GREEDY, Sampler, ServeEngine, poisson_workload,
                         shared_prefix_workload)


def _build(arch: str, smoke: bool):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "encoder":
        raise ValueError("encoder-only arch has no decode step")
    return cfg, build_model(cfg)


def _workload_factory(cfg, *, requests, rate_rps, shared_prefix, prefix_len,
                      n_prefixes, prompt_len_range, gen_len_range,
                      temperature, seed):
    sampler = Sampler(temperature) if temperature > 0 else GREEDY
    if shared_prefix:
        return lambda: shared_prefix_workload(
            n_requests=requests, vocab=cfg.vocab, rate_rps=rate_rps,
            n_prefixes=n_prefixes, prefix_len=prefix_len,
            suffix_len_range=(0, max(prompt_len_range[1] - prefix_len, 0)),
            gen_len_range=gen_len_range, sampler=sampler, seed=seed)
    return lambda: poisson_workload(
        n_requests=requests, vocab=cfg.vocab, rate_rps=rate_rps,
        prompt_len_range=prompt_len_range, gen_len_range=gen_len_range,
        sampler=sampler, seed=seed)


def run(*, arch: str = "llama3-8b", smoke: bool = True, requests: int = 8,
        rate_rps: float = 50.0, slots: int = 4, max_len: int = 96,
        prompt_len_range=(4, 24), gen_len_range=(2, 12),
        temperature: float = 0.0, seed: int = 0,
        warmup: bool = True, shared_prefix: bool = False,
        prefix_len: int = 16, n_prefixes: int = 2) -> dict:
    """One dense engine run; returns the ``serving-v1`` record.

    ``warmup`` replays the same workload once unmeasured first, so XLA
    compilation of each prefill bucket and the decode step lands outside
    the measured TTFT / per-token distributions.
    """
    cfg, model = _build(arch, smoke)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    engine = ServeEngine(model, params, n_slots=slots, max_len=max_len,
                         rng=rng)
    make_workload = _workload_factory(
        cfg, requests=requests, rate_rps=rate_rps,
        shared_prefix=shared_prefix, prefix_len=prefix_len,
        n_prefixes=n_prefixes, prompt_len_range=prompt_len_range,
        gen_len_range=gen_len_range, temperature=temperature, seed=seed)
    if warmup:
        engine.run(make_workload())
    results, report = engine.run(make_workload())
    return {
        "schema": "serving-v1",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_slots": slots,
            "max_len": max_len, "requests": requests, "rate_rps": rate_rps,
            "prompt_len_range": list(prompt_len_range),
            "gen_len_range": list(gen_len_range),
            "temperature": temperature, "seed": seed, "warmup": warmup,
            "shared_prefix": shared_prefix,
        },
        "requests": [r.to_json() for r in results],
        "aggregate": report,
    }


def run_paged(*, arch: str = "llama3-8b", smoke: bool = True,
              requests: int = 8, rate_rps: float = 50.0, slots: int = 4,
              max_len: int = 96, block_size: int = 16, n_blocks: int = 0,
              prompt_len_range=(4, 24), gen_len_range=(2, 12),
              temperature: float = 0.0, seed: int = 0, warmup: bool = True,
              shared_prefix: bool = True, prefix_len: int = 16,
              n_prefixes: int = 2) -> dict:
    """Dense-vs-paged comparison on one workload; ``serving-v2`` record.

    Both engines serve the identical request stream (same seed) so the
    TTFT columns differ only through the cache layout: the paged engine's
    prefix-cache hits skip shared prefill compute (dense family), and its
    ``resident_kv_bytes`` prices pages in use instead of the
    ``n_slots x max_len`` reservation.
    """
    cfg, model = _build(arch, smoke)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    make_workload = _workload_factory(
        cfg, requests=requests, rate_rps=rate_rps,
        shared_prefix=shared_prefix, prefix_len=prefix_len,
        n_prefixes=n_prefixes, prompt_len_range=prompt_len_range,
        gen_len_range=gen_len_range, temperature=temperature, seed=seed)
    runs = {}
    for mode in ("dense", "paged"):
        engine = ServeEngine(
            model, params, n_slots=slots, max_len=max_len,
            paged=(mode == "paged"), block_size=block_size,
            n_blocks=n_blocks or None, rng=rng)
        if warmup:
            # paged: twice — the first replay warms the prefix trie, the
            # second compiles the suffix-prefill shapes that only occur
            # once admissions start hitting the warm trie
            for _ in range(2 if mode == "paged" else 1):
                engine.run(make_workload())
        results, report = engine.run(make_workload())
        runs[mode] = {"requests": [r.to_json() for r in results],
                      "aggregate": report}
    paged_agg = runs["paged"]["aggregate"]
    comparison = {
        "ttft_p50_ms_dense": runs["dense"]["aggregate"]["ttft_ms"]["p50"],
        "ttft_p50_ms_paged": paged_agg["ttft_ms"]["p50"],
        "prefix_hits": paged_agg["paged"]["prefix_hits"],
        "prefix_hit_rate": paged_agg["paged"]["prefix_hit_rate"],
        "cached_prompt_tokens": sum(
            r["cached_prompt_tokens"] for r in runs["paged"]["requests"]),
        "resident_kv_bytes": paged_agg["paged"]["resident_kv_bytes"],
        "dense_equiv_kv_bytes": paged_agg["paged"]["dense_equiv_kv_bytes"],
    }
    return {
        "schema": "serving-v2",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_slots": slots,
            "max_len": max_len, "block_size": block_size,
            "n_blocks": paged_agg["paged"]["n_blocks"],
            "requests": requests, "rate_rps": rate_rps,
            "prompt_len_range": list(prompt_len_range),
            "gen_len_range": list(gen_len_range),
            "temperature": temperature, "seed": seed, "warmup": warmup,
            "shared_prefix": shared_prefix, "prefix_len": prefix_len,
            "n_prefixes": n_prefixes,
        },
        "dense": runs["dense"],
        "paged": runs["paged"],
        "comparison": comparison,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Continuous-batching serving benchmark (JSON output)")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=50.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="run the dense-vs-paged comparison (serving-v2)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="[--paged] tokens per physical KV page")
    ap.add_argument("--blocks", type=int, default=0,
                    help="[--paged] pool pages (0 = dense equivalent)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared-prefix workload (system-prompt style)")
    ap.add_argument("--prefix-len", type=int, default=16,
                    help="[--shared-prefix] shared prefix tokens")
    ap.add_argument("--prefixes", type=int, default=2,
                    help="[--shared-prefix] distinct prefixes")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the unmeasured warmup replay (metrics then "
                         "include XLA compile time)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON record here (default: stdout)")
    args = ap.parse_args(argv)

    common = dict(arch=args.arch, smoke=args.smoke, requests=args.requests,
                  rate_rps=args.rate, slots=args.slots, max_len=args.max_len,
                  temperature=args.temperature, seed=args.seed,
                  warmup=not args.no_warmup,
                  shared_prefix=args.shared_prefix,
                  prefix_len=args.prefix_len, n_prefixes=args.prefixes)
    if args.paged:
        record = run_paged(block_size=args.block_size, n_blocks=args.blocks,
                           **common)
    else:
        record = run(**common)
    text = json.dumps(record, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        if record["schema"] == "serving-v2":
            c = record["comparison"]
            print(f"[bench] wrote {args.json}: serving-v2, "
                  f"ttft p50 dense={c['ttft_p50_ms_dense']:.0f}ms "
                  f"paged={c['ttft_p50_ms_paged']:.0f}ms, "
                  f"prefix hits={c['prefix_hits']}, "
                  f"resident={c['resident_kv_bytes']:,}B / "
                  f"dense {c['dense_equiv_kv_bytes']:,}B", file=sys.stderr)
        else:
            agg = record["aggregate"]
            print(f"[bench] wrote {args.json}: {agg['n_requests']} requests, "
                  f"{agg['tok_per_s']:.1f} tok/s, "
                  f"ttft p50={agg['ttft_ms']['p50']:.0f}ms, "
                  f"occupancy={agg['slot_occupancy']:.2f}", file=sys.stderr)
    else:
        print(text)


if __name__ == "__main__":
    main()
