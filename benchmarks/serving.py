"""Serving benchmark: continuous-batching engine under a Poisson workload,
JSON results (the BENCH trajectory's machine-readable record).

Emits one JSON document with the run configuration, per-request records
(TTFT ms, per-token latency ms, tok/s, strategy-priced MOA FLOPs) and the
aggregate report (total tok/s, latency distributions, slot occupancy,
slot reuse).

  PYTHONPATH=src python -m benchmarks.serving --smoke --json out.json
  PYTHONPATH=src python -m benchmarks.serving --arch mamba2-370m --smoke \
      --requests 16 --rate 100 --slots 8 --json out.json
"""

from __future__ import annotations

import argparse
import json
import sys

import jax

from repro.configs.registry import get_config, smoke_config
from repro.models.api import build_model
from repro.serve import GREEDY, Sampler, ServeEngine, poisson_workload


def run(*, arch: str = "llama3-8b", smoke: bool = True, requests: int = 8,
        rate_rps: float = 50.0, slots: int = 4, max_len: int = 96,
        prompt_len_range=(4, 24), gen_len_range=(2, 12),
        temperature: float = 0.0, seed: int = 0,
        warmup: bool = True) -> dict:
    """Run the workload through the engine; returns the JSON-able record.

    ``warmup`` replays the same workload once unmeasured first, so XLA
    compilation of each prefill bucket and the decode step lands outside
    the measured TTFT / per-token distributions.
    """
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "encoder":
        raise ValueError("encoder-only arch has no decode step")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    engine = ServeEngine(model, params, n_slots=slots, max_len=max_len,
                         rng=rng)
    make_workload = lambda: poisson_workload(
        n_requests=requests, vocab=cfg.vocab, rate_rps=rate_rps,
        prompt_len_range=prompt_len_range, gen_len_range=gen_len_range,
        sampler=Sampler(temperature) if temperature > 0 else GREEDY,
        seed=seed)
    if warmup:
        engine.run(make_workload())
    results, report = engine.run(make_workload())
    return {
        "schema": "serving-v1",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_slots": slots,
            "max_len": max_len, "requests": requests, "rate_rps": rate_rps,
            "prompt_len_range": list(prompt_len_range),
            "gen_len_range": list(gen_len_range),
            "temperature": temperature, "seed": seed, "warmup": warmup,
        },
        "requests": [r.to_json() for r in results],
        "aggregate": report,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Continuous-batching serving benchmark (JSON output)")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=50.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the unmeasured warmup replay (metrics then "
                         "include XLA compile time)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON record here (default: stdout)")
    args = ap.parse_args(argv)

    record = run(arch=args.arch, smoke=args.smoke, requests=args.requests,
                 rate_rps=args.rate, slots=args.slots, max_len=args.max_len,
                 temperature=args.temperature, seed=args.seed,
                 warmup=not args.no_warmup)
    text = json.dumps(record, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        agg = record["aggregate"]
        print(f"[bench] wrote {args.json}: {agg['n_requests']} requests, "
              f"{agg['tok_per_s']:.1f} tok/s, "
              f"ttft p50={agg['ttft_ms']['p50']:.0f}ms, "
              f"occupancy={agg['slot_occupancy']:.2f}", file=sys.stderr)
    else:
        print(text)


if __name__ == "__main__":
    main()
