"""Serving benchmark: continuous-batching engine under a Poisson workload,
JSON results (the BENCH trajectory's machine-readable record).

Record schemas (all validated by ``scripts/check_bench_schema.py``):

* ``serving-v1`` (default): one engine run — run configuration,
  per-request records (TTFT ms, per-token latency ms, tok/s,
  strategy-priced MOA FLOPs) and the aggregate report.
* ``serving-v2`` (``--paged``): the same workload through **both** cache
  layouts — dense per-slot regions and the paged block pool — plus a
  comparison block (paged-vs-dense TTFT, prefix hits, resident KV bytes
  vs the dense reservation). ``--shared-prefix`` swaps in the
  system-prompt-style workload that actually exercises the prefix cache.
* ``serving-v3`` (``--spec-decode``): the same workload through plain
  decode and speculative decode at a **sweep of forced accept rates**
  (oracle drafter with independent per-token corruption) — the paper's
  "does the multiplexing gamble pay" question measured end-to-end, with
  the acceptance-aware cost-model prediction alongside each measured
  point (docs/spec-decode.md).

* ``serving-v4`` (``--mesh DxM``): the same workload through a
  single-device engine and a **mesh-sharded** engine (params
  tensor-parallel, KV cache sharded over slots and heads, per
  ``docs/sharded-serving.md``) — per-axis mesh shape, tok/s and TTFT side
  by side, plus a greedy token-parity bit (the sharded mapping validated
  on the actual device topology, the paper's core lesson). On CPU the
  mesh runs on XLA host-platform devices.

* ``serving-v5`` (``--slo``): the same **bursty, deadline-carrying**
  workload through a FIFO engine and an SLO engine (deadline-aware
  admission + preemptive spill/revive + chunked prefill,
  ``docs/slo-scheduling.md``), both on a deterministic
  :class:`~repro.serve.clock.StepClock` — p99 TTFT of the deadline
  cohort, attainment and goodput-under-SLO side by side, plus a greedy
  token-parity bit (preemption must not change any request's tokens).

* ``serving-v6`` (``--backends``): the same workload through two paged
  engines that differ only in the attention backend — ``jnp`` (gathered
  dense KV view, reference) vs ``pallas`` (fused block-table flash
  decode/verify, ``docs/kernels.md``) — tok/s and TTFT side by side, the
  per-step gathered-vs-fused attention HBM bytes (the traffic the fused
  kernel removes), and a ``greedy_tokens_match`` bit.

* ``serving-v7`` (``--replicas N``): the same greedy workload through a
  failure-free replica fleet and a **chaos** fleet — injected replica
  crashes (heartbeat-detected, requests requeued and re-prefilled
  elsewhere) plus a mid-run checkpoint save that triggers a rolling
  watcher-driven weight reload (``docs/fault-tolerance.md``) — goodput
  and requeue-latency cost of the failures, a ``greedy_tokens_match``
  bit against the failure-free baseline, and the zero-loss /
  zero-reload-drop counters CI gates on.

  PYTHONPATH=src python -m benchmarks.serving --smoke --json out.json
  PYTHONPATH=src python -m benchmarks.serving --smoke --paged \
      --shared-prefix --block-size 8 --json paged.json
  PYTHONPATH=src python -m benchmarks.serving --smoke --spec-decode \
      --spec-k 3 --json spec.json
  PYTHONPATH=src python -m benchmarks.serving --smoke --mesh 2x4 \
      --json sharded.json
  PYTHONPATH=src python -m benchmarks.serving --smoke --slo \
      --json slo.json
  PYTHONPATH=src python -m benchmarks.serving --smoke --backends \
      --block-size 8 --json backends.json
"""

from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.configs.registry import get_config, smoke_config
from repro.launch.costing import spec_decode_cost
from repro.launch.mesh import ensure_host_devices, make_mesh, parse_mesh
from repro.models.api import build_model
from repro.serve import (GREEDY, OracleDrafter, Sampler, ServeEngine,
                         StepClock, bursty_workload, poisson_workload,
                         shared_prefix_workload)


def _build(arch: str, smoke: bool):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_config(cfg)
    if cfg.family == "encoder":
        raise ValueError("encoder-only arch has no decode step")
    return cfg, build_model(cfg)


def _workload_factory(cfg, *, requests, rate_rps, shared_prefix, prefix_len,
                      n_prefixes, prompt_len_range, gen_len_range,
                      temperature, seed):
    sampler = Sampler(temperature) if temperature > 0 else GREEDY
    if shared_prefix:
        return lambda: shared_prefix_workload(
            n_requests=requests, vocab=cfg.vocab, rate_rps=rate_rps,
            n_prefixes=n_prefixes, prefix_len=prefix_len,
            suffix_len_range=(0, max(prompt_len_range[1] - prefix_len, 0)),
            gen_len_range=gen_len_range, sampler=sampler, seed=seed)
    return lambda: poisson_workload(
        n_requests=requests, vocab=cfg.vocab, rate_rps=rate_rps,
        prompt_len_range=prompt_len_range, gen_len_range=gen_len_range,
        sampler=sampler, seed=seed)


def run(*, arch: str = "llama3-8b", smoke: bool = True, requests: int = 8,
        rate_rps: float = 50.0, slots: int = 4, max_len: int = 96,
        prompt_len_range=(4, 24), gen_len_range=(2, 12),
        temperature: float = 0.0, seed: int = 0,
        warmup: bool = True, shared_prefix: bool = False,
        prefix_len: int = 16, n_prefixes: int = 2) -> dict:
    """One dense engine run; returns the ``serving-v1`` record.

    ``warmup`` replays the same workload once unmeasured first, so XLA
    compilation of each prefill bucket and the decode step lands outside
    the measured TTFT / per-token distributions; the measured run also
    executes the engine's warmup tick, so any residual compile time is
    reported as ``aggregate.compile_s`` instead of folding into
    ``wall_s``.
    """
    cfg, model = _build(arch, smoke)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    engine = ServeEngine(model, params, n_slots=slots, max_len=max_len,
                         rng=rng)
    make_workload = _workload_factory(
        cfg, requests=requests, rate_rps=rate_rps,
        shared_prefix=shared_prefix, prefix_len=prefix_len,
        n_prefixes=n_prefixes, prompt_len_range=prompt_len_range,
        gen_len_range=gen_len_range, temperature=temperature, seed=seed)
    if warmup:
        engine.run(make_workload())
    results, report = engine.run(make_workload(), warmup=warmup)
    return {
        "schema": "serving-v1",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_slots": slots,
            "max_len": max_len, "requests": requests, "rate_rps": rate_rps,
            "prompt_len_range": list(prompt_len_range),
            "gen_len_range": list(gen_len_range),
            "temperature": temperature, "seed": seed, "warmup": warmup,
            "shared_prefix": shared_prefix,
        },
        "requests": [r.to_json() for r in results],
        "aggregate": report,
    }


def run_paged(*, arch: str = "llama3-8b", smoke: bool = True,
              requests: int = 8, rate_rps: float = 50.0, slots: int = 4,
              max_len: int = 96, block_size: int = 16, n_blocks: int = 0,
              prompt_len_range=(4, 24), gen_len_range=(2, 12),
              temperature: float = 0.0, seed: int = 0, warmup: bool = True,
              shared_prefix: bool = True, prefix_len: int = 16,
              n_prefixes: int = 2,
              attn_backend: str = None) -> dict:
    """Dense-vs-paged comparison on one workload; ``serving-v2`` record.

    Both engines serve the identical request stream (same seed) so the
    TTFT columns differ only through the cache layout: the paged engine's
    prefix-cache hits skip shared prefill compute (dense family), and its
    ``resident_kv_bytes`` prices pages in use instead of the
    ``n_slots x max_len`` reservation.
    """
    cfg, model = _build(arch, smoke)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    make_workload = _workload_factory(
        cfg, requests=requests, rate_rps=rate_rps,
        shared_prefix=shared_prefix, prefix_len=prefix_len,
        n_prefixes=n_prefixes, prompt_len_range=prompt_len_range,
        gen_len_range=gen_len_range, temperature=temperature, seed=seed)
    runs = {}
    for mode in ("dense", "paged"):
        engine = ServeEngine(
            model, params, n_slots=slots, max_len=max_len,
            paged=(mode == "paged"), block_size=block_size,
            n_blocks=n_blocks or None, rng=rng,
            attn_backend=attn_backend if mode == "paged" else None)
        if warmup:
            # paged: twice — the first replay warms the prefix trie, the
            # second compiles the suffix-prefill shapes that only occur
            # once admissions start hitting the warm trie
            for _ in range(2 if mode == "paged" else 1):
                engine.run(make_workload())
        results, report = engine.run(make_workload(), warmup=warmup)
        runs[mode] = {"requests": [r.to_json() for r in results],
                      "aggregate": report}
    paged_agg = runs["paged"]["aggregate"]
    comparison = {
        "ttft_p50_ms_dense": runs["dense"]["aggregate"]["ttft_ms"]["p50"],
        "ttft_p50_ms_paged": paged_agg["ttft_ms"]["p50"],
        "prefix_hits": paged_agg["paged"]["prefix_hits"],
        "prefix_hit_rate": paged_agg["paged"]["prefix_hit_rate"],
        "cached_prompt_tokens": sum(
            r["cached_prompt_tokens"] for r in runs["paged"]["requests"]),
        "resident_kv_bytes": paged_agg["paged"]["resident_kv_bytes"],
        "dense_equiv_kv_bytes": paged_agg["paged"]["dense_equiv_kv_bytes"],
    }
    return {
        "schema": "serving-v2",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_slots": slots,
            "max_len": max_len, "block_size": block_size,
            "n_blocks": paged_agg["paged"]["n_blocks"],
            "requests": requests, "rate_rps": rate_rps,
            "prompt_len_range": list(prompt_len_range),
            "gen_len_range": list(gen_len_range),
            "temperature": temperature, "seed": seed, "warmup": warmup,
            "shared_prefix": shared_prefix, "prefix_len": prefix_len,
            "n_prefixes": n_prefixes,
        },
        "dense": runs["dense"],
        "paged": runs["paged"],
        "comparison": comparison,
    }


def _slot_norm_tokens_per_step(agg: dict) -> float:
    """Tick-emitted tokens per active-slot step (plain decode ≡ 1.0).

    Matches the spec report's normalization: each request's first token
    comes from its prefill, not a decode tick, so it is excluded.
    """
    slot_steps = agg["slot_occupancy"] * agg["decode_steps"] * agg["n_slots"]
    return (agg["total_new_tokens"] - agg["n_requests"]) \
        / max(slot_steps, 1e-9)


def run_spec(*, arch: str = "llama3-8b", smoke: bool = True,
             requests: int = 8, rate_rps: float = 50.0, slots: int = 4,
             max_len: int = 96, spec_k: int = 3,
             accept_probs=(1.0, 0.75, 0.5, 0.0),
             prompt_len_range=(4, 24), gen_len_range=(2, 12),
             temperature: float = 0.0, seed: int = 0,
             warmup: bool = True) -> dict:
    """Plain-vs-speculative comparison at a sweep of forced accept rates;
    ``serving-v3`` record.

    Every run serves the identical request stream. The oracle drafter
    proposes the target's own greedy continuation with each token
    independently corrupted at rate ``1 - accept_prob``, so the *measured*
    accept rate tracks the knob and ``tokens_per_step`` (slot-step
    normalized: plain decode ≡ 1.0) traces the payoff curve that the
    acceptance-aware estimator (:func:`repro.launch.costing
    .spec_decode_cost`) predicts — measured and predicted land side by
    side in ``comparison``, the paper's promising-on-paper vs
    synthesized-reality split.
    """
    cfg, model = _build(arch, smoke)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    make_workload = _workload_factory(
        cfg, requests=requests, rate_rps=rate_rps, shared_prefix=False,
        prefix_len=0, n_prefixes=1, prompt_len_range=prompt_len_range,
        gen_len_range=gen_len_range, temperature=temperature, seed=seed)

    engine = ServeEngine(model, params, n_slots=slots, max_len=max_len,
                         rng=rng)
    if warmup:
        engine.run(make_workload())
    plain_results, plain_report = engine.run(make_workload(),
                                             warmup=warmup)
    plain = {"requests": [r.to_json() for r in plain_results],
             "aggregate": plain_report}
    plain_tps = _slot_norm_tokens_per_step(plain_report)

    s_attn = float(sum(prompt_len_range) / 2 + sum(gen_len_range) / 2)
    spec_runs, curve = [], []
    for accept in accept_probs:
        engine = ServeEngine(
            model, params, n_slots=slots, max_len=max_len, rng=rng,
            drafter=OracleDrafter(spec_k, accept_prob=accept, seed=seed))
        if warmup:
            engine.run(make_workload())
        results, report = engine.run(make_workload(), warmup=warmup)
        spec_runs.append({"accept_prob": accept,
                          "requests": [r.to_json() for r in results],
                          "aggregate": report})
        predicted = spec_decode_cost(cfg, k=spec_k, accept_prob=accept,
                                     s_attn=s_attn, draft_cfg=cfg)
        sp = report["spec"]
        curve.append({
            "accept_prob": accept,
            "measured_accept_rate": sp["accept_rate"],
            "tokens_per_step": sp["tokens_per_step"],
            "speedup_vs_plain": sp["tokens_per_step"] / max(plain_tps, 1e-9),
            "predicted_tokens_per_step":
                predicted["expected_tokens_per_step"],
            "predicted_flops_overhead": predicted["flops_overhead"],
            "ttft_p50_ms": report["ttft_ms"]["p50"],
        })
    best = max(curve, key=lambda c: c["tokens_per_step"])
    return {
        "schema": "serving-v3",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_slots": slots,
            "max_len": max_len, "requests": requests, "rate_rps": rate_rps,
            "prompt_len_range": list(prompt_len_range),
            "gen_len_range": list(gen_len_range),
            "temperature": temperature, "seed": seed, "warmup": warmup,
            "spec_k": spec_k, "accept_probs": list(accept_probs),
            "drafter": "oracle",
        },
        "plain": plain,
        "spec_runs": spec_runs,
        "comparison": {
            "tokens_per_step_plain": plain_tps,
            "ttft_p50_ms_plain": plain_report["ttft_ms"]["p50"],
            "curve": curve,
            "best_tokens_per_step": best["tokens_per_step"],
            "best_accept_prob": best["accept_prob"],
        },
    }


def run_sharded(*, arch: str = "llama3-8b", smoke: bool = True,
                requests: int = 8, rate_rps: float = 50.0, slots: int = 4,
                max_len: int = 96, mesh_shape=(2, 4),
                prompt_len_range=(4, 24), gen_len_range=(2, 12),
                temperature: float = 0.0, seed: int = 0,
                warmup: bool = True) -> dict:
    """Single-device vs mesh-sharded engine on one workload; ``serving-v4``.

    The sharded engine places the parameters tensor-parallel and the KV
    cache slot/head-sharded (``docs/sharded-serving.md``); both engines
    serve the identical request stream, so the comparison isolates the
    device mapping: tok/s and TTFT per topology, plus
    ``greedy_tokens_match`` — the bit-identical-output check that the
    paper's "validate the mapping on the device" lesson demands. The mesh
    must already be satisfiable by the visible devices (the CLI requests
    XLA host-platform devices before jax initializes).
    """
    cfg, model = _build(arch, smoke)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    make_workload = _workload_factory(
        cfg, requests=requests, rate_rps=rate_rps, shared_prefix=False,
        prefix_len=0, n_prefixes=1, prompt_len_range=prompt_len_range,
        gen_len_range=gen_len_range, temperature=temperature, seed=seed)
    mesh = make_mesh(tuple(mesh_shape))
    runs = {}
    for mode, m in (("single", None), ("sharded", mesh)):
        engine = ServeEngine(model, params, n_slots=slots, max_len=max_len,
                             rng=rng, mesh=m)
        if warmup:
            engine.run(make_workload())
        results, report = engine.run(make_workload(), warmup=warmup)
        runs[mode] = {"results": results,
                      "requests": [r.to_json() for r in results],
                      "aggregate": report}
    single_agg = runs["single"]["aggregate"]
    shard_agg = runs["sharded"]["aggregate"]
    tokens_match = all(
        np.array_equal(a.tokens, b.tokens)
        for a, b in zip(runs["single"]["results"],
                        runs["sharded"]["results"]))
    for mode in runs:
        del runs[mode]["results"]
    return {
        "schema": "serving-v4",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_slots": slots,
            "max_len": max_len, "requests": requests, "rate_rps": rate_rps,
            "prompt_len_range": list(prompt_len_range),
            "gen_len_range": list(gen_len_range),
            "temperature": temperature, "seed": seed, "warmup": warmup,
            "mesh": {
                "shape": [int(s) for s in mesh.devices.shape],
                "axes": list(mesh.axis_names),
                "n_devices": int(mesh.devices.size),
            },
        },
        "single": runs["single"],
        "sharded": runs["sharded"],
        "comparison": {
            "greedy_tokens_match": bool(tokens_match),
            "tok_per_s_single": single_agg["tok_per_s"],
            "tok_per_s_sharded": shard_agg["tok_per_s"],
            "sharded_speedup": shard_agg["tok_per_s"]
                / max(single_agg["tok_per_s"], 1e-9),
            "ttft_p50_ms_single": single_agg["ttft_ms"]["p50"],
            "ttft_p50_ms_sharded": shard_agg["ttft_ms"]["p50"],
            "compile_s_single": single_agg["compile_s"],
            "compile_s_sharded": shard_agg["compile_s"],
        },
    }


def run_backends(*, arch: str = "llama3-8b", smoke: bool = True,
                 requests: int = 8, rate_rps: float = 50.0, slots: int = 4,
                 max_len: int = 96, block_size: int = 16, n_blocks: int = 0,
                 prompt_len_range=(4, 24), gen_len_range=(2, 12),
                 temperature: float = 0.0, seed: int = 0,
                 warmup: bool = True, shared_prefix: bool = False,
                 prefix_len: int = 16, n_prefixes: int = 2) -> dict:
    """Gather-vs-fused paged attention on one workload; ``serving-v6``.

    Both engines serve the identical request stream through the paged
    pool; they differ only in ``attn_backend`` — ``jnp`` streams the
    gathered (padded, high-water-bucketed) KV view, ``pallas`` walks the
    block table inside the fused flash kernel and touches only live
    pages. ``comparison.kv_bytes_per_step`` records both byte counts at
    every decode step (same cursors, so the fused column is <= the
    gathered one by construction — the bandwidth headroom the kernel
    converts into tok/s), and ``greedy_tokens_match`` asserts the two
    backends emit bit-identical greedy tokens. On CPU the pallas engine
    runs the kernels in interpret mode, so the token-parity bit is
    meaningful everywhere while the tok/s columns only are on TPU.
    """
    cfg, model = _build(arch, smoke)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    make_workload = _workload_factory(
        cfg, requests=requests, rate_rps=rate_rps,
        shared_prefix=shared_prefix, prefix_len=prefix_len,
        n_prefixes=n_prefixes, prompt_len_range=prompt_len_range,
        gen_len_range=gen_len_range, temperature=temperature, seed=seed)
    runs = {}
    logs = {}
    for backend in ("jnp", "pallas"):
        engine = ServeEngine(
            model, params, n_slots=slots, max_len=max_len, paged=True,
            block_size=block_size, n_blocks=n_blocks or None, rng=rng,
            attn_backend=backend)
        if warmup:
            for _ in range(2):
                engine.run(make_workload())
        results, report = engine.run(make_workload(), warmup=warmup)
        runs[backend] = {"results": results,
                         "requests": [r.to_json() for r in results],
                         "aggregate": report}
        logs[backend] = [[int(g), int(f)] for g, f in engine._kv_step_log]
    jnp_agg = runs["jnp"]["aggregate"]
    pallas_agg = runs["pallas"]["aggregate"]
    tokens_match = all(
        np.array_equal(a.tokens, b.tokens)
        for a, b in zip(runs["jnp"]["results"], runs["pallas"]["results"]))
    for backend in runs:
        del runs[backend]["results"]
    # same workload + parity => identical cursor streams; keep one log
    step_log = logs["jnp"]
    comparison = {
        "greedy_tokens_match": bool(tokens_match),
        "tok_per_s_jnp": jnp_agg["tok_per_s"],
        "tok_per_s_pallas": pallas_agg["tok_per_s"],
        "pallas_speedup": pallas_agg["tok_per_s"]
            / max(jnp_agg["tok_per_s"], 1e-9),
        "ttft_p50_ms_jnp": jnp_agg["ttft_ms"]["p50"],
        "ttft_p50_ms_pallas": pallas_agg["ttft_ms"]["p50"],
        "compile_s_jnp": jnp_agg["compile_s"],
        "compile_s_pallas": pallas_agg["compile_s"],
        "gathered_kv_bytes": jnp_agg["paged"]["gathered_kv_bytes"],
        "fused_kv_bytes": jnp_agg["paged"]["fused_kv_bytes"],
        "kv_bytes_per_step": step_log,
        "fused_le_gathered_every_step": bool(
            all(f <= g for g, f in step_log)),
        "kv_bytes_saved_frac": 1.0
            - jnp_agg["paged"]["fused_kv_bytes"]
            / max(jnp_agg["paged"]["gathered_kv_bytes"], 1),
    }
    return {
        "schema": "serving-v6",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_slots": slots,
            "max_len": max_len, "block_size": block_size,
            "n_blocks": jnp_agg["paged"]["n_blocks"],
            "requests": requests, "rate_rps": rate_rps,
            "prompt_len_range": list(prompt_len_range),
            "gen_len_range": list(gen_len_range),
            "temperature": temperature, "seed": seed, "warmup": warmup,
            "shared_prefix": shared_prefix,
            "backends": ["jnp", "pallas"],
            "default_backend": jax.default_backend(),
        },
        "jnp": runs["jnp"],
        "pallas": runs["pallas"],
        "comparison": comparison,
    }


def run_slo(*, arch: str = "llama3-8b", smoke: bool = True,
            slots: int = 2, max_len: int = 96, n_long: int = 0,
            n_burst: int = 8, long_prompt_len: int = 24,
            long_gen_len: int = 40, burst_prompt_len: int = 8,
            burst_gen_len: int = 4, burst_at_s: float = 0.004,
            burst_deadline_s: float = 0.035, prefill_chunk: int = 16,
            clock_dt: float = 1e-3, seed: int = 0) -> dict:
    """FIFO-vs-SLO comparison on one bursty workload; ``serving-v5``.

    Long generations grab every slot, then a burst of short requests with
    tight TTFT deadlines lands behind them. Both engines run on a
    deterministic :class:`StepClock` (virtual time advances per engine
    clock read, so XLA compile time cannot skew any latency — no warmup
    replay needed and the record is exactly reproducible). FIFO queues
    the burst until a long decode finishes and blows the deadline cohort's
    p99 TTFT; the SLO engine preempts the longs (their first token is
    already banked), serves the burst, and revives them — same tokens for
    every request, very different tail latency. The SLO engine also
    prefills in ``prefill_chunk``-token chunks so a long admission never
    blocks a tick for more than one chunk.
    """
    cfg, model = _build(arch, smoke)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    n_long = n_long or slots
    make_workload = lambda: bursty_workload(  # noqa: E731
        vocab=cfg.vocab, n_long=n_long, n_burst=n_burst,
        long_prompt_len=long_prompt_len, long_gen_len=long_gen_len,
        burst_prompt_len=burst_prompt_len, burst_gen_len=burst_gen_len,
        burst_at_s=burst_at_s, burst_deadline_s=burst_deadline_s,
        seed=seed)
    runs = {}
    for policy in ("fifo", "slo"):
        engine = ServeEngine(
            model, params, n_slots=slots, max_len=max_len, rng=rng,
            clock=StepClock(dt=clock_dt), scheduling=policy,
            prefill_chunk_tokens=(prefill_chunk or None)
            if policy == "slo" else None)
        results, report = engine.run(make_workload())
        runs[policy] = {"results": results,
                        "requests": [r.to_json() for r in results],
                        "aggregate": report}
    tokens_match = all(
        np.array_equal(a.tokens, b.tokens)
        for a, b in zip(runs["fifo"]["results"], runs["slo"]["results"]))
    for policy in runs:
        del runs[policy]["results"]
    f, s = (runs[p]["aggregate"]["slo"] for p in ("fifo", "slo"))
    comparison = {
        "greedy_tokens_match": bool(tokens_match),
        "attainment_fifo": f["attainment"],
        "attainment_slo": s["attainment"],
        "deadline_ttft_p99_ms_fifo": f["deadline_ttft_ms"]["p99"],
        "deadline_ttft_p99_ms_slo": s["deadline_ttft_ms"]["p99"],
        "goodput_tok_per_s_fifo": f["goodput_tok_per_s"],
        "goodput_tok_per_s_slo": s["goodput_tok_per_s"],
        "preemptions": s["preemptions"],
        "spills": s["spills"],
        "revivals": s["revivals"],
        "prefill_chunk_count": s["prefill_chunk_count"],
        "slo_wins_p99": bool(s["deadline_ttft_ms"]["p99"]
                             < f["deadline_ttft_ms"]["p99"]),
        "slo_wins_goodput": bool(s["goodput_tok_per_s"]
                                 > f["goodput_tok_per_s"]),
    }
    return {
        "schema": "serving-v5",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_slots": slots,
            "max_len": max_len, "n_long": n_long, "n_burst": n_burst,
            "long_prompt_len": long_prompt_len,
            "long_gen_len": long_gen_len,
            "burst_prompt_len": burst_prompt_len,
            "burst_gen_len": burst_gen_len, "burst_at_s": burst_at_s,
            "burst_deadline_s": burst_deadline_s,
            "prefill_chunk_tokens": prefill_chunk, "clock_dt": clock_dt,
            "seed": seed,
        },
        "fifo": runs["fifo"],
        "slo": runs["slo"],
        "comparison": comparison,
    }


def run_replicas(*, arch: str = "llama3-8b", smoke: bool = True,
                 n_replicas: int = 3, requests: int = 8,
                 rate_rps: float = 100.0, slots: int = 2, max_len: int = 96,
                 prompt_len_range=(4, 16), gen_len_range=(3, 8),
                 kill_schedule=((6, 1),), reload_at_step: int = 12,
                 miss_limit: int = 3, clock_dt: float = 1e-3,
                 seed: int = 0) -> dict:
    """Failure-free vs chaos replica-set serving; ``serving-v7`` record.

    Both fleets serve the identical greedy workload on a deterministic
    :class:`StepClock`. The chaos fleet additionally takes ``kill_schedule``
    — per-replica :class:`~repro.runtime.failures.FailureInjector` crashes
    at the given router steps (requests requeue after heartbeat detection
    and restart from their prompts elsewhere) — and, at
    ``reload_at_step``, a checkpoint save that the watcher turns into a
    rolling drain → swap → rejoin weight reload. ``comparison`` records
    the goodput cost of the chaos (the dead replica's partial decodes are
    wasted work), the requeue latency distribution, and the two proof
    bits CI gates on: ``greedy_tokens_match`` (every requeued request
    regenerated a bit-identical stream) and ``lost_requests == 0`` with
    ``reload_dropped == 0``.
    """
    import tempfile

    from repro.checkpoint import CheckpointManager, CheckpointWatcher
    from repro.runtime import FailureInjector
    from repro.serve import ReplicaSet

    cfg, model = _build(arch, smoke)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    make_workload = _workload_factory(
        cfg, requests=requests, rate_rps=rate_rps, shared_prefix=False,
        prefix_len=0, n_prefixes=1, prompt_len_range=prompt_len_range,
        gen_len_range=gen_len_range, temperature=0.0, seed=seed)
    kills = {}
    for step, rid in kill_schedule:
        kills.setdefault(int(rid), []).append(int(step))

    def fleet(chaos: bool, tmpdir: str):
        clock = StepClock(dt=clock_dt)
        factory = lambda: ServeEngine(  # noqa: E731
            model, params, n_slots=slots, max_len=max_len, rng=rng,
            clock=clock)
        manager = watcher = None
        actions = {}
        if chaos and reload_at_step:
            manager = CheckpointManager(tmpdir)
            watcher = CheckpointWatcher(manager)
            actions[reload_at_step] = lambda _rs: manager.save(1, params)
        rs = ReplicaSet(
            factory, n_replicas=n_replicas, clock=clock,
            miss_limit=miss_limit,
            failure_injectors={rid: FailureInjector(steps)
                               for rid, steps in kills.items()}
            if chaos else None,
            watcher=watcher,
            load_params=(lambda step: manager.restore(params)[0])
            if watcher else None)
        results, report = rs.run(make_workload(), actions=actions)
        rs.check()
        return results, report

    with tempfile.TemporaryDirectory() as tmpdir:
        base_results, base_report = fleet(False, tmpdir)
        chaos_results, chaos_report = fleet(True, tmpdir)
    tokens_match = len(base_results) == len(chaos_results) and all(
        a.uid == b.uid and np.array_equal(a.tokens, b.tokens)
        for a, b in zip(base_results, chaos_results))

    def _run_record(results, report):
        return {
            "requests": [{"uid": r.uid,
                          "prompt_tokens": r.metrics.prompt_tokens,
                          "new_tokens": r.metrics.new_tokens,
                          "ttft_ms": 1e3 * r.metrics.ttft_s}
                         for r in results],
            "fleet": report,
        }

    return {
        "schema": "serving-v7",
        "config": {
            "arch": cfg.name, "family": cfg.family, "smoke": smoke,
            "moa": cfg.moa_strategy.spec, "n_replicas": n_replicas,
            "n_slots": slots, "max_len": max_len, "requests": requests,
            "rate_rps": rate_rps,
            "prompt_len_range": list(prompt_len_range),
            "gen_len_range": list(gen_len_range),
            "kill_schedule": [[int(s), int(r)] for s, r in kill_schedule],
            "reload_at_step": reload_at_step, "miss_limit": miss_limit,
            "clock_dt": clock_dt, "seed": seed,
        },
        "baseline": _run_record(base_results, base_report),
        "chaos": _run_record(chaos_results, chaos_report),
        "comparison": {
            "greedy_tokens_match": bool(tokens_match),
            "lost_requests": chaos_report["lost_requests"],
            "kills": chaos_report["kills"],
            "deaths_detected": chaos_report["deaths_detected"],
            "requeues": chaos_report["requeues"],
            "requeue_latency_ms": chaos_report["requeue_latency_ms"],
            "reloads_completed": chaos_report["reloads_completed"],
            "reload_dropped": chaos_report["reload_dropped"],
            "goodput_tok_per_s_baseline": base_report["tok_per_s"],
            "goodput_tok_per_s_chaos": chaos_report["tok_per_s"],
            "goodput_ratio": chaos_report["tok_per_s"]
                / max(base_report["tok_per_s"], 1e-9),
            "router_steps_baseline": base_report["router_steps"],
            "router_steps_chaos": chaos_report["router_steps"],
        },
    }


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Continuous-batching serving benchmark (JSON output)")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=50.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="run the dense-vs-paged comparison (serving-v2)")
    ap.add_argument("--backends", action="store_true",
                    help="run the jnp-vs-pallas paged attention backend "
                         "comparison (serving-v6; see docs/kernels.md)")
    ap.add_argument("--attn-backend", default=None,
                    choices=("auto", "jnp", "pallas"),
                    help="[--paged] paged attention backend for the paged "
                         "engine (default: the model config's, usually "
                         "auto)")
    ap.add_argument("--mesh", default="",
                    help="run the single-vs-sharded comparison on a DxM "
                         "device mesh, e.g. 2x4 (serving-v4; see "
                         "docs/sharded-serving.md)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="run the plain-vs-speculative accept-rate sweep "
                         "(serving-v3; see docs/spec-decode.md)")
    ap.add_argument("--slo", action="store_true",
                    help="run the FIFO-vs-SLO bursty-deadline comparison "
                         "on a deterministic virtual clock (serving-v5; "
                         "see docs/slo-scheduling.md)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="run the failure-free-vs-chaos replica-set "
                         "comparison with N replicas (serving-v7; see "
                         "docs/fault-tolerance.md)")
    ap.add_argument("--kill", default="6:1",
                    help="[--replicas] chaos schedule STEP:REPLICA[,...] "
                         "of injected replica crashes")
    ap.add_argument("--reload-at", type=int, default=12,
                    help="[--replicas] router step of the mid-run "
                         "checkpoint save that triggers the rolling hot "
                         "reload (0 = no reload)")
    ap.add_argument("--burst", type=int, default=8,
                    help="[--slo] short tight-deadline requests in the "
                         "burst")
    ap.add_argument("--deadline", type=float, default=0.035,
                    help="[--slo] burst TTFT deadline, virtual seconds "
                         "after arrival")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="[--slo] SLO engine's prefill chunk tokens "
                         "(0 = one-shot)")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="[--spec-decode] draft tokens per verify window")
    ap.add_argument("--accept-probs", default="1.0,0.75,0.5,0.0",
                    help="[--spec-decode] comma-separated forced accept "
                         "probabilities to sweep")
    ap.add_argument("--block-size", type=int, default=16,
                    help="[--paged] tokens per physical KV page")
    ap.add_argument("--blocks", type=int, default=0,
                    help="[--paged] pool pages (0 = dense equivalent)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="shared-prefix workload (system-prompt style)")
    ap.add_argument("--prefix-len", type=int, default=16,
                    help="[--shared-prefix] shared prefix tokens")
    ap.add_argument("--prefixes", type=int, default=2,
                    help="[--shared-prefix] distinct prefixes")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the unmeasured warmup replay (metrics then "
                         "include XLA compile time)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the JSON record here (default: stdout)")
    args = ap.parse_args(argv)

    if sum(map(bool, (args.paged, args.spec_decode, args.mesh,
                      args.slo, args.backends, args.replicas))) > 1:
        raise SystemExit("--paged, --spec-decode, --mesh, --slo, "
                         "--backends and --replicas are separate "
                         "comparisons; run them as separate records")
    if args.attn_backend and not args.paged:
        raise SystemExit("--attn-backend selects the paged engine's "
                         "attention backend; it requires --paged "
                         "(--backends always runs both)")
    if (args.spec_decode or args.mesh) and args.shared_prefix:
        raise SystemExit("--spec-decode and --mesh use the plain Poisson "
                         "workload; --shared-prefix belongs to the --paged "
                         "comparison")
    if args.slo and args.shared_prefix:
        raise SystemExit("--slo uses the bursty deadline workload; "
                         "--shared-prefix belongs to the --paged "
                         "comparison")
    common = dict(arch=args.arch, smoke=args.smoke, requests=args.requests,
                  rate_rps=args.rate, slots=args.slots, max_len=args.max_len,
                  temperature=args.temperature, seed=args.seed,
                  warmup=not args.no_warmup)
    if args.replicas:
        kill_schedule = []
        for item in filter(None, (s.strip()
                                  for s in args.kill.split(","))):
            step_s, rid_s = item.split(":")
            kill_schedule.append((int(step_s), int(rid_s)))
        record = run_replicas(arch=args.arch, smoke=args.smoke,
                              n_replicas=args.replicas,
                              requests=args.requests, rate_rps=args.rate,
                              slots=args.slots, max_len=args.max_len,
                              kill_schedule=tuple(kill_schedule),
                              reload_at_step=args.reload_at,
                              seed=args.seed)
    elif args.slo:
        record = run_slo(arch=args.arch, smoke=args.smoke,
                         slots=args.slots, max_len=args.max_len,
                         n_burst=args.burst,
                         burst_deadline_s=args.deadline,
                         prefill_chunk=args.prefill_chunk, seed=args.seed)
    elif args.mesh:
        # must run before jax initializes its backends: XLA locks the
        # host-platform device count at first init
        shape = parse_mesh(args.mesh)
        ensure_host_devices(shape)
        record = run_sharded(mesh_shape=shape, **common)
    elif args.spec_decode:
        record = run_spec(spec_k=args.spec_k,
                          accept_probs=tuple(
                              float(a) for a in
                              args.accept_probs.split(",") if a),
                          **common)
    elif args.backends:
        record = run_backends(block_size=args.block_size,
                              n_blocks=args.blocks,
                              shared_prefix=args.shared_prefix,
                              prefix_len=args.prefix_len,
                              n_prefixes=args.prefixes, **common)
    elif args.paged:
        record = run_paged(block_size=args.block_size, n_blocks=args.blocks,
                           shared_prefix=args.shared_prefix,
                           prefix_len=args.prefix_len,
                           n_prefixes=args.prefixes,
                           attn_backend=args.attn_backend, **common)
    else:
        record = run(shared_prefix=args.shared_prefix,
                     prefix_len=args.prefix_len, n_prefixes=args.prefixes,
                     **common)
    text = json.dumps(record, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(text + "\n")
        if record["schema"] == "serving-v7":
            c = record["comparison"]
            print(f"[bench] wrote {args.json}: serving-v7, "
                  f"kills={c['kills']} requeues={c['requeues']} "
                  f"(latency p95={c['requeue_latency_ms']['p95']:.0f}ms), "
                  f"reloads={c['reloads_completed']} "
                  f"(dropped {c['reload_dropped']}), lost="
                  f"{c['lost_requests']}, goodput "
                  f"{c['goodput_tok_per_s_baseline']:.0f}->"
                  f"{c['goodput_tok_per_s_chaos']:.0f} tok/s, greedy "
                  f"tokens "
                  f"{'MATCH' if c['greedy_tokens_match'] else 'DIVERGE'}",
                  file=sys.stderr)
        elif record["schema"] == "serving-v5":
            c = record["comparison"]
            print(f"[bench] wrote {args.json}: serving-v5, deadline ttft "
                  f"p99 fifo={c['deadline_ttft_p99_ms_fifo']:.0f}ms "
                  f"slo={c['deadline_ttft_p99_ms_slo']:.0f}ms, attainment "
                  f"{c['attainment_fifo']:.2f}->{c['attainment_slo']:.2f}, "
                  f"goodput {c['goodput_tok_per_s_fifo']:.0f}->"
                  f"{c['goodput_tok_per_s_slo']:.0f} tok/s, "
                  f"preemptions={c['preemptions']}, greedy tokens "
                  f"{'MATCH' if c['greedy_tokens_match'] else 'DIVERGE'}",
                  file=sys.stderr)
        elif record["schema"] == "serving-v6":
            c = record["comparison"]
            print(f"[bench] wrote {args.json}: serving-v6, tok/s "
                  f"jnp={c['tok_per_s_jnp']:.1f} "
                  f"pallas={c['tok_per_s_pallas']:.1f}, kv bytes/run "
                  f"gathered={c['gathered_kv_bytes']:,}B "
                  f"fused={c['fused_kv_bytes']:,}B "
                  f"(saved {c['kv_bytes_saved_frac']:.0%}), greedy tokens "
                  f"{'MATCH' if c['greedy_tokens_match'] else 'DIVERGE'}",
                  file=sys.stderr)
        elif record["schema"] == "serving-v4":
            c = record["comparison"]
            m = record["config"]["mesh"]
            axes = "x".join(str(s) for s in m["shape"])
            print(f"[bench] wrote {args.json}: serving-v4, mesh {axes} "
                  f"({m['n_devices']} devices), tok/s "
                  f"single={c['tok_per_s_single']:.1f} "
                  f"sharded={c['tok_per_s_sharded']:.1f}, greedy tokens "
                  f"{'MATCH' if c['greedy_tokens_match'] else 'DIVERGE'}",
                  file=sys.stderr)
        elif record["schema"] == "serving-v3":
            c = record["comparison"]
            pts = ", ".join(
                f"a={p['accept_prob']:.2f}:{p['tokens_per_step']:.2f}"
                for p in c["curve"])
            print(f"[bench] wrote {args.json}: serving-v3, "
                  f"tok/step plain={c['tokens_per_step_plain']:.2f} "
                  f"spec[{pts}]", file=sys.stderr)
        elif record["schema"] == "serving-v2":
            c = record["comparison"]
            print(f"[bench] wrote {args.json}: serving-v2, "
                  f"ttft p50 dense={c['ttft_p50_ms_dense']:.0f}ms "
                  f"paged={c['ttft_p50_ms_paged']:.0f}ms, "
                  f"prefix hits={c['prefix_hits']}, "
                  f"resident={c['resident_kv_bytes']:,}B / "
                  f"dense {c['dense_equiv_kv_bytes']:,}B", file=sys.stderr)
        else:
            agg = record["aggregate"]
            print(f"[bench] wrote {args.json}: {agg['n_requests']} requests, "
                  f"{agg['tok_per_s']:.1f} tok/s, "
                  f"ttft p50={agg['ttft_ms']['p50']:.0f}ms, "
                  f"occupancy={agg['slot_occupancy']:.2f}", file=sys.stderr)
    else:
        print(text)


if __name__ == "__main__":
    main()
