"""Benchmark/repro of paper Table 1: MOA census of AlexNet conv layers."""

from __future__ import annotations

import time

from repro.core import dhm

__all__ = ["run"]


def run(verbose: bool = True):
    t0 = time.perf_counter()
    reports = dhm.analyze_network(
        dhm.ALEXNET_CONV_SPECS, densities=dhm.paper_calibrated_densities())
    elapsed_us = (time.perf_counter() - t0) * 1e6

    rows = []
    if verbose:
        print("# Table 1 — MOAs and mean non-null operands per AlexNet layer")
        print(f"{'layer':8s} {'N (MOAs)':>9s} {'C·J·K':>7s} {'n_opd':>8s} "
              f"{'paper':>6s} {'err%':>6s} {'MOA frac':>9s}")
    for r in reports:
        paper = dhm.ALEXNET_PAPER_NOPD[r.spec.name]
        err = 100 * abs(r.n_opd - paper) / paper
        rows.append((r.spec.name, r.spec.n_filters, r.spec.operands,
                     r.n_opd, paper, err, r.moa_fraction))
        if verbose:
            print(f"{r.spec.name:8s} {r.spec.n_filters:9d} "
                  f"{r.spec.operands:7d} {r.n_opd:8.1f} {paper:6d} "
                  f"{err:5.2f}% {r.moa_fraction:8.1%}")
    max_err = max(r[5] for r in rows)
    conv1_frac = rows[0][6]
    return {
        "us_per_call": elapsed_us,
        "derived": (f"max_nopd_err={max_err:.2f}%"
                    f";conv1_moa_frac={conv1_frac:.3f}(paper:0.69)"),
    }
