"""Substrate tests: data determinism, optimizer, compression, checkpoints,
fault-tolerance runtime."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData, host_shard
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_int8, compressed_gradients, cosine_schedule,
                         decompress_int8, init_error_feedback)
from repro.runtime import (FailureInjector, HeartbeatMonitor,
                           SimulatedFailure, Supervisor, plan_mesh_shape)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_step_indexing_deterministic(self):
        d = SyntheticLMData(vocab=100, seq_len=16, global_batch=4, seed=3)
        b1 = d.batch_for_step(7)
        b2 = d.batch_for_step(7)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))

    def test_steps_differ(self):
        d = SyntheticLMData(vocab=100, seq_len=16, global_batch=4)
        assert not np.array_equal(np.asarray(d.batch_for_step(0)["tokens"]),
                                  np.asarray(d.batch_for_step(1)["tokens"]))

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLMData(vocab=100, seq_len=16, global_batch=2)
        b = d.batch_for_step(0)
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))

    def test_host_shard_partitions(self):
        d = SyntheticLMData(vocab=100, seq_len=8, global_batch=8)
        b = d.batch_for_step(0)
        parts = [host_shard(b, h, 4)["tokens"] for h in range(4)]
        np.testing.assert_array_equal(
            np.concatenate([np.asarray(p) for p in parts]),
            np.asarray(b["tokens"]))

    def test_bigram_learnable_structure(self):
        """noise=0 ⇒ next token is a deterministic function of prev."""
        d = SyntheticLMData(vocab=97, seq_len=32, global_batch=4, noise=0.0)
        t = np.asarray(d.batch_for_step(0)["tokens"])
        a = 2 * (d.seed % 1000) + 1
        c = (d.seed * 7919 + 13) % d.vocab
        np.testing.assert_array_equal(t[:, 1:], (t[:, :-1] * a + c) % 97)


# ---------------------------------------------------------------------------
# optimizer + schedules
# ---------------------------------------------------------------------------

class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
        opt = adamw_init(params)
        cfg = AdamWConfig(weight_decay=0.0, clip_norm=None)
        for step in range(200):
            g = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
            params, opt, _ = adamw_update(g, opt, params, lr=0.05, config=cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=1e-2)

    def test_clip_bounds_update(self):
        params = {"w": jnp.zeros((3,))}
        opt = adamw_init(params)
        g = {"w": jnp.asarray([1e6, -1e6, 1e6])}
        _, _, metrics = adamw_update(g, opt, params, lr=1e-3,
                                     config=AdamWConfig(clip_norm=1.0))
        assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip

    def test_cosine_schedule_shape(self):
        lrs = [float(cosine_schedule(s, peak_lr=1.0, warmup_steps=10,
                                     total_steps=100)) for s in range(100)]
        assert lrs[0] < lrs[9] <= 1.0
        assert abs(max(lrs) - 1.0) < 0.01
        assert lrs[-1] < 0.2


class TestCompression:
    def test_roundtrip_error_bounded(self, rng):
        x = jax.random.normal(rng, (1000,))
        q, s = compress_int8(x)
        err = np.abs(np.asarray(decompress_int8(q, s) - x))
        assert err.max() <= float(s) * 0.5 + 1e-6

    @given(seed=st.integers(0, 50))
    @settings(max_examples=10, deadline=None)
    def test_error_feedback_accumulates_residual(self, seed):
        """Σ_t deq_t ≈ Σ_t g_t: residue is carried, not lost."""
        rng = np.random.default_rng(seed)
        g_true = {"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
        err = init_error_feedback(g_true)
        total_deq = np.zeros(64)
        T = 50
        for _ in range(T):
            deq, err = compressed_gradients(g_true, err)
            total_deq += np.asarray(deq["w"])
        drift = np.abs(total_deq - T * np.asarray(g_true["w"])).max()
        # leftover residue is at most one quantization step
        assert drift <= float(np.abs(np.asarray(g_true["w"])).max() / 127) + 1e-4

    def test_compression_changes_single_step(self, rng):
        g = {"w": jax.random.normal(rng, (64,))}
        err = init_error_feedback(g)
        deq, _ = compressed_gradients(g, err)
        assert not np.allclose(np.asarray(deq["w"]), np.asarray(g["w"]))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def _tree(self, k=0):
        return {"params": {"w": jnp.arange(6, dtype=jnp.float32) + k,
                           "b": jnp.ones((2,), jnp.bfloat16) * k},
                "step": jnp.asarray(k, jnp.int32)}

    def test_roundtrip(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(3, self._tree(3), metadata={"loss": 1.5})
        restored, meta = m.restore(jax.eval_shape(lambda: self._tree()))
        assert meta["loss"] == 1.5
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(self._tree(3)["params"]["w"]))
        assert restored["params"]["b"].dtype == jnp.bfloat16

    def test_latest_and_retention(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 5, 9, 12):
            m.save(s, self._tree(s))
        assert m.latest_step() == 12
        assert m.available_steps() == [9, 12]

    def test_async_save(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save_async(4, self._tree(4))
        m.wait()
        assert m.latest_step() == 4

    def test_atomicity_no_partial_dirs(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(1, self._tree(1))
        names = os.listdir(tmp_path)
        assert names == ["step_1"]
        assert not any(n.endswith(".tmp") for n in names)

    def test_restore_with_target_sharding(self, tmp_path):
        """Elastic restore path: device_put onto explicit shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        m = CheckpointManager(str(tmp_path))
        m.save(0, self._tree(7))
        mesh = jax.make_mesh((1,), ("data",))
        template = jax.eval_shape(lambda: self._tree())
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), template)
        restored, _ = m.restore(template, shardings=sh)
        assert restored["params"]["w"].sharding == NamedSharding(mesh, P())

    def test_sharded_save_restore(self, tmp_path):
        """Two 'hosts' each save half the leaves; restore merges."""
        t = self._tree(2)
        for sid in (0, 1):
            m = CheckpointManager(str(tmp_path), shard_id=sid, n_shards=2)
            m.save(5, t)
        m = CheckpointManager(str(tmp_path), n_shards=2)
        restored, _ = m.restore(jax.eval_shape(lambda: self._tree()))
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(t["params"]["w"]))

    def test_missing_key_raises(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(0, {"a": jnp.ones(3)})
        with pytest.raises(KeyError):
            m.restore(jax.eval_shape(lambda: {"a": jnp.ones(3),
                                              "b": jnp.ones(2)}))


# ---------------------------------------------------------------------------
# runtime: heartbeats, failures, supervisor, elastic
# ---------------------------------------------------------------------------

class TestRuntime:
    def test_straggler_detected(self):
        mon = HeartbeatMonitor(n_workers=4, window=16)
        for step in range(8):
            for w in range(4):
                mon.beat(w, step, 0.1)
        report = mon.beat(2, 8, 1.0)  # 10× median
        assert report is not None and report.worker == 2

    def test_uniform_noise_no_false_positives(self):
        rng = np.random.default_rng(0)
        mon = HeartbeatMonitor(n_workers=4)
        for step in range(30):
            for w in range(4):
                mon.beat(w, step, 0.1 + 0.005 * rng.random())
        assert mon.reports == []

    def test_dead_worker_detection(self):
        mon = HeartbeatMonitor(n_workers=2)
        for step in range(10):
            mon.beat(0, step, 0.1)
        mon.beat(1, 2, 0.1)
        assert mon.dead_workers(current_step=9) == [1]

    def test_failure_injector_fires_once(self):
        inj = FailureInjector([3])
        inj.maybe_fail(2)
        with pytest.raises(SimulatedFailure):
            inj.maybe_fail(3)
        inj.maybe_fail(3)  # replaced node survives the same step

    def test_supervisor_restarts_and_completes(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        inj = FailureInjector([4, 7])
        log = []

        def train_fn(start, restored):
            state = restored if restored is not None else 0
            for step in range(start, 10):
                state += 1
                inj.maybe_fail(step)
                mgr.save(step, {"acc": jnp.asarray(state)})
                log.append(step)
            return state

        def restore_fn(step):
            t, _ = mgr.restore({"acc": jnp.asarray(0)}, step=step)
            return int(t["acc"])

        sup = Supervisor(mgr, max_restarts=3)
        res = sup.run(train_fn, restore_fn=restore_fn)
        assert res.completed and res.restarts == 2
        assert res.final_state == 10

    @given(n=st.integers(1, 600))
    @settings(max_examples=50, deadline=None)
    def test_plan_mesh_uses_all_devices(self, n):
        d, m = plan_mesh_shape(n, model_parallel=16)
        assert d * m == n
        assert m <= 16
