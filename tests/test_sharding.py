"""Sharding rules + a real (subprocess) small-mesh dry-run.

The in-process tests cover the pure logic (rule lookup, divisibility
fallback, dedupe, FSDP upgrade). The subprocess test spins up 8 host
devices (XLA locks device count at first init, so it cannot run in the
test process) and lowers+compiles a train step with full shardings — the
same code path the 512-device production dry-run uses.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.steps import _dedupe_spec, infer_param_axes
from repro.parallel import DEFAULT_RULES, ShardingRules, logical_to_spec


class TestRules:
    def test_lookup_and_override(self):
        assert DEFAULT_RULES.lookup("vocab") == "model"
        assert DEFAULT_RULES.lookup("batch") == ("pod", "data")
        assert DEFAULT_RULES.lookup("seq") is None
        r2 = DEFAULT_RULES.with_overrides(kv_seq="data", batch=None)
        assert r2.lookup("kv_seq") == "data"
        assert r2.lookup("batch") is None
        assert DEFAULT_RULES.lookup("kv_seq") is None  # immutable

    def test_unknown_names_replicate(self):
        assert DEFAULT_RULES.lookup("no_such_axis") is None

    def test_logical_to_spec_drops_absent_mesh_axes(self):
        # mesh=None context: spec built from rules verbatim
        spec = logical_to_spec(("batch", "seq", "vocab"), DEFAULT_RULES,
                               mesh=None)
        assert spec == P(("pod", "data"), None, "model")

    def test_dedupe_first_wins(self):
        assert _dedupe_spec(P("model", None, "model")) == P("model", None,
                                                            None)
        assert _dedupe_spec(P(("pod", "data"), "data")) == \
            P(("pod", "data"), None)


class TestParamAxes:
    def test_transformer_axes(self, rng):
        from repro.configs.registry import ARCHS, smoke_config
        from repro.models.api import build_model

        model = build_model(smoke_config(ARCHS["llama3-8b"]))
        axes = infer_param_axes(model.abstract_params())
        assert axes["embed"]["table"] == ("vocab", "embed")
        # stacked layers get a leading None for the scan axis
        assert axes["layers"]["attn"]["wq"] == (None, "embed", "heads")
        assert axes["layers"]["mlp"]["w_down"] == (None, "ff", "embed")

    def test_moe_axes(self, rng):
        from repro.configs.registry import ARCHS, smoke_config
        from repro.models.api import build_model

        model = build_model(smoke_config(ARCHS["moonshot-v1-16b-a3b"]))
        axes = infer_param_axes(model.abstract_params())
        assert axes["layers"]["moe"]["w_gate"] == \
            (None, "experts", "embed", "ff")
        assert axes["layers"]["moe"]["router"] == (None, "embed", "experts")


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, "src")
    import jax
    from repro.configs.registry import ARCHS, smoke_config
    from repro.configs.base import ShapeSpec
    from repro.models.api import build_model
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_mesh
    from repro.launch.dryrun import collective_census
    from repro.parallel import DEFAULT_RULES, activate

    mesh = make_mesh((2, 2, 2))   # pod, data, model — multi-pod shape
    cfg = smoke_config(ARCHS["{arch}"])
    model = build_model(cfg)
    shape = ShapeSpec("t", 64, 8, "train")
    rules = steps_lib.rules_for(cfg, shape, mesh, DEFAULT_RULES)
    with activate(mesh, rules):
        specs = model.input_specs(shape)
        batch_sh = steps_lib.batch_specs(specs, mesh, rules)
        hyper = steps_lib.TrainHyper()
        state_spec = jax.eval_shape(lambda: steps_lib.init_train_state(
            model, jax.random.PRNGKey(0), hyper=hyper))
        axes = steps_lib.state_axes(state_spec)
        state_sh = steps_lib.build_shardings(state_spec, axes, mesh, rules,
                                             fsdp=True)
        fn = jax.jit(steps_lib.build_train_step(model, hyper=hyper),
                     in_shardings=(state_sh, batch_sh), donate_argnums=(0,))
        compiled = fn.lower(state_spec, specs).compile()
    census = collective_census(compiled.as_text())
    mem = compiled.memory_analysis()
    print(json.dumps({{"collectives": census["count"],
                       "total_bytes": census["total_bytes"],
                       "temp": mem.temp_size_in_bytes}}))
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3-8b", "moonshot-v1-16b-a3b",
                                  "zamba2-1.2b"])
def test_multipod_train_step_compiles_in_subprocess(arch):
    """8 placeholder devices, (pod=2, data=2, model=2) mesh: the full
    sharded train step must lower, compile, and emit collectives."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT.format(arch=arch)],
        capture_output=True, text=True, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), env=env,
        timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result["collectives"] > 0          # SPMD actually partitioned
    assert result["total_bytes"] > 0
