"""Static-analysis gate: the real serve path audits clean, and every rule
is proven live by a fixture that trips it."""

import importlib.util
import json
import pathlib

import pytest

from repro.analysis.fixtures import (CLEAN_LINT_FIXTURES, COST_FIXTURES,
                                     JAXPR_FIXTURES, LINT_FIXTURES)
from repro.analysis.jaxpr_audit import audit_target, audit_targets
from repro.analysis.lint import dead_module_census, lint_source, run_lint
from repro.analysis.report import ANALYSIS_SCHEMA, RULES, build_report
from repro.analysis.targets import (SERVE_FAMILIES, build_family_targets,
                                    make_audit_mesh)

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def _schema_registry():
    path = REPO_ROOT / "scripts" / "check_bench_schema.py"
    spec = importlib.util.spec_from_file_location("check_bench_schema", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# ---------------------------------------------------------------------------
# the real serve path is clean
# ---------------------------------------------------------------------------


class TestServePathClean:
    @pytest.mark.parametrize("family", SERVE_FAMILIES)
    @pytest.mark.parametrize("mesh_mode", ["none", "mesh"])
    def test_family_audits_clean(self, family, mesh_mode):
        mesh = make_audit_mesh() if mesh_mode == "mesh" else None
        targets = build_family_targets(family, mesh=mesh)
        assert targets, family
        violations = audit_targets(targets)
        assert not violations, "\n".join(v.format() for v in violations)

    def test_repo_lints_clean(self):
        violations, n_files = run_lint(str(REPO_ROOT))
        assert n_files > 50
        assert not violations, "\n".join(v.format() for v in violations)


# ---------------------------------------------------------------------------
# every rule fires on its fixture
# ---------------------------------------------------------------------------


class TestRulesAreLive:
    @pytest.mark.parametrize("key", sorted(JAXPR_FIXTURES))
    def test_jaxpr_fixture_fires(self, key):
        builder, needs_mesh = JAXPR_FIXTURES[key]
        target = builder(make_audit_mesh()) if needs_mesh else builder()
        rule = key.split("/")[0]
        violations = audit_target(target)
        assert any(v.rule == rule for v in violations), \
            (key, [v.rule for v in violations])

    @pytest.mark.parametrize("rule", sorted(LINT_FIXTURES))
    def test_lint_fixture_fires(self, rule):
        path, source = LINT_FIXTURES[rule]
        violations = lint_source(path, source)
        assert any(v.rule == rule for v in violations), \
            (rule, [v.rule for v in violations])

    @pytest.mark.parametrize("name", sorted(CLEAN_LINT_FIXTURES))
    def test_near_miss_stays_clean(self, name):
        path, source = CLEAN_LINT_FIXTURES[name]
        violations = lint_source(path, source)
        assert not violations, [v.format() for v in violations]

    def test_every_rule_has_a_fixture(self):
        """RULES without a proving fixture are dead weight (lint-dead-module
        is proven by the census test below, the cost-audit rules in
        tests/test_cost_audit.py)."""
        proven = {k.split("/")[0] for k in JAXPR_FIXTURES}
        proven |= set(LINT_FIXTURES) | {"lint-dead-module"}
        proven |= set(COST_FIXTURES)
        assert proven == set(RULES)

    def test_upcast_fixture_site_attribution(self):
        """The upcast violation points at the fixture's own source line."""
        builder, _ = JAXPR_FIXTURES["f32-upcast-allowlist"]
        (v,) = audit_target(builder())
        assert v.file == "src/repro/analysis/fixtures.py"
        assert v.line > 0


# ---------------------------------------------------------------------------
# dead-module census
# ---------------------------------------------------------------------------


class TestCensus:
    def _tree(self, tmp_path, files):
        for rel, src in files.items():
            p = tmp_path / rel
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(src)
        return str(tmp_path)

    def test_flags_only_orphans(self, tmp_path):
        root = self._tree(tmp_path, {
            "src/repro/__init__.py": "",
            "src/repro/used.py": "X = 1\n",
            "src/repro/dead.py": "Y = 2\n",
            "tests/test_used.py": "from repro.used import X\n",
        })
        flagged = {v.file for v in dead_module_census(root)}
        assert flagged == {"src/repro/dead.py"}

    def test_entry_points_exempt(self, tmp_path):
        root = self._tree(tmp_path, {
            "src/repro/__init__.py": "",
            "src/repro/cli.py": ("def main():\n    pass\n\n"
                                 "if __name__ == '__main__':\n    main()\n"),
        })
        assert dead_module_census(root) == []

    def test_from_import_of_module_counts(self, tmp_path):
        root = self._tree(tmp_path, {
            "src/repro/__init__.py": "",
            "src/repro/pkg/__init__.py": "",
            "src/repro/pkg/mod.py": "Z = 3\n",
            "scripts/run.py": "from repro.pkg import mod\n",
        })
        assert dead_module_census(root) == []


# ---------------------------------------------------------------------------
# analysis-v1 report schema
# ---------------------------------------------------------------------------


class TestReportSchema:
    def _report(self):
        builder, _ = JAXPR_FIXTURES["no-host-transfer"]
        violations = audit_target(builder())
        assert violations
        return build_report(
            violations, targets_audited=1, files_linted=0,
            config={"families": ["dense"], "mesh_modes": ["none"]})

    def test_round_trip_validates(self, tmp_path):
        registry = _schema_registry()
        report = self._report()
        assert report["schema"] == ANALYSIS_SCHEMA
        p = tmp_path / "report.json"
        p.write_text(json.dumps(report))
        assert registry.validate(json.loads(p.read_text())) == []

    def test_corrupted_summary_fails(self):
        registry = _schema_registry()
        report = self._report()
        report["summary"]["violations"] += 1
        assert any("does not match" in e for e in registry.validate(report))

    def test_mistyped_violation_fails(self):
        registry = _schema_registry()
        report = self._report()
        report["violations"][0]["line"] = "twelve"
        assert any("line" in e for e in registry.validate(report))

    def test_bad_severity_fails(self):
        registry = _schema_registry()
        report = self._report()
        report["violations"][0]["severity"] = "meh"
        assert any("severity" in e for e in registry.validate(report))

    def test_unknown_schema_fails(self):
        registry = _schema_registry()
        errors = registry.validate({"schema": "analysis-v99"})
        assert errors and "unknown schema" in errors[0]
        assert "analysis-v1" in errors[0]     # registry lists what it knows

    def test_serving_schemas_still_registered(self):
        registry = _schema_registry()
        assert {"serving-v1", "serving-v2", "serving-v3", "serving-v4",
                "analysis-v1"} <= set(registry.SCHEMAS)
