"""Speculative decoding: verify-step parity, engine equivalence, drafters,
acceptance, costing, and the serving-v3 schema.

The load-bearing claims (docs/spec-decode.md):

* ``verify_step`` over a k-token window is **bit-identical** to k
  sequential ``decode_step`` calls — dense/MoE/hybrid, dense and paged
  caches, with slots at heterogeneous positions;
* with a forced accept-rate-1 drafter, speculative greedy decode emits
  **bit-identical outputs** to plain greedy decode (and with a forced
  accept-rate-0 drafter too: the rewind path, exercised every tick);
* temperature requests are deterministic per engine seed;
* rejection never corrupts state — including recurrent SSM snapshots and
  paged tentative writes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke_config
from repro.launch.costing import (expected_accepted_len,
                                  spec_break_even_accept, spec_decode_cost)
from repro.models.api import build_model
from repro.serve import (DraftModelDrafter, NgramDrafter, OracleDrafter,
                         Request, Sampler, ServeEngine, poisson_workload,
                         resolve_drafter, verify_accept)
from repro.serve.engine import _write_slot


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


_BUILT = {}


def _built(arch):
    """Module-cached (cfg, model, params): params init dominates runtime."""
    if arch not in _BUILT:
        cfg = smoke_config(get_config(arch))
        model = build_model(cfg)
        _BUILT[arch] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return _BUILT[arch]


def _staggered_cache(model, cfg, params, rng, *, n_slots=3, max_len=32,
                     plens=(5, 9, 7)):
    """Batched dense cache with per-slot prefills of different lengths —
    the engine's mid-flight shape."""
    cache = model.init_cache(n_slots, max_len)
    cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
    for b, p in enumerate(plens):
        toks = jax.random.randint(jax.random.fold_in(rng, b), (1, p), 0,
                                  cfg.vocab)
        _, pre = model.prefill(params, {"tokens": toks}, max_len=max_len)
        cache = _write_slot(cache, pre, b)
    return cache


def _workload(cfg, *, n=6, seed=1, temperature=0.0):
    sampler = Sampler(temperature)
    return poisson_workload(
        n_requests=n, rate_rps=100.0, vocab=cfg.vocab,
        prompt_len_range=(4, 12), gen_len_range=(3, 10), sampler=sampler,
        seed=seed)


# ---------------------------------------------------------------------------
# verify-step parity: one call vs k sequential decode steps
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3-8b", "moonshot-v1-16b-a3b",
                                  "zamba2-1.2b", "mamba2-370m"])
def test_verify_bitwise_matches_sequential_decode(rng, arch):
    """verify_step logits at every window position are bit-identical to
    the corresponding sequential decode_step call, with slots sitting at
    heterogeneous positions; committing the full window reproduces the
    sequential cursor."""
    cfg, model, params = _built(arch)
    B, T = 3, 4
    cache = _staggered_cache(model, cfg, params, rng)
    vtoks = jnp.asarray(jax.random.randint(jax.random.fold_in(rng, 99),
                                           (B, T), 0, cfg.vocab), jnp.int32)
    seq_cache = jax.tree.map(lambda a: a, cache)
    seq_logits = []
    for i in range(T):
        lg, seq_cache = model.decode_step(params, seq_cache,
                                          vtoks[:, i:i + 1])
        seq_logits.append(np.asarray(lg[:, 0], np.float32))
    vlogits, vcache, aux = model.verify_step(params, cache, vtoks)
    np.testing.assert_array_equal(np.stack(seq_logits, axis=1),
                                  np.asarray(vlogits, np.float32))
    # pos is untouched until commit; a full-window commit lands exactly on
    # the sequential cursor
    np.testing.assert_array_equal(np.asarray(vcache["pos"]),
                                  np.asarray(cache["pos"]))
    committed = model.commit_verified(vcache, jnp.full((B,), T, jnp.int32),
                                      aux)
    np.testing.assert_array_equal(np.asarray(committed["pos"]),
                                  np.asarray(seq_cache["pos"]))


@pytest.mark.parametrize("arch", ["llama3-8b", "zamba2-1.2b"])
def test_verify_rewind_resumes_exactly(rng, arch):
    """Commit at keep < T, then decode onward: logits match a run that
    never speculated — the cursor rewind (and, hybrid, the SSM snapshot
    restore) leaves no trace of the rejected suffix."""
    cfg, model, params = _built(arch)
    B, T = 3, 4
    keep = jnp.asarray([1, 3, 2], jnp.int32)
    cache = _staggered_cache(model, cfg, params, rng)
    ref_cache = jax.tree.map(lambda a: a, cache)
    vtoks = jnp.asarray(jax.random.randint(jax.random.fold_in(rng, 7),
                                           (B, T), 0, cfg.vocab), jnp.int32)
    _, vcache, aux = model.verify_step(params, cache, vtoks)
    rewound = model.commit_verified(vcache, keep, aux)
    # reference: feed only the kept prefix, sequentially — slots whose
    # keep ran out freeze at their previous state (per-leaf (B,) select)
    for i in range(int(jnp.max(keep))):
        _, stepped = model.decode_step(params, ref_cache, vtoks[:, i:i + 1])
        mask = np.asarray(keep) > i
        ref_cache = jax.tree.map(
            lambda new, old: jnp.where(_mask_for(new, mask), new, old),
            stepped, ref_cache)
    next_tok = jnp.asarray(jax.random.randint(jax.random.fold_in(rng, 8),
                                              (B, 1), 0, cfg.vocab))
    got, _ = model.decode_step(params, rewound, next_tok)
    want, _ = model.decode_step(params, ref_cache, next_tok)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def _mask_for(leaf, mask):
    """Broadcast a (B,) bool mask onto a cache leaf.

    ``pos`` is ``(B,)``; every other leaf is ``(stack, B, ...)``.
    """
    m = jnp.asarray(mask)
    if leaf.ndim == 1:
        return m
    return m.reshape((1, -1) + (1,) * (leaf.ndim - 2))


# ---------------------------------------------------------------------------
# engine equivalence: spec greedy ≡ plain greedy, accept 1 and accept 0
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,paged", [
    ("llama3-8b", False), ("llama3-8b", True),
    ("moonshot-v1-16b-a3b", False),
    ("zamba2-1.2b", False), ("zamba2-1.2b", True),
])
def test_spec_greedy_bit_identical_to_plain(rng, arch, paged):
    """Acceptance criterion: with the forced accept-rate-1 oracle drafter,
    speculative greedy decode emits bit-identical outputs to plain greedy
    decode — dense, MoE, hybrid; dense and paged caches — and at accept
    rate 1 the engine reports > 1.5 tokens per slot-step."""
    cfg, model, params = _built(arch)
    plain = ServeEngine(model, params, n_slots=3, max_len=48, paged=paged,
                        block_size=8, rng=rng, clock=lambda: 0.0)
    ref, _ = plain.run(_workload(cfg))
    spec = ServeEngine(model, params, n_slots=3, max_len=48, paged=paged,
                       block_size=8, rng=rng, clock=lambda: 0.0,
                       drafter=OracleDrafter(3))
    got, report = spec.run(_workload(cfg))
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    sp = report["spec"]
    assert sp["accept_rate"] == 1.0
    assert sp["tokens_per_step"] > 1.5
    assert sp["verify_ticks"] < sum(r.tokens.size for r in ref)


@pytest.mark.parametrize("arch", ["llama3-8b", "zamba2-1.2b"])
def test_spec_all_rejected_still_identical(rng, arch):
    """Accept-rate-0 oracle (every draft corrupted): the rewind path runs
    every tick and outputs still match plain greedy exactly — rejection
    rolls back KV rows and recurrent state without a trace."""
    cfg, model, params = _built(arch)
    plain = ServeEngine(model, params, n_slots=3, max_len=48, rng=rng,
                        clock=lambda: 0.0)
    ref, _ = plain.run(_workload(cfg))
    spec = ServeEngine(model, params, n_slots=3, max_len=48, rng=rng,
                       clock=lambda: 0.0,
                       drafter=OracleDrafter(3, accept_prob=0.0))
    got, report = spec.run(_workload(cfg))
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert report["spec"]["accept_rate"] == 0.0
    assert report["spec"]["tokens_per_step"] == pytest.approx(1.0)


def test_spec_ngram_drafter_end_to_end(rng):
    """The ngram drafter never changes greedy outputs (any drafter is
    output-neutral under greedy acceptance) and the report's histogram
    accounts for every slot-tick."""
    cfg, model, params = _built("llama3-8b")
    plain = ServeEngine(model, params, n_slots=2, max_len=48, rng=rng,
                        clock=lambda: 0.0)
    ref, _ = plain.run(_workload(cfg, n=4))
    spec = ServeEngine(model, params, n_slots=2, max_len=48, rng=rng,
                       clock=lambda: 0.0,
                       drafter=resolve_drafter("ngram?n=2", 3))
    got, report = spec.run(_workload(cfg, n=4))
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    sp = report["spec"]
    assert sp["draft_steps"] == 0
    # histogram counts (slot, tick) pairs: at least one active slot per
    # tick, at most n_slots
    assert sp["verify_ticks"] <= sum(sp["accepted_hist"]) \
        <= sp["verify_ticks"] * 2


def test_spec_temperature_deterministic_per_seed(rng):
    """Seeded temperature spec decode reproduces itself exactly (all
    randomness flows through the engine key) and differs from greedy."""
    cfg, model, params = _built("llama3-8b")

    def run_once():
        engine = ServeEngine(model, params, n_slots=2, max_len=48,
                             rng=jax.random.PRNGKey(3), clock=lambda: 0.0,
                             drafter=OracleDrafter(2, accept_prob=0.5))
        return engine.run(_workload(cfg, n=4, temperature=0.8))

    r1, rep1 = run_once()
    r2, rep2 = run_once()
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert rep1["spec"]["accepted_hist"] == rep2["spec"]["accepted_hist"]


def test_spec_draft_model_drafter_is_oracle_for_same_model(rng):
    """DraftModelDrafter pointed at the target model itself behaves as a
    perfect drafter (greedy proposals == target greedy) — accept rate 1."""
    cfg, model, params = _built("llama3-8b")
    drafter = DraftModelDrafter(model, params, 2)
    engine = ServeEngine(model, params, n_slots=2, max_len=48, rng=rng,
                         clock=lambda: 0.0, drafter=drafter)
    _, report = engine.run(_workload(cfg, n=4))
    assert report["spec"]["accept_rate"] == 1.0
    assert report["spec"]["draft_steps"] > 0


def test_spec_moa_flops_acceptance_aware(rng):
    """Per-request moa_flops prices the verify work actually spent:
    rejected drafts are compute, so the accept-0 run costs strictly more
    FLOPs than both the accept-1 run and the plain run (same outputs)."""
    cfg, model, params = _built("llama3-8b")

    def total_flops(drafter):
        engine = ServeEngine(model, params, n_slots=2, max_len=48, rng=rng,
                             clock=lambda: 0.0, drafter=drafter)
        _, report = engine.run(_workload(cfg, n=4))
        return report["moa_flops_total"]

    plain_engine = ServeEngine(model, params, n_slots=2, max_len=48,
                               rng=rng, clock=lambda: 0.0)
    _, plain_report = plain_engine.run(_workload(cfg, n=4))
    at_one = total_flops(OracleDrafter(3))
    at_zero = total_flops(OracleDrafter(3, accept_prob=0.0))
    assert at_zero > at_one
    assert at_zero > plain_report["moa_flops_total"]


# ---------------------------------------------------------------------------
# scheduler margin + gating
# ---------------------------------------------------------------------------


def test_spec_margin_tightens_admission(rng):
    """Invariant 3 with spec margin: a request that fits plain mode is
    rejected when prompt + max_new + k would overflow the slot."""
    cfg, model, params = _built("llama3-8b")
    engine = ServeEngine(model, params, n_slots=1, max_len=16, rng=rng,
                         clock=lambda: 0.0, drafter=OracleDrafter(3))
    ok = Request(uid=0, prompt=(1, 2, 3, 4), max_new_tokens=9)
    engine.submit(ok)
    with pytest.raises(ValueError, match="spec_margin"):
        engine.submit(Request(uid=1, prompt=(1, 2, 3, 4),
                              max_new_tokens=10))


def test_spec_rejects_unverifiable_family(rng):
    """Capacity-limited MoE has no exact multi-token verify."""
    import dataclasses
    cfg, model, params = _built("moonshot-v1-16b-a3b")
    tight = dataclasses.replace(cfg, capacity_factor=1.0)
    tight_model = build_model(tight)
    assert not tight_model.supports_spec_decode
    with pytest.raises(ValueError, match="verify"):
        ServeEngine(tight_model, params, n_slots=2, max_len=48,
                    drafter=OracleDrafter(2))


# ---------------------------------------------------------------------------
# acceptance rule
# ---------------------------------------------------------------------------


def _logits_for(targets, vocab, peak=50.0):
    """(B, T) target ids → logits strongly peaked on them."""
    return peak * jax.nn.one_hot(jnp.asarray(targets), vocab)


def test_verify_accept_greedy_prefix():
    """Greedy rows accept exactly the matching prefix and emit the argmax
    sequence: accepted drafts, then the correction token."""
    vocab, B, T = 11, 2, 4
    g = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]])
    logits = _logits_for(g, vocab)
    draft = jnp.asarray([[1, 2, 9], [9, 6, 7]])     # row0: 2 accepted
    out, n_acc = verify_accept(
        logits, draft, jnp.zeros((B,), jnp.float32),
        jnp.ones((B,), bool), jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(n_acc), [2, 0])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(g))


def test_verify_accept_temperature_degenerate():
    """With the target distribution collapsed onto single tokens,
    temperature acceptance is forced: matching drafts are accepted with
    probability ~1, mismatching ones rejected with the residual sample
    equal to the target token."""
    vocab, B = 7, 2
    g = jnp.asarray([[1, 2, 3], [4, 5, 6]])
    logits = _logits_for(g, vocab, peak=200.0)
    draft = jnp.asarray([[1, 2], [0, 5]])           # row1 rejects at 0
    out, n_acc = verify_accept(
        logits, draft, jnp.full((B,), 0.7, jnp.float32),
        jnp.zeros((B,), bool), jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(n_acc), [2, 0])
    # row0 fully accepted: drafts then bonus (= argmax under the
    # collapsed distribution); row1: residual at position 0 must be the
    # target token (all other mass is ~0)
    np.testing.assert_array_equal(np.asarray(out[0]), [1, 2, 3])
    assert int(out[1, 0]) == 4


def test_ngram_drafter_lookup_and_fallback():
    d = NgramDrafter(3, max_ngram=2)
    # "...7 8 9 ... 7 8" → propose what followed [7, 8] last time: 9, 1, 2
    hist = [7, 8, 9, 1, 2, 3, 7, 8]
    assert d.propose({0: hist})[0] == [9, 1, 2]
    # no repeat anywhere: pad with the last token
    assert d.propose({1: [1, 2, 3]})[1] == [3, 3, 3]


def test_resolve_drafter_specs():
    assert isinstance(resolve_drafter("ngram?n=2", 3), NgramDrafter)
    oracle = resolve_drafter("oracle?accept=0.25&seed=7", 2)
    assert isinstance(oracle, OracleDrafter)
    assert oracle.accept_prob == 0.25
    with pytest.raises(ValueError, match="unknown drafter"):
        resolve_drafter("mystery", 2)
    with pytest.raises(ValueError, match="unknown keys"):
        resolve_drafter("ngram?depth=2", 2)


# ---------------------------------------------------------------------------
# acceptance-aware costing
# ---------------------------------------------------------------------------


def test_expected_accepted_len_bounds():
    assert expected_accepted_len(3, 1.0) == 3.0
    assert expected_accepted_len(3, 0.0) == 0.0
    assert expected_accepted_len(4, 0.5) == pytest.approx(
        0.5 + 0.25 + 0.125 + 0.0625)


def test_spec_decode_cost_shape():
    """FLOPs overhead ≥ 1 always; tokens/step monotone in accept prob;
    free drafter's speedup equals the emitted-token count."""
    cfg = smoke_config(get_config("llama3-8b"))
    prev = 0.0
    for a in (0.0, 0.5, 1.0):
        c = spec_decode_cost(cfg, k=3, accept_prob=a, s_attn=64)
        assert c["flops_overhead"] >= 1.0 - 1e-9
        assert c["expected_tokens_per_step"] >= prev
        assert c["step_speedup"] == pytest.approx(
            c["expected_tokens_per_step"])
        prev = c["expected_tokens_per_step"]
    at_one = spec_decode_cost(cfg, k=3, accept_prob=1.0, s_attn=64)
    assert at_one["flops_overhead"] == pytest.approx(1.0)
    # a costly draft model needs a real accept rate to pay; a free
    # drafter breaks even immediately (within the bisection tolerance —
    # at a = 0 exactly, the gamble is a wash, not a win)
    assert spec_break_even_accept(cfg, k=3, s_attn=64, draft_cfg=cfg) > 0.01
    assert spec_break_even_accept(cfg, k=3, s_attn=64) <= 1e-3


# ---------------------------------------------------------------------------
# serving-v3 record + schema
# ---------------------------------------------------------------------------


def test_serving_v3_record_validates(rng):
    """The --spec-decode benchmark emits a schema-valid serving-v3 record
    and its accept-1 point clears the ≥1.5× tokens-per-step bar."""
    import importlib.util
    import pathlib
    import sys as _sys

    from benchmarks.serving import run_spec

    record = run_spec(requests=5, rate_rps=100.0, slots=2, max_len=48,
                      spec_k=3, accept_probs=(1.0, 0.0),
                      prompt_len_range=(4, 10), gen_len_range=(4, 10),
                      warmup=False)
    assert record["schema"] == "serving-v3"
    assert record["comparison"]["tokens_per_step_plain"] == pytest.approx(
        1.0)
    at_one = record["comparison"]["curve"][0]
    assert at_one["accept_prob"] == 1.0
    assert at_one["tokens_per_step"] >= 1.5
    assert at_one["speedup_vs_plain"] >= 1.5

    root = pathlib.Path(__file__).resolve().parents[1]
    spec_path = root / "scripts" / "check_bench_schema.py"
    spec = importlib.util.spec_from_file_location("check_bench_schema",
                                                  spec_path)
    mod = importlib.util.module_from_spec(spec)
    _sys.modules["check_bench_schema"] = mod
    spec.loader.exec_module(mod)
    assert mod.validate(record) == []
