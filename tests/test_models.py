"""Per-architecture smoke tests (reduced configs) + serving parity + CNNs.

One test per assigned architecture: instantiate the REDUCED same-family
config, run one forward/train step on CPU, assert output shapes and no
NaNs — per the assignment. Full configs are exercised only by the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeSpec, shape_applicable
from repro.configs.registry import ARCHS, get_config, list_archs, smoke_config
from repro.models import cnn
from repro.models.api import build_model

TRAIN = SHAPES["train_4k"]


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", sorted(ARCHS))
class TestArchSmoke:
    def test_train_step(self, key, arch):
        cfg = smoke_config(get_config(arch))
        model = build_model(cfg)
        params = model.init(key)
        batch = model.make_batch(key, TRAIN, batch_override=2,
                                 seq_override=32)
        loss, metrics = model.loss(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), arch
        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        leaves = jax.tree.leaves(grads)
        assert leaves, arch
        assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
                   for g in leaves), arch

    def test_forward_shapes(self, key, arch):
        cfg = smoke_config(get_config(arch))
        model = build_model(cfg)
        params = model.init(key)
        batch = model.make_batch(key, TRAIN, batch_override=2,
                                 seq_override=32)
        logits = model.forward(params, batch)
        if cfg.family == "encoder":
            expect_s = batch["frames"].shape[1]
        elif cfg.family == "vlm":
            expect_s = batch["tokens"].shape[1]
        else:
            expect_s = batch["tokens"].shape[1]
        assert logits.shape == (2, expect_s, cfg.vocab), arch
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", [a for a in sorted(ARCHS)
                                  if ARCHS[a].family != "encoder"])
def test_prefill_decode_matches_forward(key, arch):
    """Serving correctness: prefill + stepwise decode reproduce the full
    forward logits (exact for attention archs; bf16-state drift tolerance
    for SSM/hybrid)."""
    cfg = smoke_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(key)
    B, S = 2, 24
    batch = model.make_batch(key, SHAPES["prefill_32k"], batch_override=B,
                             seq_override=S)
    logits_full = model.forward(params, batch)
    n_text = batch["tokens"].shape[1]
    n_gen = 4
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : n_text - n_gen]
    lg, cache = model.prefill(params, pre, max_len=S)
    errs = [float(jnp.max(jnp.abs(lg[:, -1] -
                                  logits_full[:, n_text - n_gen - 1])))]
    for t in range(n_gen):
        tok = batch["tokens"][:, n_text - n_gen + t][:, None]
        lg, cache = model.decode_step(params, cache, tok)
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0] - logits_full[:, n_text - n_gen + t]))))
    # bf16 compute: logits carry ~bf16 eps (≈8e-3) × O(10) magnitudes of
    # reassociation drift between the flash (chunked) and decode (full)
    # softmax paths; SSM/hybrid additionally carry bf16 recurrent state.
    tol = 0.15 if cfg.family in ("ssm", "hybrid") else 0.05
    assert max(errs) < tol, (arch, errs)


def test_int8_kv_cache_decode_parity(key):
    """Quantized KV cache (the decode memory-roofline lever): decode stays
    within int8 quantization noise of the bf16 forward."""
    import dataclasses

    cfg = dataclasses.replace(smoke_config(get_config("llama3-8b")),
                              kv_cache_dtype="int8")
    model = build_model(cfg)
    params = model.init(key)
    B, S, n_gen = 2, 24, 3
    batch = model.make_batch(key, SHAPES["prefill_32k"], batch_override=B,
                             seq_override=S)
    logits_full = model.forward(params, batch)
    n_text = batch["tokens"].shape[1]
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : n_text - n_gen]
    lg, cache = model.prefill(params, pre, max_len=S)
    # quantized layout: int8 K/V + f32 scales live in the cache pytree
    assert "k_scale" in str(jax.tree_util.tree_structure(cache))
    assert cache["layers"]["k"].dtype == jnp.int8
    errs = []
    for t in range(n_gen):
        tok = batch["tokens"][:, n_text - n_gen + t][:, None]
        lg, cache = model.decode_step(params, cache, tok)
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0] - logits_full[:, n_text - n_gen + t]))))
    assert max(errs) < 0.25, errs


def test_skip_rules_match_assignment():
    """The DESIGN.md §5 skip table, executable."""
    expected_skips = {
        ("hubert-xlarge", "decode_32k"), ("hubert-xlarge", "long_500k"),
        ("qwen1.5-32b", "long_500k"), ("yi-34b", "long_500k"),
        ("llama3-8b", "long_500k"), ("llama3-405b", "long_500k"),
        ("llava-next-34b", "long_500k"),
        ("llama4-maverick-400b-a17b", "long_500k"),
        ("moonshot-v1-16b-a3b", "long_500k"),
    }
    actual = set()
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                actual.add((arch, sname))
    assert actual == expected_skips
    # → 40 − 9 skips = 31 valid cells… plus the two SSM long_500k runs
    assert len(ARCHS) * len(SHAPES) - len(actual) == 31


def test_exact_assigned_configs():
    """The registry carries the EXACT assigned dimensions."""
    c = get_config("qwen1.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.qkv_bias) == (64, 5120, 40, 40, 27392, 152064, True)
    c = get_config("yi-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (60, 7168, 56, 8, 20480, 64000)
    c = get_config("llama3-405b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (126, 16384, 128, 8, 53248, 128256)
    c = get_config("llama4-maverick-400b-a17b")
    assert (c.n_experts, c.top_k, c.vocab, c.d_ff) == (128, 1, 202048, 8192)
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_experts, c.top_k, c.vocab, c.d_ff) == (64, 6, 163840, 1408)
    c = get_config("mamba2-370m")
    assert (c.n_layers, c.d_model, c.d_state, c.vocab) == (48, 1024, 128,
                                                           50280)
    c = get_config("zamba2-1.2b")
    assert (c.n_layers, c.d_model, c.d_state, c.vocab) == (38, 2048, 64,
                                                           32000)
    c = get_config("hubert-xlarge")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (48, 1280, 16, 5120, 504)


def test_param_counts_in_expected_range():
    """Sanity: analytic parameter counts match the model names."""
    expect = {
        "llama3-8b": (7e9, 9e9),
        "llama3-405b": (380e9, 420e9),
        "yi-34b": (32e9, 36e9),
        # MHA (kv=40) + 152k vocab push the assigned dims slightly above
        # the "32b" name: 35.2B
        "qwen1.5-32b": (30e9, 37e9),
        "mamba2-370m": (0.3e9, 0.45e9),
        "zamba2-1.2b": (1.0e9, 1.5e9),
        "hubert-xlarge": (0.9e9, 1.1e9),
        # NOTE: the *assigned* dims (48L × 128 experts × d_ff 8192 each)
        # give 778B total / 11B active — the HF "400b-a17b" card uses a
        # different layer mix (interleaved dense/MoE); we implement the
        # assignment's numbers and document the delta in EXPERIMENTS.md.
        "llama4-maverick-400b-a17b": (700e9, 830e9),
        "moonshot-v1-16b-a3b": (26e9, 30e9),  # 64e × d_ff 1408 as assigned
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    active = get_config("llama4-maverick-400b-a17b").active_param_count()
    assert 9e9 <= active <= 14e9, active  # "a17b" under assigned dims
    active = get_config("moonshot-v1-16b-a3b").active_param_count()
    assert 2e9 <= active <= 5e9, active  # "a3b"


class TestCNN:
    def test_config_modules(self):
        """The per-arch conv config modules agree with the model layouts."""
        from repro.configs import alexnet, lenet5

        assert lenet5.NAME == "lenet5"
        assert lenet5.INPUT_SHAPE == (32, 32, 1)
        assert lenet5.LENET5_LAYOUT is cnn.LENET5_LAYOUT
        assert alexnet.NAME == "alexnet"
        assert alexnet.INPUT_SHAPE == (227, 227, 3)
        assert alexnet.ALEXNET_LAYOUT is cnn.ALEXNET_LAYOUT
        assert len(alexnet.ALEXNET_CONV_SPECS) == 5

    def test_lenet5_forward(self, key):
        params = cnn.init_lenet5(key)
        x = jax.random.normal(key, (2, 32, 32, 1))
        logits = cnn.lenet5_forward(params, x)
        assert logits.shape == (2, 10)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_alexnet_forward(self, key):
        params = cnn.init_alexnet(key)
        x = jax.random.normal(key, (1, 227, 227, 3))
        logits = cnn.alexnet_forward(params, x)
        assert logits.shape == (1, 1000)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_im2col_conv_matches_lax_conv(self, key):
        """The DHM-style explicit-MOA conv equals XLA's fused conv."""
        from jax import lax

        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (2, 16, 16, 3))
        w = jax.random.normal(kw, (8, 3, 5, 5))
        b = jnp.zeros((8,))
        got = cnn.im2col_conv(x, w, b, stride=1)
        want = lax.conv_general_dilated(
            x, w, (1, 1), "VALID",
            dimension_numbers=("NHWC", "OIHW", "NHWC"))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_lenet5_accum_im2col_parity(self, key):
        """The accum="im2col" path (every groups==1 conv through the MOA
        strategy) matches the lax.conv baseline end-to-end."""
        params = cnn.init_lenet5(key)
        x = jax.random.normal(key, (2, 32, 32, 1))
        ref = cnn.lenet5_forward(params, x)
        got = cnn.lenet5_forward(params, x, accum="im2col")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        serial = cnn.lenet5_forward(params, x, accum="im2col",
                                    strategy="serial?chunk=16")
        np.testing.assert_allclose(np.asarray(serial), np.asarray(ref),
                                   rtol=1e-3, atol=1e-3)
        with pytest.raises(ValueError, match="accum"):
            cnn.lenet5_forward(params, x, accum="winograd")

    def test_alexnet_accum_im2col_parity(self, key):
        """AlexNet: groups==1 layers (conv1 stride 4, conv3 SAME padding)
        route through im2col; the grouped layers keep lax.conv."""
        params = cnn.init_alexnet(key)
        x = jax.random.normal(key, (1, 227, 227, 3))
        ref = cnn.alexnet_forward(params, x)
        got = cnn.alexnet_forward(params, x, accum="im2col")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_im2col_conv_same_padding(self, key):
        """SAME padding support (needed by AlexNet conv3)."""
        from jax import lax

        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (2, 9, 9, 3))
        w = jax.random.normal(kw, (4, 3, 3, 3))
        b = jnp.zeros((4,))
        got = cnn.im2col_conv(x, w, b, stride=1, padding="SAME")
        want = lax.conv_general_dilated(
            x, w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "OIHW", "NHWC"))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_im2col_conv_serial_strategy(self, key):
        kx, kw = jax.random.split(key)
        x = jax.random.normal(kx, (1, 12, 12, 3))
        w = jax.random.normal(kw, (4, 3, 3, 3))
        b = jnp.zeros((4,))
        tree = cnn.im2col_conv(x, w, b, stride=1)
        serial = cnn.im2col_conv(x, w, b, stride=1, strategy="serial?chunk=8")
        np.testing.assert_allclose(np.asarray(serial), np.asarray(tree),
                                   rtol=1e-4, atol=1e-4)
