"""Pallas kernel sweeps: shapes × dtypes × block sizes vs the jnp oracles.

All kernels execute in interpret mode on CPU (the kernel body runs in
Python) — the TPU lowering path (BlockSpec tiling, grid accumulation) is
identical code.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rtol(dtype):
    return {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}.get(dtype, 0)


# ---------------------------------------------------------------------------
# moa_reduce
# ---------------------------------------------------------------------------

class TestMoaReduce:
    @pytest.mark.parametrize("shape", [(8, 16), (100, 33), (1000, 256),
                                       (4096, 128), (7, 5), (513, 129)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
    def test_matches_oracle(self, rng, shape, dtype):
        if jnp.issubdtype(dtype, jnp.integer):
            x = jax.random.randint(rng, shape, -100, 100, dtype)
        else:
            x = jax.random.normal(rng, shape, jnp.float32).astype(dtype)
        got = ops.moa_reduce(x)
        want = ref.moa_reduce_ref(x)
        if jnp.issubdtype(dtype, jnp.integer):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        else:
            np.testing.assert_allclose(np.asarray(got, np.float32),
                                       np.asarray(want, np.float32),
                                       rtol=_rtol(dtype), atol=1e-2)

    @pytest.mark.parametrize("block_n,block_f", [(64, 64), (512, 256),
                                                 (128, 512), (1024, 32)])
    def test_block_shape_invariance(self, rng, block_n, block_f):
        """The serialized-MOA cluster size n_c must not change the result."""
        x = jax.random.normal(rng, (777, 130), jnp.float32)
        got = ops.moa_reduce(x, block_n=block_n, block_f=block_f)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.moa_reduce_ref(x)),
                                   rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# loa_add / loa_reduce
# ---------------------------------------------------------------------------

class TestLoaKernels:
    @pytest.mark.parametrize("n", [16, 100, 1024, 5000])
    @pytest.mark.parametrize("l", [0, 1, 3, 6, 8])
    def test_loa_add_matches_oracle(self, rng, n, l):
        kx, ky = jax.random.split(rng)
        x = jax.random.randint(kx, (n,), 0, 256, jnp.int32)
        y = jax.random.randint(ky, (n,), 0, 256, jnp.int32)
        got = ops.loa_add(x, y, approx_bits=l)
        want = ref.loa_add_ref(x, y, approx_bits=l, width=8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("shape", [(256, 64), (512, 100), (1024, 256)])
    @pytest.mark.parametrize("l", [0, 2, 4])
    def test_loa_reduce_matches_oracle(self, rng, shape, l):
        x = jax.random.randint(rng, shape, 0, 128, jnp.int32)
        got = ops.loa_reduce(x, approx_bits=l, block_n=min(256, shape[0]))
        want = ref.loa_reduce_ref(x, approx_bits=l, width=8,
                                  block_n=min(256, shape[0]))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_loa_reduce_exact_when_l0(self, rng):
        x = jax.random.randint(rng, (512, 32), 0, 128, jnp.int32)
        got = ops.loa_reduce(x, approx_bits=0, block_n=128)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(jnp.sum(x, axis=0)))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

class TestFlashAttention:
    @pytest.mark.parametrize("sq,skv,d", [(64, 64, 32), (100, 100, 16),
                                          (128, 256, 64), (37, 53, 32)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_oracle(self, rng, sq, skv, d, causal):
        if causal and sq != skv:
            pytest.skip("causal requires aligned q/kv positions here")
        kq, kk, kv = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (3, sq, d), jnp.float32)
        k = jax.random.normal(kk, (3, skv, d), jnp.float32)
        v = jax.random.normal(kv, (3, skv, d), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal, block_q=32,
                                  block_k=32)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bq,bk", [(16, 64), (64, 16), (128, 128)])
    def test_block_shape_invariance(self, rng, bq, bk):
        """The serialized-softmax cluster size must not change the math."""
        kq, kk, kv = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (2, 128, 32), jnp.float32)
        k = jax.random.normal(kk, (2, 128, 32), jnp.float32)
        v = jax.random.normal(kv, (2, 128, 32), jnp.float32)
        got = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bq,bk", [(16, 16), (32, 64), (64, 32)])
    def test_causal_block_skip_parity(self, rng, bq, bk):
        """Causal runs skip fully-above-diagonal k-blocks via ``pl.when``
        instead of computing-then-masking them; the skip must change no
        bits relative to the unskipped schedule. Comparing across block
        shapes moves the diagonal through different skip patterns — any
        dropped live block or leaked dead block shows up immediately."""
        kq, kk, kv = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (2, 128, 32), jnp.float32)
        k = jax.random.normal(kk, (2, 128, 32), jnp.float32)
        v = jax.random.normal(kv, (2, 128, 32), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_k=bk)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        # single k-block along the row => nothing skippable: the skipped
        # and unskipped schedules fold the identical block sequence
        whole_row = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                        block_k=128)
        np.testing.assert_allclose(np.asarray(whole_row),
                                   np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_bf16_inputs(self, rng):
        kq, kk, kv = jax.random.split(rng, 3)
        q = jax.random.normal(kq, (2, 64, 32)).astype(jnp.bfloat16)
        k = jax.random.normal(kk, (2, 64, 32)).astype(jnp.bfloat16)
        v = jax.random.normal(kv, (2, 64, 32)).astype(jnp.bfloat16)
        got = ops.flash_attention(q, k, v, block_q=32, block_k=32)
        want = ref.flash_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# dot_moa
# ---------------------------------------------------------------------------

class TestDotMoa:
    @pytest.mark.parametrize("m,k,n", [(32, 64, 16), (100, 700, 130),
                                       (256, 1024, 256), (17, 33, 9)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_float_matches_oracle(self, rng, m, k, n, dtype):
        ka, kb = jax.random.split(rng)
        a = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
        b = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
        got = ops.dot_moa(a, b, block_m=64, block_n=64, block_k=256)
        want = ref.dot_moa_ref(a, b)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                                   atol=1e-1 if dtype == jnp.bfloat16 else 1e-4)

    @pytest.mark.parametrize("block_k", [64, 128, 512])
    def test_int8_exact(self, rng, block_k):
        ka, kb = jax.random.split(rng)
        a = jax.random.randint(ka, (64, 512), -8, 8, jnp.int8)
        b = jax.random.randint(kb, (512, 48), -8, 8, jnp.int8)
        got = ops.dot_moa(a, b, block_k=block_k)
        want = np.asarray(a, np.int32) @ np.asarray(b, np.int32)
        np.testing.assert_array_equal(np.asarray(got), want)

    def test_loa_accumulation_bounded_error(self, rng):
        """Serialized LOA MOA: error bounded by (#folds) · 2^l."""
        ka, kb = jax.random.split(rng)
        a = jax.random.randint(ka, (16, 512), 0, 8, jnp.int32)
        b = jax.random.randint(kb, (512, 16), 0, 8, jnp.int32)
        l, block_k = 4, 128
        got = np.asarray(ops.dot_moa(a, b, block_k=block_k, approx_bits=l))
        want = np.asarray(a) @ np.asarray(b)
        n_folds = 512 // block_k - 1
        assert np.all(np.abs(got - want) <= n_folds * (1 << l))

    def test_block_shape_invariance_f32(self, rng):
        ka, kb = jax.random.split(rng)
        a = jax.random.normal(ka, (128, 1000), jnp.float32)
        b = jax.random.normal(kb, (1000, 64), jnp.float32)
        outs = [np.asarray(ops.dot_moa(a, b, block_m=bm, block_n=bn,
                                       block_k=bk))
                for bm, bn, bk in [(32, 32, 128), (128, 64, 500),
                                   (64, 64, 1000)]]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-4)
