"""Hypothesis property tests: scheduler + block-pool invariants.

Random submit/admit/release/alloc/share/free/match sequences against
``SlotScheduler`` and ``BlockPool``, asserting the documented invariants
after every step: slots partition free/active (S1), FIFO admission over
arrived requests (S2), lifetime fit (S3), bucket fit (S4), gate = strict
head-of-line backpressure (S6); pool states partition (P1), refcount >= 1
with no double-free (P2), trie points at live blocks (P3), alloc never
hands out referenced blocks (P4), admission plans fit availability (P5).

Skips (like ``test_moa_properties.py``) when hypothesis is absent.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.serve.kv_pool import BlockPool, blocks_needed
from repro.serve.request import Request
from repro.serve.scheduler import SlotScheduler

# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

_MAX_LEN = 32

# op stream: ("submit", arrival_s, prompt_len, max_new) | ("admit", now_s)
# | ("release",) — release frees the longest-held active slot
_SCHED_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("submit"),
                  st.floats(0.0, 10.0, allow_nan=False),
                  st.integers(1, 16), st.integers(1, 16)),
        st.tuples(st.just("admit"), st.floats(0.0, 10.0, allow_nan=False)),
        st.tuples(st.just("release")),
    ),
    min_size=1, max_size=60)


class TestSchedulerProperties:
    @given(ops=_SCHED_OPS, n_slots=st.integers(1, 4))
    @settings(max_examples=60, deadline=None)
    def test_invariants_under_random_ops(self, ops, n_slots):
        sched = SlotScheduler(n_slots, max_len=_MAX_LEN)
        uid = 0
        submitted = {}                 # uid -> arrival_s
        admitted_order = []
        clock_high = 0.0
        for op in ops:
            if op[0] == "submit":
                _, arr, p, g = op
                req = Request(uid=uid, prompt=(1,) * p,
                              max_new_tokens=min(g, _MAX_LEN - p),
                              arrival_s=arr)
                if p + req.max_new_tokens > _MAX_LEN \
                        or req.max_new_tokens < 1:
                    continue
                sched.submit(req)
                submitted[uid] = arr
                uid += 1
            elif op[0] == "admit":
                now = max(op[1], clock_high)   # engine clock is monotonic
                clock_high = now
                for slot, req in sched.admit_ready(now):
                    # S2: only arrived requests are admitted
                    assert req.arrival_s <= now
                    # S3: fits for its whole lifetime
                    assert req.prompt_len + req.max_new_tokens <= _MAX_LEN
                    # S4: prompt fits a bucket
                    assert sched.bucket_for(req.prompt_len) \
                        <= sched.buckets[-1]
                    admitted_order.append(req.uid)
            elif sched.active:
                sched.release(min(sched.active))
            # S1: free and active slots partition the slot set
            free = set(sched._free)
            active = set(sched.active)
            assert not (free & active)
            assert free | active == set(range(n_slots))
        # S2 (global): among same-arrival requests, admission is uid-FIFO
        by_arrival = {}
        for u in admitted_order:
            by_arrival.setdefault(submitted[u], []).append(u)
        for group in by_arrival.values():
            assert group == sorted(group)

    @given(reject_after=st.integers(0, 5))
    @settings(max_examples=20, deadline=None)
    def test_gate_blocks_head_of_line(self, reject_after):
        """S6: once the gate rejects the queue head, nothing behind it is
        admitted — FIFO is never reordered."""
        sched = SlotScheduler(4, max_len=_MAX_LEN)
        for u in range(6):
            sched.submit(Request(uid=u, prompt=(1, 2), max_new_tokens=2))
        admitted = sched.admit_ready(
            0.0, gate=lambda req: req.uid < reject_after)
        assert [r.uid for _, r in admitted] == \
            list(range(min(reject_after, 4)))

    @given(n=st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_admit_limit(self, n):
        sched = SlotScheduler(8, max_len=_MAX_LEN)
        for u in range(8):
            sched.submit(Request(uid=u, prompt=(1,), max_new_tokens=1))
        assert len(sched.admit_ready(0.0, limit=n)) == n


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------

# op stream over a pool: alloc n | free i-th live | share i-th live |
# register i-th live | match+admit a synthetic prompt
_POOL_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(1, 4)),
        st.tuples(st.just("free"), st.integers(0, 30)),
        st.tuples(st.just("share"), st.integers(0, 30)),
        st.tuples(st.just("register"), st.integers(0, 30)),
        st.tuples(st.just("plan"), st.integers(1, 20), st.integers(1, 8)),
    ),
    min_size=1, max_size=80)


def _admit(pool: BlockPool, prompt, max_new: int):
    """Engine-shaped admission against a bare pool: gate, plan, share
    matched pages, allocate the rest (+ CoW spare on a matched tail),
    register the privately written prompt blocks. Returns every block
    the admission holds a reference to, or None when the gate refuses."""
    if not pool.can_admit(prompt, max_new):
        return None
    plan = pool.plan(prompt, max_new)
    for b in plan.full_matched:
        pool.share(b)
    if plan.tail_matched is not None:
        pool.share(plan.tail_matched)
    fresh = iter(pool.alloc(plan.new_needed))
    n_full = len(plan.full_matched)
    blocks = list(plan.full_matched)
    tail_idx = n_full if plan.tail_matched is not None else None
    for i in range(n_full, plan.n_logical):
        blocks.append(plan.tail_matched if i == tail_idx else next(fresh))
    held = list(blocks)
    if plan.tail_matched is not None:
        held.append(next(fresh))                   # the CoW spare
    bs, p = pool.block_size, len(prompt)
    for i in range(n_full, p // bs):
        pool.register(blocks[i], prompt[: (i + 1) * bs])
    if p % bs and plan.tail_matched is None and p // bs < plan.n_logical:
        pool.register(blocks[p // bs], prompt)
    return held


# op stream mirroring an engine's lifetime: admissions (which share/alloc/
# register), releases, and forced eviction storms (alloc everything
# available, then free it — every evictable cached block gets reclaimed)
_EVICT_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("admit"),
                  st.lists(st.integers(0, 1), min_size=1, max_size=12),
                  st.integers(1, 6)),
        st.tuples(st.just("release"), st.integers(0, 30)),
        st.tuples(st.just("storm"), st.integers(1, 8)),
    ),
    min_size=1, max_size=60)


class TestBlockPoolProperties:
    @given(ops=_POOL_OPS, n_blocks=st.integers(2, 12))
    @settings(max_examples=60, deadline=None)
    def test_invariants_under_random_ops(self, ops, n_blocks):
        bs = 4
        pool = BlockPool(n_blocks, block_size=bs)
        live = []                      # (block_id, outstanding_refs)
        chain_seq = 0
        for op in ops:
            kind = op[0]
            if kind == "alloc":
                n = min(op[1], pool.available)
                if n:
                    got = pool.alloc(n)
                    # P4: never hands out a still-referenced block
                    assert not (set(got) & {b for b, _ in live})
                    live.extend((b, 1) for b in got)
            elif kind == "free" and live:
                i = op[1] % len(live)
                b, refs = live[i]
                pool.free(b)
                if refs == 1:
                    live.pop(i)
                    # P2: freeing again raises unless re-referenced
                    if pool.refcount(b) == 0:
                        with pytest.raises(KeyError):
                            pool.free(b)
                else:
                    live[i] = (b, refs - 1)
            elif kind == "share" and live:
                i = op[1] % len(live)
                b, refs = live[i]
                pool.share(b)
                live[i] = (b, refs + 1)
                assert pool.refcount(b) == refs + 1
            elif kind == "register" and live:
                i = op[1] % len(live)
                chain_seq += 1
                pool.register(live[i][0], (chain_seq,) * bs)
            elif kind == "plan":
                p, g = op[1], op[2]
                plan = pool.plan(tuple(range(p)), g)
                assert plan.n_logical == blocks_needed(p, g, bs)
                assert 0 <= plan.new_needed <= plan.n_logical
                # P5: can_admit iff the plan fits current availability
                assert pool.can_admit(tuple(range(p)), g) == \
                    (plan.new_needed <= pool.available)
            # P1-P3 after every operation
            pool.check()
            # refcounts match our model
            for b, refs in live:
                assert pool.refcount(b) == refs

    @given(ops=_EVICT_OPS, n_blocks=st.integers(2, 10))
    @settings(max_examples=60, deadline=None)
    def test_eviction_storm_keeps_invariants(self, ops, n_blocks):
        """Interleaved admissions, releases, and forced eviction storms:
        P1-P5 (including P3's prefix closure — eviction must cascade to
        the chain suffix rooted below the reclaimed block) hold after
        every op. Prompts come from a 2-token alphabet so prefixes collide
        constantly and the trie grows real chains."""
        pool = BlockPool(n_blocks, block_size=4)
        live = []                          # per-admission held block lists
        for op in ops:
            if op[0] == "admit":
                prompt, max_new = tuple(op[1]), op[2]
                plan = pool.plan(prompt, max_new)
                admissible = pool.can_admit(prompt, max_new)
                # P5: the gate's verdict matches the plan's need (matched
                # evictable pages count as revived, not allocatable)
                held = _admit(pool, prompt, max_new)
                assert (held is not None) == admissible
                if held is not None:
                    assert plan.new_needed <= n_blocks
                    live.append(held)
            elif op[0] == "release" and live:
                for b in live.pop(op[1] % len(live)):
                    pool.free(b)
            elif op[0] == "storm":
                n = min(op[1], pool.available)
                if n:
                    got = pool.alloc(n)
                    # P4: a storm never hands out a block a live
                    # admission still references
                    assert not (set(got) & {b for bl in live for b in bl})
                    for b in got:
                        pool.free(b)
            pool.check()                   # P1-P3 incl. prefix closure
