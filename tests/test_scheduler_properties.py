"""Hypothesis property tests: scheduler + block-pool invariants.

Random submit/admit/release/alloc/share/free/match sequences against
``SlotScheduler`` and ``BlockPool``, asserting the documented invariants
after every step: slots partition free/active (S1), FIFO admission over
arrived requests (S2), lifetime fit (S3), bucket fit (S4), gate = strict
head-of-line backpressure (S6); pool states partition (P1), refcount >= 1
with no double-free (P2), trie points at live blocks (P3), alloc never
hands out referenced blocks (P4), admission plans fit availability (P5).

The preemption lifecycle (invariant S7, docs/slo-scheduling.md) gets its
own op streams: submit/admit/preempt/release under both policies with
``check()`` after every op, SLO-ordered re-admission of preempted
requests, and eviction storms interleaved with engine-style spills —
a spilled request's pinned pages must survive any storm and stay
revivable.

Skips (like ``test_moa_properties.py``) when hypothesis is absent.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.serve.kv_pool import BlockPool, blocks_needed
from repro.serve.request import Request
from repro.serve.scheduler import SlotScheduler

# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

_MAX_LEN = 32

# op stream: ("submit", arrival_s, prompt_len, max_new) | ("admit", now_s)
# | ("release",) — release frees the longest-held active slot
_SCHED_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("submit"),
                  st.floats(0.0, 10.0, allow_nan=False),
                  st.integers(1, 16), st.integers(1, 16)),
        st.tuples(st.just("admit"), st.floats(0.0, 10.0, allow_nan=False)),
        st.tuples(st.just("release")),
    ),
    min_size=1, max_size=60)


class TestSchedulerProperties:
    @given(ops=_SCHED_OPS, n_slots=st.integers(1, 4))
    @settings(max_examples=60, deadline=None)
    def test_invariants_under_random_ops(self, ops, n_slots):
        sched = SlotScheduler(n_slots, max_len=_MAX_LEN)
        uid = 0
        submitted = {}                 # uid -> arrival_s
        admitted_order = []
        clock_high = 0.0
        for op in ops:
            if op[0] == "submit":
                _, arr, p, g = op
                req = Request(uid=uid, prompt=(1,) * p,
                              max_new_tokens=min(g, _MAX_LEN - p),
                              arrival_s=arr)
                if p + req.max_new_tokens > _MAX_LEN \
                        or req.max_new_tokens < 1:
                    continue
                sched.submit(req)
                submitted[uid] = arr
                uid += 1
            elif op[0] == "admit":
                now = max(op[1], clock_high)   # engine clock is monotonic
                clock_high = now
                for slot, req in sched.admit_ready(now):
                    # S2: only arrived requests are admitted
                    assert req.arrival_s <= now
                    # S3: fits for its whole lifetime
                    assert req.prompt_len + req.max_new_tokens <= _MAX_LEN
                    # S4: prompt fits a bucket
                    assert sched.bucket_for(req.prompt_len) \
                        <= sched.buckets[-1]
                    admitted_order.append(req.uid)
            elif sched.active:
                sched.release(min(sched.active))
            # S1: free and active slots partition the slot set
            free = set(sched._free)
            active = set(sched.active)
            assert not (free & active)
            assert free | active == set(range(n_slots))
        # S2 (global): among same-arrival requests, admission is uid-FIFO
        by_arrival = {}
        for u in admitted_order:
            by_arrival.setdefault(submitted[u], []).append(u)
        for group in by_arrival.values():
            assert group == sorted(group)

    @given(reject_after=st.integers(0, 5))
    @settings(max_examples=20, deadline=None)
    def test_gate_blocks_head_of_line(self, reject_after):
        """S6: once the gate rejects the queue head, nothing behind it is
        admitted — FIFO is never reordered."""
        sched = SlotScheduler(4, max_len=_MAX_LEN)
        for u in range(6):
            sched.submit(Request(uid=u, prompt=(1, 2), max_new_tokens=2))
        admitted = sched.admit_ready(
            0.0, gate=lambda req: req.uid < reject_after)
        assert [r.uid for _, r in admitted] == \
            list(range(min(reject_after, 4)))

    @given(n=st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_admit_limit(self, n):
        sched = SlotScheduler(8, max_len=_MAX_LEN)
        for u in range(8):
            sched.submit(Request(uid=u, prompt=(1,), max_new_tokens=1))
        assert len(sched.admit_ready(0.0, limit=n)) == n


# ---------------------------------------------------------------------------
# preemption lifecycle (S7)
# ---------------------------------------------------------------------------

# op stream: submit carries (arrival, prompt, gen, priority, deadline
# offset | None); preempt picks an active slot by index; admit/release as
# before
_PREEMPT_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("submit"),
                  st.floats(0.0, 10.0, allow_nan=False),
                  st.integers(1, 16), st.integers(1, 16),
                  st.integers(0, 2),
                  st.one_of(st.none(),
                            st.floats(0.01, 20.0, allow_nan=False))),
        st.tuples(st.just("admit"), st.floats(0.0, 10.0, allow_nan=False)),
        st.tuples(st.just("release")),
        st.tuples(st.just("preempt"), st.integers(0, 3)),
    ),
    min_size=1, max_size=80)


class TestPreemptionLifecycleProperties:
    @given(ops=_PREEMPT_OPS, n_slots=st.integers(1, 4),
           policy=st.sampled_from(["fifo", "slo"]))
    @settings(max_examples=60, deadline=None)
    def test_invariants_under_preemption(self, ops, n_slots, policy):
        """S1-S4 + S7 hold through arbitrary submit/admit/preempt/release
        interleavings under both policies: a preempted slot is immediately
        free, the request is requeued exactly once (never active *and*
        queued), every preemption is logged, and the scheduler still
        drains to done."""
        sched = SlotScheduler(n_slots, max_len=_MAX_LEN, policy=policy)
        uid = 0
        clock_high = 0.0
        preempted_uids = []
        for op in ops:
            if op[0] == "submit":
                _, arr, p, g, pri, dl = op
                req = Request(uid=uid, prompt=(1,) * p,
                              max_new_tokens=min(g, _MAX_LEN - p),
                              arrival_s=arr, priority=pri,
                              deadline_s=arr + dl if dl is not None
                              else None)
                if req.max_new_tokens < 1:
                    continue
                sched.submit(req)
                uid += 1
            elif op[0] == "admit":
                clock_high = max(op[1], clock_high)
                sched.admit_ready(clock_high)
            elif op[0] == "release" and sched.active:
                sched.release(min(sched.active))
            elif op[0] == "preempt" and sched.active:
                slot = sorted(sched.active)[op[1] % len(sched.active)]
                victim = sched.active[slot]
                req = sched.preempt(slot, clock_high)
                # S7: same request handed back, slot free, re-queued
                assert req.uid == victim.uid
                assert slot not in sched.active
                assert sched.has_ready or sched.has_pending
                assert sched.preemption_log[-1][:2] == (req.uid, slot)
                preempted_uids.append(req.uid)
            sched.check()      # S1-S4 + S7 structural audit, every op
        # drain: every request (preempted ones included) is re-admissible
        n_preempted = len(sched.preemption_log)
        assert n_preempted == len(preempted_uids)
        while not sched.done:
            for slot in list(sched.active):
                sched.release(slot)
            if sched.has_pending:
                assert sched.admit_ready(clock_high + 1e9), \
                    "stuck: requests queued, slots free, none admitted"
            sched.check()

    @given(subs=st.lists(
        st.tuples(st.integers(0, 3),
                  st.one_of(st.none(),
                            st.floats(0.1, 50.0, allow_nan=False))),
        min_size=1, max_size=12))
    @settings(max_examples=60, deadline=None)
    def test_slo_policy_orders_by_priority_then_deadline(self, subs):
        """With everything arrived and one slot, repeated admit/preempt
        cycles pop requests in exact (priority desc, deadline asc,
        arrival, uid) order — including requests re-queued by preemption,
        which keep their rank rather than jumping the line."""
        sched = SlotScheduler(1, max_len=_MAX_LEN, policy="slo")
        reqs = []
        for u, (pri, dl) in enumerate(subs):
            req = Request(uid=u, prompt=(1, 2), max_new_tokens=2,
                          arrival_s=0.0, priority=pri,
                          deadline_s=dl)
            sched.submit(req)
            reqs.append(req)
        want = sorted(reqs, key=lambda r: (
            -r.priority,
            r.deadline_s if r.deadline_s is not None else float("inf"),
            r.arrival_s, r.uid))
        # first pop, then preempt it straight back once: the re-queued
        # entry must re-emerge before anything ranked behind it
        [(slot, first)] = sched.admit_ready(1.0)
        assert first.uid == want[0].uid
        sched.preempt(slot, 1.0)
        sched.check()
        got = []
        while sched.has_ready or sched.has_pending:
            [(slot, req)] = sched.admit_ready(1.0)
            got.append(req.uid)
            sched.release(slot)
        assert got == [r.uid for r in want]


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------

# op stream over a pool: alloc n | free i-th live | share i-th live |
# register i-th live | match+admit a synthetic prompt
_POOL_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("alloc"), st.integers(1, 4)),
        st.tuples(st.just("free"), st.integers(0, 30)),
        st.tuples(st.just("share"), st.integers(0, 30)),
        st.tuples(st.just("register"), st.integers(0, 30)),
        st.tuples(st.just("plan"), st.integers(1, 20), st.integers(1, 8)),
    ),
    min_size=1, max_size=80)


def _admit(pool: BlockPool, prompt, max_new: int):
    """Engine-shaped admission against a bare pool: gate, plan, share
    matched pages, allocate the rest (+ CoW spare on a matched tail),
    register the privately written prompt blocks. Returns every block
    the admission holds a reference to, or None when the gate refuses."""
    if not pool.can_admit(prompt, max_new):
        return None
    plan = pool.plan(prompt, max_new)
    for b in plan.full_matched:
        pool.share(b)
    if plan.tail_matched is not None:
        pool.share(plan.tail_matched)
    fresh = iter(pool.alloc(plan.new_needed))
    n_full = len(plan.full_matched)
    blocks = list(plan.full_matched)
    tail_idx = n_full if plan.tail_matched is not None else None
    for i in range(n_full, plan.n_logical):
        blocks.append(plan.tail_matched if i == tail_idx else next(fresh))
    held = list(blocks)
    if plan.tail_matched is not None:
        held.append(next(fresh))                   # the CoW spare
    bs, p = pool.block_size, len(prompt)
    for i in range(n_full, p // bs):
        pool.register(blocks[i], prompt[: (i + 1) * bs])
    if p % bs and plan.tail_matched is None and p // bs < plan.n_logical:
        pool.register(blocks[p // bs], prompt)
    return held


# op stream mirroring an engine's lifetime: admissions (which share/alloc/
# register), releases, and forced eviction storms (alloc everything
# available, then free it — every evictable cached block gets reclaimed)
_EVICT_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("admit"),
                  st.lists(st.integers(0, 1), min_size=1, max_size=12),
                  st.integers(1, 6)),
        st.tuples(st.just("release"), st.integers(0, 30)),
        st.tuples(st.just("storm"), st.integers(1, 8)),
    ),
    min_size=1, max_size=60)


class TestBlockPoolProperties:
    @given(ops=_POOL_OPS, n_blocks=st.integers(2, 12))
    @settings(max_examples=60, deadline=None)
    def test_invariants_under_random_ops(self, ops, n_blocks):
        bs = 4
        pool = BlockPool(n_blocks, block_size=bs)
        live = []                      # (block_id, outstanding_refs)
        chain_seq = 0
        for op in ops:
            kind = op[0]
            if kind == "alloc":
                n = min(op[1], pool.available)
                if n:
                    got = pool.alloc(n)
                    # P4: never hands out a still-referenced block
                    assert not (set(got) & {b for b, _ in live})
                    live.extend((b, 1) for b in got)
            elif kind == "free" and live:
                i = op[1] % len(live)
                b, refs = live[i]
                pool.free(b)
                if refs == 1:
                    live.pop(i)
                    # P2: freeing again raises unless re-referenced
                    if pool.refcount(b) == 0:
                        with pytest.raises(KeyError):
                            pool.free(b)
                else:
                    live[i] = (b, refs - 1)
            elif kind == "share" and live:
                i = op[1] % len(live)
                b, refs = live[i]
                pool.share(b)
                live[i] = (b, refs + 1)
                assert pool.refcount(b) == refs + 1
            elif kind == "register" and live:
                i = op[1] % len(live)
                chain_seq += 1
                pool.register(live[i][0], (chain_seq,) * bs)
            elif kind == "plan":
                p, g = op[1], op[2]
                plan = pool.plan(tuple(range(p)), g)
                assert plan.n_logical == blocks_needed(p, g, bs)
                assert 0 <= plan.new_needed <= plan.n_logical
                # P5: can_admit iff the plan fits current availability
                assert pool.can_admit(tuple(range(p)), g) == \
                    (plan.new_needed <= pool.available)
            # P1-P3 after every operation
            pool.check()
            # refcounts match our model
            for b, refs in live:
                assert pool.refcount(b) == refs

    @given(ops=_EVICT_OPS, n_blocks=st.integers(2, 10))
    @settings(max_examples=60, deadline=None)
    def test_eviction_storm_keeps_invariants(self, ops, n_blocks):
        """Interleaved admissions, releases, and forced eviction storms:
        P1-P5 (including P3's prefix closure — eviction must cascade to
        the chain suffix rooted below the reclaimed block) hold after
        every op. Prompts come from a 2-token alphabet so prefixes collide
        constantly and the trie grows real chains."""
        pool = BlockPool(n_blocks, block_size=4)
        live = []                          # per-admission held block lists
        for op in ops:
            if op[0] == "admit":
                prompt, max_new = tuple(op[1]), op[2]
                plan = pool.plan(prompt, max_new)
                admissible = pool.can_admit(prompt, max_new)
                # P5: the gate's verdict matches the plan's need (matched
                # evictable pages count as revived, not allocatable)
                held = _admit(pool, prompt, max_new)
                assert (held is not None) == admissible
                if held is not None:
                    assert plan.new_needed <= n_blocks
                    live.append(held)
            elif op[0] == "release" and live:
                for b in live.pop(op[1] % len(live)):
                    pool.free(b)
            elif op[0] == "storm":
                n = min(op[1], pool.available)
                if n:
                    got = pool.alloc(n)
                    # P4: a storm never hands out a block a live
                    # admission still references
                    assert not (set(got) & {b for bl in live for b in bl})
                    for b in got:
                        pool.free(b)
            pool.check()                   # P1-P3 incl. prefix closure

    @given(ops=st.lists(
        st.one_of(
            st.tuples(st.just("admit"),
                      st.lists(st.integers(0, 1), min_size=1, max_size=12),
                      st.integers(1, 6)),
            st.tuples(st.just("release"), st.integers(0, 30)),
            st.tuples(st.just("spill"), st.integers(0, 30)),
            st.tuples(st.just("revive"), st.integers(0, 30)),
            st.tuples(st.just("storm"), st.integers(1, 8)),
        ),
        min_size=1, max_size=60), n_blocks=st.integers(2, 10))
    @settings(max_examples=60, deadline=None)
    def test_spilled_chains_survive_eviction_storms(self, ops, n_blocks):
        """Engine-style preemption against the pool: a *spilled* admission
        keeps every block reference it held (the engine snapshots only the
        slot-indexed state and leaves the pages pinned), so interleaved
        eviction storms can never reclaim its chain and revival needs no
        new blocks — the chain comes back exactly as spilled."""
        pool = BlockPool(n_blocks, block_size=4)
        live = []                          # in-slot admissions' held blocks
        spilled = []                       # preempted admissions, pinned
        for op in ops:
            if op[0] == "admit":
                held = _admit(pool, tuple(op[1]), op[2])
                if held is not None:
                    live.append(held)
            elif op[0] == "release" and live:
                for b in live.pop(op[1] % len(live)):
                    pool.free(b)
            elif op[0] == "spill" and live:
                # preemption: the slot is lost, the references are not
                spilled.append(live.pop(op[1] % len(live)))
            elif op[0] == "revive" and spilled:
                # revival consumes zero new blocks by construction
                live.append(spilled.pop(op[1] % len(spilled)))
            elif op[0] == "storm":
                n = min(op[1], pool.available)
                if n:
                    got = pool.alloc(n)
                    pinned = {b for bl in live + spilled for b in bl}
                    assert not (set(got) & pinned), \
                        "storm reclaimed a spilled request's pinned page"
                    for b in got:
                        pool.free(b)
            pool.check()
            # every spilled chain is still fully referenced
            for bl in spilled:
                for b in bl:
                    assert pool.refcount(b) >= 1
        # wind down: revive + free everything; the pool must audit clean
        for bl in spilled + live:
            for b in bl:
                pool.free(b)
        pool.check()
