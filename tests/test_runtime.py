"""Unit tests for the runtime fault-tolerance helpers.

Unlike ``test_substrate.py`` (which skips wholesale when hypothesis is
absent), this module runs on the base install — it is where the
checkpoint manager's error paths, the supervisor's restart budget, and
replica-fleet sizing are actually pinned.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, CheckpointWatcher
from repro.runtime.elastic import plan_replicas
from repro.runtime.failures import FailureInjector, SimulatedFailure
from repro.runtime.heartbeat import HeartbeatMonitor, StragglerReport
from repro.runtime.supervisor import Supervisor


class TestHeartbeatMonitor:
    def test_uniform_durations_never_flag(self):
        mon = HeartbeatMonitor(n_workers=4)
        for step in range(20):
            for w in range(4):
                assert mon.beat(w, step, 1.0) is None
        assert mon.reports == []

    def test_warmup_never_flags(self):
        """Below max(8, n_workers) samples there is no baseline to flag
        against — even a wild outlier passes."""
        mon = HeartbeatMonitor(n_workers=4)
        for w in range(4):
            assert mon.beat(w, 0, 100.0 if w == 3 else 1.0) is None

    def test_straggler_flagged(self):
        mon = HeartbeatMonitor(n_workers=4)
        for step in range(4):
            for w in range(4):
                mon.beat(w, step, 1.0 + 0.01 * w)
        report = mon.beat(3, 4, 10.0)
        assert isinstance(report, StragglerReport)
        assert report.worker == 3 and report.step == 4
        assert report.duration == 10.0
        assert report.duration > report.threshold >= 2.0 * report.median
        assert mon.reports == [report]

    def test_threshold_scales_with_jitter(self):
        """A duration outside factor×median still passes when the MAD term
        dominates (noisy-but-healthy fleet)."""
        mon = HeartbeatMonitor(n_workers=2, factor=2.0, z=6.0)
        durations = [1.0, 3.0] * 8            # huge spread → huge MAD
        for step, d in enumerate(durations):
            mon.beat(step % 2, step // 2, d)
        assert mon.beat(0, 9, 5.0) is None    # < median + 6×1.4826×MAD

    def test_dead_workers(self):
        mon = HeartbeatMonitor(n_workers=3, miss_limit=3)
        for step in range(6):
            mon.beat(0, step, 1.0)
            mon.beat(1, step, 1.0)
            if step < 2:
                mon.beat(2, step, 1.0)
        assert mon.dead_workers(current_step=5) == [2]
        assert mon.dead_workers(current_step=2) == []

    def test_window_bounds_history(self):
        mon = HeartbeatMonitor(n_workers=1, window=8)
        for step in range(100):
            mon.beat(0, step, 1.0)
        assert len(mon._history[0]) == 8


class TestFailureInjector:
    def test_fires_once_per_scheduled_step(self):
        inj = FailureInjector(fail_at_steps=[2, 5], kind="preemption")
        survived = []
        step = 0
        while step < 8:
            try:
                inj.maybe_fail(step)
            except SimulatedFailure as e:
                assert "preemption" in str(e) and f"step {step}" in str(e)
                continue                      # restart re-runs the step
            survived.append(step)
            step += 1
        assert survived == list(range(8))
        assert inj.fired == [2, 5]

    def test_unscheduled_steps_pass(self):
        inj = FailureInjector()
        for step in range(10):
            inj.maybe_fail(step)
        assert inj.fired == []

    def test_is_runtime_error(self):
        with pytest.raises(RuntimeError):
            FailureInjector([0]).maybe_fail(0)


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------


def _tree(k=0):
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32) + k,
                       "b": jnp.ones((2,), jnp.bfloat16) * k},
            "step": jnp.asarray(k, jnp.int32)}


class TestCheckpointManager:
    def test_save_restore_roundtrip(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(3, _tree(3), metadata={"loss": 1.5})
        restored, meta = m.restore(_tree())
        assert meta == {"loss": 1.5}
        np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                      np.asarray(_tree(3)["params"]["w"]))
        # bfloat16 is not npz-native; the uint bit-cast must round-trip
        assert restored["params"]["b"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["b"], np.float32),
            np.asarray(_tree(3)["params"]["b"], np.float32))

    def test_restore_by_step(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        for s in (2, 7):
            m.save(s, _tree(s))
        old, _ = m.restore(_tree(), step=2)
        assert int(old["step"]) == 2
        latest, _ = m.restore(_tree())
        assert int(latest["step"]) == 7

    def test_retention_keeps_newest_n(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 5, 9, 12):
            m.save(s, _tree(s))
        assert m.available_steps() == [9, 12]
        assert m.latest_step() == 12
        assert sorted(os.listdir(tmp_path)) == ["step_12", "step_9"]

    def test_async_save_then_wait(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save_async(4, _tree(4))
        m.wait()
        restored, _ = m.restore(_tree())
        assert int(restored["step"]) == 4

    def test_async_failure_surfaces_on_next_call(self, tmp_path,
                                                 monkeypatch):
        """A background write error is reported like a real multi-host
        checkpointer's: on the *next* save, not silently swallowed."""
        m = CheckpointManager(str(tmp_path))

        def boom(*a, **kw):
            raise OSError("disk gone")

        monkeypatch.setattr("repro.checkpoint.manager.np.savez", boom)
        m.save_async(1, _tree(1))
        m.wait()
        monkeypatch.undo()
        with pytest.raises(RuntimeError, match="async checkpoint save"):
            m.save(2, _tree(2))
        m.save(3, _tree(3))             # error consumed; manager recovers
        assert m.available_steps() == [3]

    def test_no_checkpoints_raises_filenotfound(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            CheckpointManager(str(tmp_path)).restore(_tree())

    def test_missing_template_key_raises(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(0, {"a": jnp.ones(3)})
        with pytest.raises(KeyError, match="missing keys"):
            m.restore({"a": jnp.ones(3), "b": jnp.ones(2)})

    def test_truncated_shard_names_file(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(5, _tree(5))
        shard = tmp_path / "step_5" / "shard_0.npz"
        shard.write_bytes(shard.read_bytes()[:40])
        with pytest.raises(RuntimeError,
                           match="corrupt or truncated") as exc:
            m.restore(_tree())
        assert "step_5" in str(exc.value) and "shard_0.npz" in str(exc.value)

    def test_corrupt_manifest_names_step(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(6, _tree(6))
        (tmp_path / "step_6" / "manifest_0.json").write_text("{not json")
        with pytest.raises(RuntimeError, match="manifest is corrupt"):
            m.restore(_tree())

    def test_unfinished_write_is_invisible(self, tmp_path):
        """A crash mid-save (arrays written, manifest missing) must leave
        the step invisible rather than restorable-but-broken."""
        m = CheckpointManager(str(tmp_path))
        m.save(1, _tree(1))
        os.makedirs(tmp_path / "step_2")
        (tmp_path / "step_2" / "shard_0.npz.tmp").write_bytes(b"partial")
        assert m.available_steps() == [1]
        restored, _ = m.restore(_tree())
        assert int(restored["step"]) == 1


class TestCheckpointWatcher:
    def test_reports_each_new_step_once(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        w = CheckpointWatcher(m)
        assert w.poll() is None
        m.save(3, _tree(3))
        assert w.poll() == 3
        assert w.poll() is None            # seen; no re-report
        m.save(8, _tree(8))
        assert w.poll() == 8

    def test_gc_shrinkage_never_rereports(self, tmp_path):
        m = CheckpointManager(str(tmp_path), keep=1)
        w = CheckpointWatcher(m)
        m.save(4, _tree(4))
        assert w.poll() == 4
        m.save(9, _tree(9))                # GC deletes step_4
        assert w.poll() == 9
        assert m.available_steps() == [9]
        assert w.poll() is None            # 9 already seen; 4 is gone

    def test_start_step_suppresses_history(self, tmp_path):
        m = CheckpointManager(str(tmp_path))
        m.save(5, _tree(5))
        w = CheckpointWatcher(m, start_step=5)
        assert w.poll() is None
        m.save(6, _tree(6))
        assert w.poll() == 6


# ---------------------------------------------------------------------------
# supervisor restart budget
# ---------------------------------------------------------------------------


class TestSupervisorBudget:
    def _run(self, tmp_path, fail_steps, max_restarts):
        mgr = CheckpointManager(str(tmp_path))
        inj = FailureInjector(fail_steps)
        trace = []

        def train_fn(start, restored):
            state = restored if restored is not None else 0
            for step in range(start, 8):
                state += step
                inj.maybe_fail(step)
                mgr.save(step, {"acc": jnp.asarray(state)})
                trace.append(step)
            return state

        def restore_fn(step):
            t, _ = mgr.restore({"acc": jnp.asarray(0)}, step=step)
            return int(t["acc"])

        res = Supervisor(mgr, max_restarts=max_restarts).run(
            train_fn, restore_fn=restore_fn)
        return res, trace

    def test_budget_exhausted_reports_incomplete(self, tmp_path):
        # 4 scheduled failures vs a budget of 2 restarts: give up, say so
        res, _ = self._run(tmp_path, [1, 2, 3, 4], max_restarts=2)
        assert not res.completed
        assert res.final_state is None
        assert res.restarts == 3           # max_restarts + the last straw
        assert len(res.failures) == 3

    def test_resume_is_bit_identical_to_unfailed_run(self, tmp_path):
        clean, clean_trace = self._run(tmp_path / "clean", [], 0)
        faulty, faulty_trace = self._run(tmp_path / "faulty", [3, 5], 3)
        assert faulty.completed and faulty.restarts == 2
        assert faulty.final_state == clean.final_state == sum(range(8))
        # no step is recomputed after its checkpoint landed
        assert faulty_trace == sorted(set(faulty_trace)) == clean_trace

    def test_within_budget_failures_are_logged(self, tmp_path):
        res, _ = self._run(tmp_path, [2], max_restarts=3)
        assert res.completed and res.restarts == 1
        assert len(res.failures) == 1 and "step 2" in res.failures[0]


# ---------------------------------------------------------------------------
# elastic replica-fleet sizing
# ---------------------------------------------------------------------------


class TestPlanReplicas:
    def test_floor_division_of_devices(self):
        assert plan_replicas(8) == 8
        assert plan_replicas(8, devices_per_replica=2) == 4
        assert plan_replicas(7, devices_per_replica=2) == 3

    def test_min_replicas_floor(self):
        assert plan_replicas(1, devices_per_replica=4) == 1
        assert plan_replicas(2, devices_per_replica=4, min_replicas=2) == 2

    def test_rejects_bad_arguments(self):
        with pytest.raises(ValueError):
            plan_replicas(0)
        with pytest.raises(ValueError):
            plan_replicas(4, devices_per_replica=0)
        with pytest.raises(ValueError):
            plan_replicas(4, min_replicas=0)
