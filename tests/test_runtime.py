"""Unit tests for the runtime fault-tolerance helpers."""

import pytest

from repro.runtime.failures import FailureInjector, SimulatedFailure
from repro.runtime.heartbeat import HeartbeatMonitor, StragglerReport


class TestHeartbeatMonitor:
    def test_uniform_durations_never_flag(self):
        mon = HeartbeatMonitor(n_workers=4)
        for step in range(20):
            for w in range(4):
                assert mon.beat(w, step, 1.0) is None
        assert mon.reports == []

    def test_warmup_never_flags(self):
        """Below max(8, n_workers) samples there is no baseline to flag
        against — even a wild outlier passes."""
        mon = HeartbeatMonitor(n_workers=4)
        for w in range(4):
            assert mon.beat(w, 0, 100.0 if w == 3 else 1.0) is None

    def test_straggler_flagged(self):
        mon = HeartbeatMonitor(n_workers=4)
        for step in range(4):
            for w in range(4):
                mon.beat(w, step, 1.0 + 0.01 * w)
        report = mon.beat(3, 4, 10.0)
        assert isinstance(report, StragglerReport)
        assert report.worker == 3 and report.step == 4
        assert report.duration == 10.0
        assert report.duration > report.threshold >= 2.0 * report.median
        assert mon.reports == [report]

    def test_threshold_scales_with_jitter(self):
        """A duration outside factor×median still passes when the MAD term
        dominates (noisy-but-healthy fleet)."""
        mon = HeartbeatMonitor(n_workers=2, factor=2.0, z=6.0)
        durations = [1.0, 3.0] * 8            # huge spread → huge MAD
        for step, d in enumerate(durations):
            mon.beat(step % 2, step // 2, d)
        assert mon.beat(0, 9, 5.0) is None    # < median + 6×1.4826×MAD

    def test_dead_workers(self):
        mon = HeartbeatMonitor(n_workers=3, miss_limit=3)
        for step in range(6):
            mon.beat(0, step, 1.0)
            mon.beat(1, step, 1.0)
            if step < 2:
                mon.beat(2, step, 1.0)
        assert mon.dead_workers(current_step=5) == [2]
        assert mon.dead_workers(current_step=2) == []

    def test_window_bounds_history(self):
        mon = HeartbeatMonitor(n_workers=1, window=8)
        for step in range(100):
            mon.beat(0, step, 1.0)
        assert len(mon._history[0]) == 8


class TestFailureInjector:
    def test_fires_once_per_scheduled_step(self):
        inj = FailureInjector(fail_at_steps=[2, 5], kind="preemption")
        survived = []
        step = 0
        while step < 8:
            try:
                inj.maybe_fail(step)
            except SimulatedFailure as e:
                assert "preemption" in str(e) and f"step {step}" in str(e)
                continue                      # restart re-runs the step
            survived.append(step)
            step += 1
        assert survived == list(range(8))
        assert inj.fired == [2, 5]

    def test_unscheduled_steps_pass(self):
        inj = FailureInjector()
        for step in range(10):
            inj.maybe_fail(step)
        assert inj.fired == []

    def test_is_runtime_error(self):
        with pytest.raises(RuntimeError):
            FailureInjector([0]).maybe_fail(0)
