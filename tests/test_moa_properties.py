"""Hypothesis property tests for the MOA/LOA invariants.

Deliberately exercises the deprecated :mod:`repro.core.moa` shim — these
invariants must keep holding through the legacy surface.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis package")
from hypothesis import given, settings, strategies as st

from repro.core import loa, metrics

with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    from repro.core import moa

_INTS = st.integers(min_value=0, max_value=255)


class TestLoaProperties:
    @given(x=_INTS, y=_INTS, l=st.integers(0, 8))
    @settings(max_examples=200, deadline=None)
    def test_matches_scalar_gate_model(self, x, y, l):
        got = int(loa.loa_add(jnp.int32(x), jnp.int32(y),
                              approx_bits=l, width=8))
        want = loa.loa_add_reference_python(x, y, l)
        assert got == want

    @given(x=_INTS, y=_INTS, l=st.integers(0, 8))
    @settings(max_examples=200, deadline=None)
    def test_error_bound(self, x, y, l):
        """|ŝ − s| < 2^l — the LOA deviation bound."""
        s_hat = loa.loa_add_reference_python(x, y, l)
        assert abs(s_hat - (x + y)) < max(1 << l, 1)

    @given(x=_INTS, y=_INTS, l=st.integers(0, 8))
    @settings(max_examples=100, deadline=None)
    def test_commutative(self, x, y, l):
        assert loa.loa_add_reference_python(x, y, l) == \
            loa.loa_add_reference_python(y, x, l)

    @given(x=_INTS, y=_INTS)
    @settings(max_examples=50, deadline=None)
    def test_exact_at_l0(self, x, y):
        assert loa.loa_add_reference_python(x, y, 0) == x + y

    @given(n=st.integers(2, 64), l=st.integers(0, 4),
           seed=st.integers(0, 1000))
    @settings(max_examples=30, deadline=None)
    def test_tree_reduction_error_bound(self, n, l, seed):
        """Tree of LOAs: worst case error < (n−1)·2^l (one deviation per
        adder instance; widths grow so the bound is conservative)."""
        rng = np.random.default_rng(seed)
        xs = rng.integers(0, 255, size=(n, 1)).astype(np.int32)
        got = int(loa.loa_sum(jnp.asarray(xs), approx_bits=l, width=8,
                              axis=0)[0])
        exact = int(xs.sum())
        assert abs(got - exact) < max((n - 1) * (1 << l), 1)


class TestMoaEquivalence:
    @given(n=st.integers(1, 300), chunk=st.integers(1, 64),
           seed=st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_serial_equals_tree_equals_sum_int(self, n, chunk, seed):
        """Integer reductions are exactly schedule-invariant."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(-1000, 1000, size=(n, 3)), jnp.int32)
        want = np.asarray(jnp.sum(x, axis=0))
        tree = moa.moa_sum(x, axis=0, strategy=moa.ReductionStrategy(
            kind="tree", accum_dtype=jnp.int32))
        serial = moa.moa_sum(x, axis=0, strategy=moa.ReductionStrategy(
            kind="serial", chunk=chunk, accum_dtype=jnp.int32))
        np.testing.assert_array_equal(np.asarray(tree), want)
        np.testing.assert_array_equal(np.asarray(serial), want)

    @given(n=st.integers(1, 200), chunk=st.integers(1, 64),
           seed=st.integers(0, 100))
    @settings(max_examples=20, deadline=None)
    def test_serial_close_to_sum_float(self, n, chunk, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((n, 4)), jnp.float32)
        want = np.asarray(jnp.sum(x, axis=0))
        for kind in ("tree", "serial"):
            got = moa.moa_sum(x, axis=0, strategy=moa.ReductionStrategy(
                kind=kind, chunk=chunk))
            np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4,
                                       atol=1e-4)

    @given(m=st.integers(1, 16), k=st.integers(1, 128),
           n=st.integers(1, 16), chunk=st.integers(1, 64),
           seed=st.integers(0, 100))
    @settings(max_examples=20, deadline=None)
    def test_moa_dot_equals_matmul(self, m, k, n, chunk, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        got = moa.moa_dot(a, b, strategy=moa.ReductionStrategy(
            kind="serial", chunk=chunk))
        np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                                   rtol=1e-4, atol=1e-4)

    def test_loa_dot_exact_when_l0(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(0, 10, (4, 33)), jnp.int32)
        b = jnp.asarray(rng.integers(0, 10, (33, 5)), jnp.int32)
        got = moa.moa_dot(a, b, strategy=moa.ReductionStrategy(
            kind="loa", approx_bits=0))
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(a) @ np.asarray(b))


class TestMetrics:
    @given(seed=st.integers(0, 1000))
    @settings(max_examples=20, deadline=None)
    def test_mred_zero_iff_equal(self, seed):
        rng = np.random.default_rng(seed)
        s = jnp.asarray(rng.integers(1, 1000, 50), jnp.int32)
        assert float(metrics.mred(s, s)) == 0.0

    @given(seed=st.integers(0, 100), scale=st.floats(0.01, 0.5))
    @settings(max_examples=20, deadline=None)
    def test_mred_scales_with_perturbation(self, seed, scale):
        rng = np.random.default_rng(seed)
        s = rng.integers(100, 1000, 100).astype(np.float32)
        s_hat = s * (1 + scale)
        assert abs(float(metrics.mred(s_hat, s)) - scale) < 1e-3
