import os
import sys

# NOTE: deliberately NO xla_force_host_platform_device_count here — tests
# and benches must see the real single CPU device; only launch/dryrun.py
# (run as its own process) requests 512 placeholder devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
