"""Reproduction of the paper's published numbers (Table 1, Figs. 4 & 5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model, dhm, loa, metrics


# ---------------------------------------------------------------------------
# Table 1 — MOA census of AlexNet
# ---------------------------------------------------------------------------

class TestTable1:
    def test_structural_operand_counts(self):
        expected = {"conv1": 363, "conv2": 1200, "conv3": 2304,
                    "conv4": 1728, "conv5": 1728}
        for spec in dhm.ALEXNET_CONV_SPECS:
            assert spec.operands == expected[spec.name]

    def test_moa_count_equals_filters(self):
        expected_n = {"conv1": 96, "conv2": 256, "conv3": 384,
                      "conv4": 384, "conv5": 256}
        for spec in dhm.ALEXNET_CONV_SPECS:
            assert spec.n_filters == expected_n[spec.name]

    def test_mean_nonnull_operands_match_paper(self):
        """n_opd within 2% of Table 1 (density-calibrated weights — trained
        AlexNet weights are unavailable offline; see DESIGN.md)."""
        reports = dhm.analyze_network(
            dhm.ALEXNET_CONV_SPECS, densities=dhm.paper_calibrated_densities())
        for r in reports:
            paper = dhm.ALEXNET_PAPER_NOPD[r.spec.name]
            assert abs(r.n_opd - paper) / paper < 0.02, \
                (r.spec.name, r.n_opd, paper)

    def test_moa_fraction_is_69_percent(self):
        """The paper's headline: 69% of conv1 logic is MOA adders."""
        reports = dhm.analyze_network(
            dhm.ALEXNET_CONV_SPECS, densities=dhm.paper_calibrated_densities())
        conv1 = reports[0]
        assert abs(conv1.moa_fraction - 0.69) < 0.01

    def test_quantization_creates_census(self):
        w = np.random.default_rng(0).standard_normal((8, 4, 3, 3))
        census = dhm.scm.classify_weights(w)
        assert census.total == 8 * 4 * 9
        assert census.zeros + census.pow2 + census.generic == census.total


# ---------------------------------------------------------------------------
# Figure 4 — serialization never wins
# ---------------------------------------------------------------------------

class TestFigure4:
    @pytest.mark.parametrize("n", [2, 4, 6, 8, 16, 32, 64, 128, 325, 1774])
    def test_serial_moa_exceeds_tree(self, n):
        """§4.1: serializer+accumulator > pipelined adder tree at EVERY
        cluster size — the paper's first negative result."""
        tree = cost_model.alm_adder_tree(n, 8)
        serial = cost_model.alm_serial_moa(n, 8)
        assert serial > tree, (n, serial, tree)

    def test_serializer_grows_linearly(self):
        """Fig. 4: serializer cost is linear in the number of operands."""
        costs = [cost_model.alm_serializer(n, 8) for n in (8, 16, 32, 64)]
        ratios = [costs[i + 1] / costs[i] for i in range(3)]
        assert all(abs(r - 2.0) < 0.01 for r in ratios)

    def test_accumulator_is_cheap(self):
        """The accumulator itself IS small — the serializer is the problem."""
        assert cost_model.alm_accumulator(64, 8) < \
            cost_model.alm_serializer(64, 8) / 10


# ---------------------------------------------------------------------------
# Figure 5 — LOA: accuracy degrades gracefully, area does not shrink
# ---------------------------------------------------------------------------

class TestFigure5:
    def _mred_for(self, bits, l, n=20000, seed=0):
        k = jax.random.PRNGKey(seed)
        kx, ky = jax.random.split(k)
        hi = 2 ** bits
        x = jax.random.randint(kx, (n,), 0, hi, jnp.int32)
        y = jax.random.randint(ky, (n,), 0, hi, jnp.int32)
        s_hat = loa.loa_add(x, y, approx_bits=l, width=bits)
        return float(metrics.mred(s_hat, x + y))

    def test_mred_below_10pct_at_8bit(self):
        """Paper: '< 10% MRED for 8-bit adders' across ratios ≤ 50%."""
        for l in (1, 2, 3, 4):
            assert self._mred_for(8, l) < 0.10, l

    def test_mred_monotone_in_approximation_ratio(self):
        vals = [self._mred_for(8, l) for l in range(0, 7)]
        assert vals[0] == 0.0
        assert all(vals[i] <= vals[i + 1] + 1e-6 for i in range(len(vals) - 1))

    def test_mred_decreases_with_bitwidth(self):
        """Fig. 5: at fixed l, wider adders have lower relative error."""
        at_l2 = [self._mred_for(b, 2) for b in (4, 8, 12, 16)]
        assert all(at_l2[i] > at_l2[i + 1] for i in range(3))

    @pytest.mark.parametrize("bits", [4, 8, 12, 16])
    def test_alm_flat_in_approx_bits(self, bits):
        """The paper's second negative result: ALM count is CONSTANT in l —
        the hard-wired full adder costs the same as an OR gate."""
        costs = {l: cost_model.alm_loa_adder(bits, l)
                 for l in range(0, bits + 1)}
        assert len(set(costs.values())) == 1

    def test_tpu_analogue_loa_costs_more(self):
        """TPU inversion of the same root cause: the LOA gate structure
        needs ~6 VPU ops where the hard adder needs 1 (DESIGN.md §2)."""
        assert cost_model.vpu_ops_loa_add() >= 6 * cost_model.vpu_ops_exact_add()
